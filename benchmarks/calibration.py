"""Calibration bench: what the accuracy-per-byte wire costs and buys.

Times the context-aware greedy calibration pass on the trained reduced
LM, then reports the v2 stream's byte economics against the v1 uniform
ladder: total bytes (raw vs entropy-coded), per-mode unit counts, and
the accuracy-per-byte curves from the Table-2 machinery. Writes
``artifacts/bench/BENCH_calibration.json`` (mirrored to the repo root
by ``benchmarks.run``).

    PYTHONPATH=src python -m benchmarks.calibration [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import entropy, wire
from repro.core.calibrate import uniform_schedule
from repro.core.progressive import divide

OUT_PATH = "artifacts/bench/BENCH_calibration.json"
MODE_NAMES = {entropy.MODE_RAW: "raw", entropy.MODE_RLE: "rle",
              entropy.MODE_RANS: "rans"}


def _unit_mode_counts(blob: bytes) -> dict[str, int]:
    """Count per-unit entropy modes by walking the framed stream."""
    meta, hdr = wire.decode_header(blob)
    layout = wire.layout_from_header(meta, hdr)
    counts = {name: 0 for name in MODE_NAMES.values()}
    off = hdr
    for stage in layout.stages:
        for (_, _, nbytes, _) in stage:
            counts[MODE_NAMES[blob[off]]] += 1
            off += nbytes
    return counts


def bench(quick: bool = False) -> dict:
    from benchmarks.table2_accuracy import _lm_setup, accuracy_per_byte_lm

    setup = _lm_setup(quick)
    _, _, params, _, _ = setup
    prog = divide(params)

    t0 = time.time()
    apb = accuracy_per_byte_lm(setup)  # calibrates + builds + evaluates
    apb_s = time.time() - t0

    blob_v1 = wire.encode(prog)
    blob_v2_raw = wire.encode(prog, schedule=uniform_schedule(prog),
                              entropy_coded=False)
    blob_v2_coded = wire.encode(prog, schedule=uniform_schedule(prog),
                                entropy_coded=True)
    return {
        "bench": "calibration",
        "model": apb["model"],
        "calibrate_and_eval_s": apb_s,
        "n_units": apb["schedule_units"],
        "bytes": {
            "v1_raw_uniform": len(blob_v1),
            "v2_raw_uniform": len(blob_v2_raw),
            "v2_coded_uniform": len(blob_v2_coded),
            "v2_coded_scheduled": apb["scheduled_coded_total_bytes"],
        },
        "unit_modes": _unit_mode_counts(blob_v2_coded),
        "accuracy_per_byte": apb,
    }


def main(quick: bool = False, out: str = OUT_PATH) -> None:
    result = bench(quick)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print("\n== calibration: accuracy-per-byte wire economics ==")
    b = result["bytes"]
    print(f"v1 raw uniform stream:     {b['v1_raw_uniform']:>10,} bytes")
    print(f"v2 raw uniform stream:     {b['v2_raw_uniform']:>10,} bytes "
          f"(framed header overhead)")
    print(f"v2 coded uniform stream:   {b['v2_coded_uniform']:>10,} bytes")
    print(f"v2 coded calibrated:       {b['v2_coded_scheduled']:>10,} bytes")
    print(f"unit entropy modes: {result['unit_modes']} "
          f"({result['n_units']} units)")
    print(f"calibration + curve eval: {result['calibrate_and_eval_s']:.1f}s")
    assert b["v2_coded_scheduled"] <= b["v1_raw_uniform"], \
        "coded stream must not exceed the raw uniform stream"
    print(f"-> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
