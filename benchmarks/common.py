"""Shared benchmark plumbing: measured per-stage client costs."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.progressive import ProgressiveModel, ReceiverState
from repro.transmission.scheduler import StageCost


def measure_stage_costs(prog: ProgressiveModel, infer_fn, n_warmup: int = 1,
                        repeats: int = 3) -> list[StageCost]:
    """Measure concat (eq. 4 OR), dequant (eq. 5), and inference wall
    times per stage on this machine. infer_fn(params) -> array."""
    costs = []
    st = ReceiverState.init(prog)
    for s in range(1, prog.n_stages + 1):
        planes = prog.stage(s)

        t0 = time.perf_counter()
        st2 = st.receive(planes)
        jax.block_until_ready([a for a in st2.acc])
        t_concat = time.perf_counter() - t0

        t0 = time.perf_counter()
        params = st2.materialize()
        jax.block_until_ready(jax.tree.leaves(params))
        t_dequant = time.perf_counter() - t0

        ts = []
        for r in range(n_warmup + repeats):
            t0 = time.perf_counter()
            out = infer_fn(params)
            jax.block_until_ready(out)
            if r >= n_warmup:
                ts.append(time.perf_counter() - t0)
        costs.append(StageCost(concat_s=t_concat, dequant_s=t_dequant,
                               inference_s=sum(ts) / len(ts)))
        st = st2
    return costs


def fmt_row(cols, widths):
    return " | ".join(str(c).ljust(w) for c, w in zip(cols, widths))
