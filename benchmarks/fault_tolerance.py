"""Fault-tolerance benchmark: what reliability costs on the wire.

Two questions, both on the real transport path (no mocks):

1. **Framing overhead** — the v3 integrity wire adds 8 bytes per unit
   (``<seq u32><crc u32>``) plus a 4-byte header CRC. How much goodput
   does that cost vs the v2 stream it frames, at small and large unit
   sizes, with and without entropy coding?
2. **Time-to-stage-k under corruption** — with seeded bit-flip faults
   at 0 / 0.1 / 1 % of chunks, how much later does each verified
   checkpoint land vs the clean channel, and how many retransmitted
   bytes did recovery cost? Every lossy run must still converge to a
   store bit-identical to the clean stream (asserted — this benchmark
   doubles as an acceptance check).

Emits ``artifacts/bench/BENCH_fault_tolerance.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import wire
from repro.core.progressive import divide
from repro.transmission.client import ProgressiveClient
from repro.transmission.session import FaultPolicy, Session
from repro.transmission.simulator import BandwidthTrace, FaultTrace

OUT_PATH = "artifacts/bench/BENCH_fault_tolerance.json"
CORRUPTION_RATES = (0.0, 0.001, 0.01)
# v3 framing must stay cheap on realistically-sized units
OVERHEAD_CEIL_FRAC = 0.02


def _make_params(n_tensors: int, side: int) -> dict:
    k = jax.random.PRNGKey(0)
    return {
        f"block{i:02d}/w": jax.random.normal(jax.random.fold_in(k, i),
                                             (side, side))
        for i in range(n_tensors)
    }


def bench_framing(n_tensors: int, side: int) -> dict:
    """v3 bytes vs the v2 stream it frames, raw and entropy-coded."""
    prog = divide(_make_params(n_tensors, side))
    out = {"n_tensors": n_tensors, "side": side}
    for tag, ec in (("raw", False), ("entropy", True)):
        v2 = wire.encode(prog, schedule=None, entropy_coded=ec) if ec else \
            wire.encode(prog)
        v3 = wire.encode(prog, integrity=True, entropy_coded=ec)
        meta, _ = wire.decode_header(v3)
        rep = wire.framing_overhead(meta)
        out[tag] = {
            "v2_bytes": len(v2), "v3_bytes": len(v3),
            "n_units": rep["n_units"],
            "declared_overhead_bytes": rep["overhead_bytes"],
            "payload_overhead_frac": rep["overhead_frac"],
            "stream_overhead_frac": len(v3) / len(v2) - 1.0,
        }
    return out


def _delivered_bytes(events, unit_sizes) -> int:
    """Total bytes that crossed the (lossy) link, retransmits included."""
    total = 0
    for e in events:
        if e.kind == "chunk":
            total += e.data["bytes"]
        elif e.kind == "repair":
            total += unit_sizes[e.data["unit"]]
    return total


def bench_corruption(blob: bytes, ref_fingerprint: dict,
                     p_corrupt: float, *, seed: int = 0) -> dict:
    """Stream ``blob`` through a lossy 1 MB/s link; record when each
    verified checkpoint lands and what recovery re-shipped."""
    sess = Session(blob, BandwidthTrace.constant(1e6),
                   chunk_bytes=16 * 1024, latency_s=0.02)
    client = ProgressiveClient()
    events: list = []
    faults = FaultTrace(seed=seed, p_corrupt=p_corrupt)
    _, runner = sess._make_transport(client, events, faults,
                                     FaultPolicy(seed=seed))
    walls = [runner.run_until_stage(k + 1) for k in range(sess.n_stages)]
    runner.pump_all()
    assert client.complete and not client.nacks
    client.materialize()
    assert client.store.fingerprint() == ref_fingerprint, \
        f"lossy run (p={p_corrupt}) diverged from the clean stream"
    unit_sizes = [e[2] for st in sess.layout.stages for e in st]
    delivered = _delivered_bytes(events, unit_sizes)
    return {
        "p_corrupt": p_corrupt,
        "time_to_stage_s": [round(w, 6) for w in walls],
        "converged_s": round(runner.wall(), 6),
        "delivered_bytes": delivered,
        "goodput_frac": len(blob) / max(delivered, 1),
        "transport": runner.summary(),
    }


def main(quick: bool = False) -> None:
    print("\n== v3 integrity framing overhead ==")
    framing = []
    sweep = [(16, 32), (16, 128)] if quick else [(16, 32), (32, 128),
                                                 (32, 256)]
    for n, side in sweep:
        r = bench_framing(n, side)
        framing.append(r)
        for tag in ("raw", "entropy"):
            print(f"{n:3d}x{side}^2 {tag:8s} v2={r[tag]['v2_bytes']:9d}B "
                  f"v3={r[tag]['v3_bytes']:9d}B  "
                  f"stream overhead {r[tag]['stream_overhead_frac']:.3%} "
                  f"({r[tag]['n_units']} units)")
    # large-unit regime is the deployment story; tiny toy units are
    # allowed to exceed the ceiling (8 B on a 100 B unit is 8%)
    big = framing[-1]["raw"]
    assert big["stream_overhead_frac"] < OVERHEAD_CEIL_FRAC, \
        f"v3 framing too expensive: {big['stream_overhead_frac']:.3%}"

    print("\n== time-to-stage-k under bit-flip corruption (1 MB/s) ==")
    prog = divide(_make_params(*(sweep[-1])))
    blob = wire.encode(prog, integrity=True)
    ref = ProgressiveClient()
    ref.feed(blob)
    ref.materialize()
    ref_fp = ref.store.fingerprint()
    corruption = []
    for p in CORRUPTION_RATES:
        r = bench_corruption(blob, ref_fp, p)
        corruption.append(r)
        w = r["time_to_stage_s"]
        print(f"p={p:<6g} stage1={w[0]:7.3f}s final={w[-1]:7.3f}s "
              f"converged={r['converged_s']:7.3f}s "
              f"goodput={r['goodput_frac']:.3f} "
              f"quarantined={r['transport']['quarantined']} "
              f"repaired={r['transport']['repaired_units']}")
    clean_final = corruption[0]["time_to_stage_s"][-1]
    for r in corruption[1:]:
        assert r["time_to_stage_s"][-1] >= clean_final - 1e-9, \
            "corruption cannot make the stream finish earlier"

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"framing": framing, "corruption": corruption,
                   "overhead_ceiling_frac": OVERHEAD_CEIL_FRAC}, f, indent=2)
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small tensors / fewer corruption rates")
    main(quick=ap.parse_args().quick)
