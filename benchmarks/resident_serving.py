"""Resident-serving benchmark: fp-materialized vs quantized-resident.

Measures, per precision stage, the three quantities the quantized-
resident refactor is about:

* **resident weight HBM bytes** — what the live param pytree pins:
  fp mode = float leaves (the re-materialized model) *plus* the uint
  accumulators it keeps underneath; quantized mode = the uint
  accumulator views plus the tiny fp remainder (norms/gates) and the
  (1,1)-ish affine metadata.
* **upgrade latency** — ``receive_stage()`` wall time: fp pays ingest +
  model-wide incremental dequantize; quantized pays ingest + metadata
  refresh only.
* **per-step decode time** — greedy decode through the jitted step at
  the final stage (plus the compiled-executable count, which must be 1
  for the quantized server across every upgrade).

Emits ``artifacts/bench/BENCH_resident_serving.json`` — the first
datapoint of the perf trajectory. On this CPU container the Pallas
dequant-matmul runs *interpreted*, so quantized decode steps carry a
large constant interpreter overhead that a real TPU does not have; the
bytes and upgrade-latency columns are the portable signal here.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.bitplanes import PlaneSchedule
from repro.core.policy import UniformPolicy
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import ProgressiveServer, resident_report

OUT_PATH = "artifacts/bench/BENCH_resident_serving.json"


def _fp_weight_bytes(params) -> int:
    return sum(np.size(l) * jnp.asarray(l).dtype.itemsize
               for l in jax.tree.leaves(params))


def _resident_bytes(server: ProgressiveServer) -> dict:
    """Device bytes the live server pins for weights. Both modes keep
    the flat uint accumulators (upgrades OR into them). On top of that,
    fp mode holds the full float materialization, while quantized mode
    holds the *uint* leaf views (slicing a buffer outside jit copies —
    the honest cost of view-shaped params) plus the tiny fp remainder
    and affine metadata. No fp weight buffer exists in quantized mode;
    the uint views are k-bit, so the total is (2k)/(k+32) of fp mode."""
    rep = server.resident_report()
    store = (server._receiver.store if server._receiver is not None
             else server.state.store)
    if server.resident == "fp":
        return {"weights": rep["fp_bytes"],
                "accumulators": store.resident_bytes(),
                "total": rep["fp_bytes"] + store.resident_bytes()}
    total = (store.resident_bytes() + rep["quantized_bytes"]
             + rep["fp_bytes"] + rep["metadata_bytes"])
    return {"weights": rep["quantized_bytes"],
            "accumulators": store.resident_bytes(),
            "fp_remainder": rep["fp_bytes"],
            "metadata": rep["metadata_bytes"],
            "total": total}


def bench(arch: str = "olmo-1b", *, stages: int = 4, decode_steps: int = 8,
          prompt_len: int = 8, batch: int = 2, seed: int = 0) -> dict:
    widths = tuple([16 // stages] * stages)
    schedule = PlaneSchedule(bits=16, widths=widths)
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    prog = divide(params, UniformPolicy(schedule=schedule))
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                (batch, prompt_len), 0, cfg.vocab
                                ).astype(jnp.int32)
    max_len = prompt_len + decode_steps

    servers = {m: ProgressiveServer(model, prog, max_len=max_len, resident=m)
               for m in ("fp", "quantized")}
    per_stage = []
    for s in range(1, prog.n_stages + 1):
        row = {"stage": s, "bits": schedule.cumulative_bits[s - 1]}
        for mode, srv in servers.items():
            t0 = time.perf_counter()
            srv.receive_stage()
            jax.block_until_ready(jax.tree.leaves(srv.params))
            row[f"{mode}_upgrade_s"] = time.perf_counter() - t0
            row[f"{mode}_resident_bytes"] = _resident_bytes(srv)
        per_stage.append(row)

    decode = {}
    for mode, srv in servers.items():
        srv.start({"tokens": tokens})
        srv.decode(2)  # warm the compiled step
        srv.start({"tokens": tokens})
        # sync mode: honest *per-token* dispatch+wait, comparable with
        # the pre-continuous-batching numbers (async windows live in
        # benchmarks/serving_throughput.py)
        res = srv.decode(decode_steps, sync=True)
        decode[mode] = {
            "per_step_s": float(np.mean(res.per_step_s)),
            "decode_cache_size": srv.decode_cache_size(),
        }
    return {
        "bench": "resident_serving",
        "arch": arch,
        "schedule": {"bits": 16, "widths": list(widths)},
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "fp_model_bytes": _fp_weight_bytes(params),
        "stages": per_stage,
        "decode": decode,
    }


def main(quick: bool = False, out: str = OUT_PATH) -> None:
    result = bench(decode_steps=4 if quick else 8)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print(f"\n== resident serving: fp vs quantized ({result['arch']}) ==")
    print(f"{'stage':>5} {'bits':>4} {'fp bytes':>12} {'quant bytes':>12} "
          f"{'fp upg':>9} {'quant upg':>9}")
    for r in result["stages"]:
        print(f"{r['stage']:5d} {r['bits']:4d} "
              f"{r['fp_resident_bytes']['total']:12d} "
              f"{r['quantized_resident_bytes']['total']:12d} "
              f"{r['fp_upgrade_s']*1e3:7.1f}ms "
              f"{r['quantized_upgrade_s']*1e3:7.1f}ms")
    d = result["decode"]
    print(f"decode per step: fp {d['fp']['per_step_s']*1e3:.1f}ms, "
          f"quantized {d['quantized']['per_step_s']*1e3:.1f}ms "
          f"(interpreted kernels: {result['interpret_kernels']}); "
          f"quantized decode executables: "
          f"{d['quantized']['decode_cache_size']}")
    assert d["quantized"]["decode_cache_size"] == 1, \
        "quantized-resident decode must never recompile across upgrades"
    print(f"-> {out}")


if __name__ == "__main__":
    main()
