"""§Roofline table generator: reads artifacts/dryrun/*.json (written by
repro.launch.dryrun) and renders the per-(arch x shape) roofline terms,
dominant bottleneck, and useful-FLOPs ratio. Single-pod mesh only (the
multi-pod runs are compile/sharding proofs)."""
from __future__ import annotations

import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str = "artifacts/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*__16x16.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return rows


def render(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "useful ratio | fits/chip |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped: {r['reason'][:40]}… | — | — |")
            continue
        if r["status"] != "ok" or "roofline" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        rl = r["roofline"]
        ma = r["memory_analysis"]
        resident = (ma["argument_bytes"] + ma["temp_bytes"]
                    + ma["output_bytes"] - ma["alias_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {max(rl['compute_s'], 0):.3e} | "
            f"{max(rl['memory_s'], 0):.3e} | {max(rl['collective_s'], 0):.3e} | "
            f"**{rl['dominant']}** | {rl['useful_ratio']:.3f} | "
            f"{resident:.1f} GiB |"
        )
    return "\n".join(out)


def main(quick: bool = False) -> None:
    rows = load()
    if not rows:
        print("\n== Roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all) ==")
        return
    print("\n== Roofline (single-pod 16x16, v5e constants) ==")
    print(render(rows))
    ok = [r for r in rows if r.get("status") == "ok" and "roofline" in r]
    if ok:
        doms = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
        print(f"\n{len(ok)} combos analysed; dominant-term histogram: {doms}")


if __name__ == "__main__":
    main()
