"""Benchmark orchestrator: one module per paper table + the roofline
report. ``python -m benchmarks.run [--quick]``."""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced training steps / fewer archs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,table3,"
                         "roofline,upgrade_latency,resident_serving,"
                         "serving_throughput,speculative_decode")
    args = ap.parse_args()

    from benchmarks import table1_execution_time, table2_accuracy, table3_ttfi
    from benchmarks import resident_serving, roofline, serving_throughput
    from benchmarks import speculative_decode, upgrade_latency

    benches = {
        "table1": table1_execution_time,
        "table2": table2_accuracy,
        "table3": table3_ttfi,
        "roofline": roofline,
        "upgrade_latency": upgrade_latency,
        "resident_serving": resident_serving,
        "serving_throughput": serving_throughput,
        "speculative_decode": speculative_decode,
    }
    selected = (args.only.split(",") if args.only else list(benches))

    os.makedirs("artifacts/bench", exist_ok=True)
    failures = []
    for name in selected:
        mod = benches[name]
        t0 = time.time()
        print(f"\n########## {name} ##########")
        try:
            mod.main(quick=args.quick)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"[{name}: {time.time() - t0:.1f}s]")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
