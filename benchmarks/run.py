"""Benchmark orchestrator: one module per paper table + the roofline
report. ``python -m benchmarks.run [--quick] [--only a,b] [--list]``.

Every bench writes its ``BENCH_*.json`` under ``artifacts/bench/``;
after a bench SUCCEEDS, the files it produced (new or updated) are
mirrored to the repo root so the latest numbers are diffable in review
without digging into (gitignored or CI-uploaded) artifact trees. A
failing bench mirrors nothing — the root copies never go stale from a
mid-run crash."""
from __future__ import annotations

import argparse
import glob
import os
import shutil
import time
import traceback


def _bench_snapshot(src_dir: str = "artifacts/bench") -> dict[str, float]:
    """``{path: mtime}`` of the BENCH artifacts currently on disk."""
    return {p: os.path.getmtime(p)
            for p in glob.glob(os.path.join(src_dir, "BENCH_*.json"))}


def mirror_artifacts(src_dir: str = "artifacts/bench",
                     dst_dir: str = ".",
                     since: dict[str, float] | None = None) -> list[str]:
    """Copy ``BENCH_*.json`` from ``src_dir`` to ``dst_dir`` (repo root
    by default). With ``since`` (a :func:`_bench_snapshot`), only files
    created or modified after the snapshot are mirrored. Returns the
    mirrored paths."""
    out = []
    for path in sorted(glob.glob(os.path.join(src_dir, "BENCH_*.json"))):
        if since is not None and os.path.getmtime(path) <= since.get(
                path, -1.0):
            continue
        dst = os.path.join(dst_dir, os.path.basename(path))
        shutil.copyfile(path, dst)
        out.append(dst)
    return out


def _bench_modules() -> dict:
    from benchmarks import table1_execution_time, table2_accuracy, table3_ttfi
    from benchmarks import calibration, fault_tolerance, resident_serving
    from benchmarks import roofline, serving_throughput, speculative_decode
    from benchmarks import upgrade_latency

    return {
        "table1": table1_execution_time,
        "table2": table2_accuracy,
        "table3": table3_ttfi,
        "roofline": roofline,
        "upgrade_latency": upgrade_latency,
        "resident_serving": resident_serving,
        "serving_throughput": serving_throughput,
        "speculative_decode": speculative_decode,
        "calibration": calibration,
        "fault_tolerance": fault_tolerance,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced training steps / fewer archs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print the available benchmark names and exit")
    args = ap.parse_args()

    benches = _bench_modules()
    if args.list:
        for name, mod in benches.items():
            doc = (mod.__doc__ or "").strip().splitlines()
            print(f"{name:20s} {doc[0] if doc else ''}")
        return
    selected = (args.only.split(",") if args.only else list(benches))
    unknown = [n for n in selected if n not in benches]
    if unknown:
        raise SystemExit(
            f"unknown benchmark name(s): {', '.join(unknown)} "
            f"(available: {', '.join(benches)})")

    os.makedirs("artifacts/bench", exist_ok=True)
    failures = []
    mirrored_all: list[str] = []
    for name in selected:
        mod = benches[name]
        t0 = time.time()
        print(f"\n########## {name} ##########")
        before = _bench_snapshot()
        try:
            mod.main(quick=args.quick)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        else:
            # mirror only what this (successful) bench wrote
            mirrored_all += mirror_artifacts(since=before)
        print(f"[{name}: {time.time() - t0:.1f}s]")
    if mirrored_all:
        print(f"\nmirrored to repo root: {', '.join(sorted(set(mirrored_all)))}")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
