"""Benchmark orchestrator: one module per paper table + the roofline
report. ``python -m benchmarks.run [--quick]``.

Every bench writes its ``BENCH_*.json`` under ``artifacts/bench/``;
after the sweep each one is mirrored to the repo root so the latest
numbers are diffable in review without digging into (gitignored or CI-
uploaded) artifact trees."""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import time
import traceback


def mirror_artifacts(src_dir: str = "artifacts/bench",
                     dst_dir: str = ".") -> list[str]:
    """Copy each ``BENCH_*.json`` in ``src_dir`` to ``dst_dir``
    (repo root by default). Returns the mirrored paths."""
    out = []
    for path in sorted(glob.glob(os.path.join(src_dir, "BENCH_*.json"))):
        dst = os.path.join(dst_dir, os.path.basename(path))
        shutil.copyfile(path, dst)
        out.append(dst)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced training steps / fewer archs")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,table3,"
                         "roofline,upgrade_latency,resident_serving,"
                         "serving_throughput,speculative_decode,"
                         "calibration,fault_tolerance")
    args = ap.parse_args()

    from benchmarks import table1_execution_time, table2_accuracy, table3_ttfi
    from benchmarks import calibration, fault_tolerance, resident_serving
    from benchmarks import roofline, serving_throughput, speculative_decode
    from benchmarks import upgrade_latency

    benches = {
        "table1": table1_execution_time,
        "table2": table2_accuracy,
        "table3": table3_ttfi,
        "roofline": roofline,
        "upgrade_latency": upgrade_latency,
        "resident_serving": resident_serving,
        "serving_throughput": serving_throughput,
        "speculative_decode": speculative_decode,
        "calibration": calibration,
        "fault_tolerance": fault_tolerance,
    }
    selected = (args.only.split(",") if args.only else list(benches))

    os.makedirs("artifacts/bench", exist_ok=True)
    failures = []
    for name in selected:
        mod = benches[name]
        t0 = time.time()
        print(f"\n########## {name} ##########")
        try:
            mod.main(quick=args.quick)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"[{name}: {time.time() - t0:.1f}s]")
    mirrored = mirror_artifacts()
    if mirrored:
        print(f"\nmirrored to repo root: {', '.join(mirrored)}")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
