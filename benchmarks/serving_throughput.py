"""Continuous-batching serving throughput: the slot pool under load.

Measures, per pool size (batch 1 / 4 / 16), the quantities the
ISSUE-4 continuous-batching refactor is about:

* **aggregate tokens/s** — total tokens emitted over honest wall-clock
  across flushed dispatch windows (warm executable; compile excluded).
  The acceptance floor on the reduced config is batch-16 >= 4x batch-1:
  the batched ragged decode step amortizes dispatch overhead across
  slots instead of serializing lock-stepped streams.
* **per-token latency p50/p99** — derived from each flushed window's
  wall time / steps (the honest async-dispatch semantics; pass
  ``--sync`` for the old block-per-token measurement).
* **upgrade-stall ms** — wall time the serving loop spends applying
  precision upgrades between batched steps (one PlaneStore ingest +
  param refresh per stage), measured in a separate cold-start phase
  that upgrades mid-generation.
* **decode-cache-size** — must be exactly 1 executable per pool across
  all admissions, evictions and N upgrades (asserted).

Emits ``artifacts/bench/BENCH_serving_throughput.json`` — the first
serving datapoint of the bench trajectory.

    PYTHONPATH=src python -m benchmarks.serving_throughput [--quick] [--sync]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import PoolRequest, SlotPoolEngine

OUT_PATH = "artifacts/bench/BENCH_serving_throughput.json"
BATCH_SIZES = (1, 4, 16)
THROUGHPUT_FLOOR_16_VS_1 = 4.0


def _prompt(cfg, i: int, prompt_len: int):
    return jax.random.randint(jax.random.PRNGKey(100 + i), (prompt_len,),
                              0, cfg.vocab).astype(jnp.int32)


def _drain_sync(pool: SlotPoolEngine) -> None:
    """--sync mode: flush after every step (old per-token semantics)."""
    while any(not s.free for s in pool.slots) or pool.queue:
        pool.step()
        pool.flush()
        pool._admit_from_queue()


def bench_pool(model, prog, cfg, *, n_slots: int, decode_steps: int,
               prompt_len: int, dispatch_window: int, sync: bool,
               warmup_steps: int = 8) -> dict:
    pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                          max_len=prompt_len + warmup_steps + decode_steps,
                          dispatch_window=dispatch_window)
    for _ in range(prog.n_stages):
        pool.receive_stage()
    for i in range(n_slots):
        pool.submit(PoolRequest(rid=i, prompt=_prompt(cfg, i, prompt_len),
                                max_new_tokens=warmup_steps + decode_steps))
    for _ in range(warmup_steps):          # compile + warm caches
        pool.step()
    pool.flush()
    pool.window_stats.clear()
    if sync:
        _drain_sync(pool)
    else:
        pool.run()
    assert pool.decode_cache_size() == 1, \
        "slot pool must keep exactly one decode executable"
    wall = sum(w.wall_s for w in pool.window_stats)
    tokens = sum(w.tokens_emitted for w in pool.window_stats)
    per_token = np.concatenate([
        np.full(w.steps, w.wall_s / w.steps) for w in pool.window_stats])
    return {
        "n_slots": n_slots,
        "tokens": int(tokens),
        "wall_s": wall,
        "tokens_per_s": tokens / wall if wall else 0.0,
        "per_token_p50_ms": float(np.percentile(per_token, 50) * 1e3),
        "per_token_p99_ms": float(np.percentile(per_token, 99) * 1e3),
        "decode_cache_size": pool.decode_cache_size(),
        "windows": len(pool.window_stats),
    }


def bench_upgrade_stall(model, prog, cfg, *, n_slots: int, prompt_len: int,
                        dispatch_window: int) -> dict:
    """Cold-start at stage 1, upgrade between windows while the pool is
    saturated; report how long dispatch stalled on upgrades."""
    steps = 2 * prog.n_stages * dispatch_window
    pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                          max_len=prompt_len + steps,
                          dispatch_window=dispatch_window)
    pool.receive_stage()
    for i in range(n_slots):
        pool.submit(PoolRequest(rid=i, prompt=_prompt(cfg, i, prompt_len),
                                max_new_tokens=steps))
    pool.run(on_window=lambda _: pool.upgrade_if_available())
    assert pool.stage == prog.n_stages
    assert pool.decode_cache_size() == 1, \
        "upgrades must not recompile the pool's decode executable"
    return {
        "n_slots": n_slots,
        "n_upgrades": len(pool.upgrades),
        "upgrade_stall_ms_total": pool.upgrade_stall_s * 1e3,
        "upgrade_stall_ms_mean": (pool.upgrade_stall_s * 1e3
                                  / max(len(pool.upgrades), 1)),
        "decode_cache_size": pool.decode_cache_size(),
    }


def bench(arch: str = "olmo-1b", *, decode_steps: int = 40,
          prompt_len: int = 8, dispatch_window: int = 8,
          sync: bool = False) -> dict:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)
    t0 = time.time()
    rows = [bench_pool(model, prog, cfg, n_slots=b,
                       decode_steps=decode_steps, prompt_len=prompt_len,
                       dispatch_window=dispatch_window, sync=sync)
            for b in BATCH_SIZES]
    stall = bench_upgrade_stall(model, prog, cfg, n_slots=BATCH_SIZES[-1],
                                prompt_len=prompt_len,
                                dispatch_window=dispatch_window)
    return {
        "bench": "serving_throughput",
        "arch": arch,
        "backend": jax.default_backend(),
        "mode": "sync" if sync else "async",
        "dispatch_window": dispatch_window,
        "decode_steps": decode_steps,
        "batches": rows,
        "upgrade_stall": stall,
        "total_bench_s": time.time() - t0,
    }


def main(quick: bool = False, out: str = OUT_PATH,
         sync: bool = False) -> None:
    result = bench(decode_steps=16 if quick else 40, sync=sync)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print(f"\n== serving throughput: slot pool ({result['arch']}, "
          f"{result['mode']} dispatch) ==")
    print(f"{'slots':>6} {'tok/s':>10} {'p50 ms':>8} {'p99 ms':>8} "
          f"{'execs':>6}")
    for r in result["batches"]:
        print(f"{r['n_slots']:6d} {r['tokens_per_s']:10,.0f} "
              f"{r['per_token_p50_ms']:8.2f} {r['per_token_p99_ms']:8.2f} "
              f"{r['decode_cache_size']:6d}")
    st = result["upgrade_stall"]
    print(f"upgrade stall: {st['n_upgrades']} upgrades, "
          f"{st['upgrade_stall_ms_mean']:.1f} ms mean "
          f"({st['upgrade_stall_ms_total']:.1f} ms total) at "
          f"{st['n_slots']} slots; executables: {st['decode_cache_size']}")
    by_slots = {r["n_slots"]: r["tokens_per_s"] for r in result["batches"]}
    ratio = by_slots[16] / max(by_slots[1], 1e-9)
    print(f"batch-16 / batch-1 aggregate throughput: {ratio:.2f}x "
          f"(floor {THROUGHPUT_FLOOR_16_VS_1:.0f}x)")
    assert ratio >= THROUGHPUT_FLOOR_16_VS_1, (
        f"continuous batching regressed: batch-16 is only {ratio:.2f}x "
        f"batch-1 aggregate tokens/s (floor {THROUGHPUT_FLOOR_16_VS_1}x)")
    print(f"-> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sync", action="store_true",
                    help="block per token (old timing semantics; "
                         "comparable to pre-ISSUE-4 numbers)")
    args = ap.parse_args()
    main(quick=args.quick, sync=args.sync)
