"""Continuous-batching serving throughput: the slot pool under load.

Measures, per pool size (batch 1 / 4 / 16), the quantities the
ISSUE-4 continuous-batching refactor is about:

* **aggregate tokens/s** — total tokens emitted over honest wall-clock
  across flushed dispatch windows (warm executable; compile excluded).
  The acceptance floor on the reduced config is batch-16 >= 4x batch-1:
  the batched ragged decode step amortizes dispatch overhead across
  slots instead of serializing lock-stepped streams.
* **per-token latency p50/p99** — derived from each flushed window's
  wall time / steps (the honest async-dispatch semantics; pass
  ``--sync`` for the old block-per-token measurement).
* **upgrade-stall ms** — host wall time the serving loop spends on
  precision upgrades between batched steps, with the default
  double-buffered (enqueue-only, zero-stall) path and with the legacy
  ``block_until_ready`` fence, side by side. Acceptance: mean
  double-buffered stall < 5 ms at the largest pool (asserted).
* **flash-crowd TTFT p50/p99** — staggered admissions with DISTINCT
  prompt lengths under chunked admission vs the pre-ISSUE-6 batch-1
  baseline (which pays one prefill compile per novel length).
  Acceptance: chunked TTFT p99 >= 5x better (asserted).
* **token identity per stage** — chunked and batch-1 admission emit
  identical streams at every precision stage (asserted).
* **decode-cache-size** — must be exactly 1 executable per pool across
  all admissions, evictions and N upgrades (asserted).

Emits ``artifacts/bench/BENCH_serving_throughput.json``.

    PYTHONPATH=src python -m benchmarks.serving_throughput \
        [--quick | --reduced] [--sync]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import PoolRequest, SlotPoolEngine

OUT_PATH = "artifacts/bench/BENCH_serving_throughput.json"
BATCH_SIZES = (1, 4, 16)
THROUGHPUT_FLOOR_16_VS_1 = 4.0
UPGRADE_STALL_CEIL_MS = 5.0
# loaded hosts (CI runners, forced multi-device CPU) inflate absolute
# enqueue times; the enqueue-only claim then falls back to a relative
# guard against the fenced A/B measured in the same run
STALL_VS_FENCED_FLOOR = 4.0
TTFT_P99_FLOOR = 5.0


def _prompt(cfg, i: int, prompt_len: int):
    return jax.random.randint(jax.random.PRNGKey(100 + i), (prompt_len,),
                              0, cfg.vocab).astype(jnp.int32)


def _drain_sync(pool: SlotPoolEngine) -> None:
    """--sync mode: flush after every step (old per-token semantics)."""
    while any(not s.free for s in pool.slots) or pool.queue:
        pool.step()
        pool.flush()
        pool._admit_from_queue()


def bench_pool(model, prog, cfg, *, n_slots: int, decode_steps: int,
               prompt_len: int, dispatch_window: int, sync: bool,
               warmup_steps: int = 8) -> dict:
    pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                          max_len=prompt_len + warmup_steps + decode_steps,
                          dispatch_window=dispatch_window)
    for _ in range(prog.n_stages):
        pool.receive_stage()
    for i in range(n_slots):
        pool.submit(PoolRequest(rid=i, prompt=_prompt(cfg, i, prompt_len),
                                max_new_tokens=warmup_steps + decode_steps))
    for _ in range(warmup_steps):          # compile + warm caches
        pool.step()
    pool.flush()
    pool.window_stats.clear()
    if sync:
        _drain_sync(pool)
    else:
        pool.run()
    assert pool.decode_cache_size() == 1, \
        "slot pool must keep exactly one decode executable"
    wall = sum(w.wall_s for w in pool.window_stats)
    tokens = sum(w.tokens_emitted for w in pool.window_stats)
    per_token = np.concatenate([
        np.full(w.steps, w.wall_s / w.steps) for w in pool.window_stats])
    return {
        "n_slots": n_slots,
        "tokens": int(tokens),
        "wall_s": wall,
        "tokens_per_s": tokens / wall if wall else 0.0,
        "per_token_p50_ms": float(np.percentile(per_token, 50) * 1e3),
        "per_token_p99_ms": float(np.percentile(per_token, 99) * 1e3),
        "decode_cache_size": pool.decode_cache_size(),
        "windows": len(pool.window_stats),
    }


def bench_upgrade_stall(model, prog, cfg, *, n_slots: int, prompt_len: int,
                        dispatch_window: int,
                        double_buffer: bool = True) -> dict:
    """Cold-start at stage 1, upgrade between windows while the pool is
    saturated; report how long dispatch stalled on upgrades.
    ``double_buffer=False`` restores the legacy ``block_until_ready``
    fence after each upgrade, for the A/B stall column."""
    steps = 2 * prog.n_stages * dispatch_window
    pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                          max_len=prompt_len + steps,
                          dispatch_window=dispatch_window,
                          double_buffer=double_buffer)
    pool.receive_stage()
    for i in range(n_slots):
        pool.submit(PoolRequest(rid=i, prompt=_prompt(cfg, i, prompt_len),
                                max_new_tokens=steps))
    pool.run(on_window=lambda _: pool.upgrade_if_available())
    assert pool.stage == prog.n_stages
    assert pool.decode_cache_size() == 1, \
        "upgrades must not recompile the pool's decode executable"
    n_up = max(len(pool.upgrades), 1)
    return {
        "n_slots": n_slots,
        "double_buffer": double_buffer,
        "n_upgrades": len(pool.upgrades),
        "upgrade_stall_ms_total": pool.upgrade_stall_s * 1e3,
        "upgrade_stall_ms_mean": pool.upgrade_stall_s * 1e3 / n_up,
        "upgrade_enqueue_ms_mean": pool.upgrade_enqueue_s * 1e3 / n_up,
        "decode_cache_size": pool.decode_cache_size(),
    }


def bench_flash_crowd(model, prog, cfg, *, n_clients: int, n_slots: int,
                      decode_steps: int, dispatch_window: int,
                      chunked: bool) -> dict:
    """Staggered admissions with DISTINCT prompt lengths — the flash
    crowd a deployed progressive server faces at a stage boundary.
    TTFT is submit -> first flushed token per client. Both pools are
    warmed with one request first (a deployed server has been serving
    before the crowd hits), so the one-time chunk/decode compiles are
    excluded — what remains is the steady-state admission cost: the
    batch-1 baseline (``chunked=False``, no buckets) still pays one
    prefill compile per NOVEL length at admission time, which is
    exactly what its TTFT tail shows; chunked admission streams every
    length through the same warm (B, chunk) executable."""
    lengths = [5 + 2 * i for i in range(n_clients)]
    pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                          max_len=max(lengths) + decode_steps,
                          dispatch_window=dispatch_window,
                          chunked_prefill=chunked, prefill_buckets=False)
    for _ in range(prog.n_stages):
        pool.receive_stage()
    # warm at a length OUTSIDE the crowd's set: the baseline keeps its
    # per-novel-length compile cost, the shared executables get built
    warm_len = 4
    assert warm_len not in lengths
    pool.submit(PoolRequest(rid=10_000, prompt=_prompt(cfg, 999, warm_len),
                            max_new_tokens=2))
    pool.run()
    backlog = [PoolRequest(rid=i, prompt=_prompt(cfg, 200 + i, lengths[i]),
                           max_new_tokens=decode_steps)
               for i in range(n_clients)]
    t0 = time.time()
    rounds = 0
    while backlog or pool.queue or any(not s.free for s in pool.slots):
        if backlog and rounds % 2 == 0:   # one arrival every other tick
            pool.submit(backlog.pop(0))
        pool.step()
        if len(pool._pending) >= dispatch_window:
            pool.flush()
            pool._admit_from_queue()
        rounds += 1
    pool.flush()
    assert pool.decode_cache_size() == 1
    ttft_ms = np.array([pool.ttft_s[i] for i in range(n_clients)]) * 1e3
    return {
        "mode": "chunked" if chunked else "batch1_baseline",
        "n_clients": n_clients,
        "n_slots": n_slots,
        "ttft_p50_ms": float(np.percentile(ttft_ms, 50)),
        "ttft_p99_ms": float(np.percentile(ttft_ms, 99)),
        "prefill_cache_size": pool.prefill_cache_size(),
        "decode_cache_size": pool.decode_cache_size(),
        "wall_s": time.time() - t0,
    }


def bench_multi_device(model, prog, cfg, *, n_slots: int, decode_steps: int,
                       prompt_len: int, dispatch_window: int) -> dict | None:
    """Sharded serving row (PR-7): the same slot pool decoding through
    a model-axis serving mesh — ShardedPlaneStore shard-local ingest,
    quantized residency over sharded accumulators, enqueue-only
    upgrades. Gated on device count (CI forces 8 host devices via
    XLA_FLAGS); reports aggregate throughput plus the exit-criterion
    check that the sharded pool's streams equal single-device exactly
    across mid-flight upgrades."""
    n_dev = jax.device_count()
    if n_dev < 2:
        return None
    from repro.launch.mesh import make_serving_mesh

    n_model = 4 if n_dev >= 4 else 2
    mesh = make_serving_mesh(n_model)
    # enough dispatch windows for every stage to land mid-generation
    # (upgrade_if_available advances one stage per window callback)
    steps = max(decode_steps, 2 * prog.n_stages * dispatch_window)
    streams: dict[bool, dict] = {}
    row: dict = {}
    for m in (None, mesh):
        pool = SlotPoolEngine(model, prog, n_slots=n_slots,
                              max_len=prompt_len + steps,
                              dispatch_window=dispatch_window,
                              resident="quantized", mesh=m)
        pool.receive_stage()
        for i in range(n_slots):
            pool.submit(PoolRequest(rid=i, prompt=_prompt(cfg, i, prompt_len),
                                    max_new_tokens=steps))
        t0 = time.time()
        out = pool.run(on_window=lambda _: pool.upgrade_if_available())
        wall = time.time() - t0
        streams[m is not None] = out
        if m is not None:
            assert pool.stage == prog.n_stages
            assert pool.decode_cache_size() == 1, \
                "sharded upgrades must not recompile the decode step"
            # double_buffer=True semantics: every sharded upgrade is an
            # enqueue — no block_until_ready fence anywhere in the log
            assert pool.double_buffer
            assert all(e["double_buffer"] and e["sharded"]
                       for e in pool.upgrade_log), \
                "sharded upgrades must run double-buffered (enqueue-only)"
            n_up = max(len(pool.upgrades), 1)
            row = {
                "n_devices": n_dev,
                "n_model_shards": n_model,
                "n_slots": n_slots,
                "tokens": sum(len(v) for v in out.values()),
                "wall_s": wall,
                "tokens_per_s": sum(len(v) for v in out.values()) / wall,
                "n_upgrades": len(pool.upgrades),
                "upgrade_stall_ms_mean": pool.upgrade_stall_s * 1e3 / n_up,
                "upgrade_enqueue_ms_mean":
                    pool.upgrade_enqueue_s * 1e3 / n_up,
                "upgrade_fence_ms_mean":
                    (pool.upgrade_stall_s - pool.upgrade_enqueue_s)
                    * 1e3 / n_up,
                "upgrade_ingest_ms_mean": sum(
                    e["ingest_s"] for e in pool.upgrade_log) * 1e3 / n_up,
                "upgrade_refresh_ms_mean": sum(
                    e["refresh_s"] for e in pool.upgrade_log) * 1e3 / n_up,
                "double_buffer": True,
                "decode_cache_size": pool.decode_cache_size(),
            }
    row["token_identical_to_single_device"] = streams[True] == streams[False]
    assert row["token_identical_to_single_device"], \
        "sharded pool diverged from the single-device stream"
    return row


def check_stage_identity(model, prog, cfg) -> dict:
    """Chunked admission must emit the batch-1 pool's exact stream at
    EVERY precision stage (the per-stage parity half of the ISSUE-6
    acceptance, asserted here against the bench config)."""
    steps = 4
    prompts = [_prompt(cfg, 300 + i, L) for i, L in enumerate((5, 9, 3))]
    for stage in range(1, prog.n_stages + 1):
        outs = {}
        for chunked in (False, True):
            pool = SlotPoolEngine(model, prog, n_slots=2,
                                  max_len=9 + steps, dispatch_window=2,
                                  chunked_prefill=chunked,
                                  prefill_buckets=False)
            for _ in range(stage):
                pool.receive_stage()
            for i, p in enumerate(prompts):
                pool.submit(PoolRequest(rid=i, prompt=p,
                                        max_new_tokens=steps))
            outs[chunked] = pool.run()
        assert outs[True] == outs[False], \
            f"chunked admission diverged from batch-1 at stage {stage}"
    return {"stages_checked": prog.n_stages, "token_identical": True}


def bench(arch: str = "olmo-1b", *, decode_steps: int = 40,
          prompt_len: int = 8, dispatch_window: int = 8,
          sync: bool = False) -> dict:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)
    t0 = time.time()
    rows = [bench_pool(model, prog, cfg, n_slots=b,
                       decode_steps=decode_steps, prompt_len=prompt_len,
                       dispatch_window=dispatch_window, sync=sync)
            for b in BATCH_SIZES]
    stall = bench_upgrade_stall(model, prog, cfg, n_slots=BATCH_SIZES[-1],
                                prompt_len=prompt_len,
                                dispatch_window=dispatch_window,
                                double_buffer=True)
    stall_fenced = bench_upgrade_stall(model, prog, cfg,
                                       n_slots=BATCH_SIZES[-1],
                                       prompt_len=prompt_len,
                                       dispatch_window=dispatch_window,
                                       double_buffer=False)
    crowd = {}
    for chunked in (True, False):
        r = bench_flash_crowd(model, prog, cfg, n_clients=BATCH_SIZES[-1],
                              n_slots=BATCH_SIZES[-1],
                              decode_steps=decode_steps,
                              dispatch_window=dispatch_window,
                              chunked=chunked)
        crowd[r["mode"]] = r
    crowd["ttft_p99_speedup"] = (crowd["batch1_baseline"]["ttft_p99_ms"]
                                 / max(crowd["chunked"]["ttft_p99_ms"], 1e-9))
    identity = check_stage_identity(model, prog, cfg)
    multi = bench_multi_device(model, prog, cfg, n_slots=4,
                               decode_steps=decode_steps,
                               prompt_len=prompt_len,
                               dispatch_window=dispatch_window)
    return {
        "bench": "serving_throughput",
        "arch": arch,
        "backend": jax.default_backend(),
        "mode": "sync" if sync else "async",
        "dispatch_window": dispatch_window,
        "decode_steps": decode_steps,
        "batches": rows,
        "upgrade_stall": stall,
        "upgrade_stall_fenced": stall_fenced,
        "flash_crowd": crowd,
        "stage_identity": identity,
        "multi_device": multi,
        "total_bench_s": time.time() - t0,
    }


def main(quick: bool = False, out: str = OUT_PATH,
         sync: bool = False) -> None:
    result = bench(decode_steps=16 if quick else 40, sync=sync)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print(f"\n== serving throughput: slot pool ({result['arch']}, "
          f"{result['mode']} dispatch) ==")
    print(f"{'slots':>6} {'tok/s':>10} {'p50 ms':>8} {'p99 ms':>8} "
          f"{'execs':>6}")
    for r in result["batches"]:
        print(f"{r['n_slots']:6d} {r['tokens_per_s']:10,.0f} "
              f"{r['per_token_p50_ms']:8.2f} {r['per_token_p99_ms']:8.2f} "
              f"{r['decode_cache_size']:6d}")
    st, stf = result["upgrade_stall"], result["upgrade_stall_fenced"]
    print(f"upgrade stall at {st['n_slots']} slots, {st['n_upgrades']} "
          f"upgrades: double-buffered {st['upgrade_stall_ms_mean']:.2f} ms "
          f"mean vs fenced {stf['upgrade_stall_ms_mean']:.2f} ms mean")
    fc = result["flash_crowd"]
    print(f"{'flash crowd':>12} {'TTFT p50':>10} {'TTFT p99':>10} "
          f"{'prefill execs':>14}")
    for key in ("chunked", "batch1_baseline"):
        r = fc[key]
        print(f"{key:>12.12} {r['ttft_p50_ms']:9.1f}ms "
              f"{r['ttft_p99_ms']:9.1f}ms {r['prefill_cache_size']:14d}")
    print(f"chunked TTFT p99 speedup: {fc['ttft_p99_speedup']:.1f}x "
          f"(floor {TTFT_P99_FLOOR:.0f}x); token-identical across "
          f"{result['stage_identity']['stages_checked']} stages")
    md = result["multi_device"]
    if md is None:
        print("multi-device row: skipped (1 device; CI forces 8 via "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    else:
        print(f"multi-device row: {md['n_model_shards']}-way model axis on "
              f"{md['n_devices']} devices, {md['tokens_per_s']:,.0f} tok/s "
              f"at {md['n_slots']} slots, {md['n_upgrades']} upgrades "
              f"({md['upgrade_stall_ms_mean']:.2f} ms mean stall), "
              f"token-identical to single-device: "
              f"{md['token_identical_to_single_device']}")
    by_slots = {r["n_slots"]: r["tokens_per_s"] for r in result["batches"]}
    ratio = by_slots[16] / max(by_slots[1], 1e-9)
    print(f"batch-16 / batch-1 aggregate throughput: {ratio:.2f}x "
          f"(floor {THROUGHPUT_FLOOR_16_VS_1:.0f}x)")
    assert ratio >= THROUGHPUT_FLOOR_16_VS_1, (
        f"continuous batching regressed: batch-16 is only {ratio:.2f}x "
        f"batch-1 aggregate tokens/s (floor {THROUGHPUT_FLOOR_16_VS_1}x)")
    stall_ceil = max(UPGRADE_STALL_CEIL_MS,
                     stf["upgrade_stall_ms_mean"] / STALL_VS_FENCED_FLOOR)
    assert st["upgrade_stall_ms_mean"] < stall_ceil, (
        f"double-buffered upgrades must not stall dispatch: mean "
        f"{st['upgrade_stall_ms_mean']:.2f} ms >= {stall_ceil:.2f} ms "
        f"(abs ceiling {UPGRADE_STALL_CEIL_MS} ms or "
        f"{STALL_VS_FENCED_FLOOR:.0f}x under the fenced "
        f"{stf['upgrade_stall_ms_mean']:.2f} ms)")
    assert fc["ttft_p99_speedup"] >= TTFT_P99_FLOOR, (
        f"chunked admission TTFT p99 is only "
        f"{fc['ttft_p99_speedup']:.2f}x the batch-1 baseline "
        f"(floor {TTFT_P99_FLOOR}x)")
    print(f"-> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="alias for --quick (CI tier-2 naming)")
    ap.add_argument("--sync", action="store_true",
                    help="block per token (old timing semantics; "
                         "comparable to pre-ISSUE-4 numbers)")
    args = ap.parse_args()
    main(quick=args.quick or args.reduced, sync=args.sync)
