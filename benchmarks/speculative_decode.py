"""Self-speculative decoding benchmark: the precision ladder as a
throughput multiplier.

Sweeps draft-bits x draft-length over every precision stage and
records, per (draft_bits, k, stage):

* **tokens/s** — emitted tokens over the speculative engine's honest
  wall clock (sync-per-round: the host observes each round's accepted
  tokens as they land, the speculative analogue of the plain path's
  block-per-token serving measurement).
* **acceptance rate** — accepted drafts / proposed drafts. This is the
  paper-shaped curve: while the download hasn't passed ``draft_bits``
  the views coincide (k collapses to 0, plain decode); once the target
  pulls ahead the rate tracks how well the coarse bit-plane model
  predicts the refined one.
* **decode executables** — must be exactly 2 per fixed-k engine (ONE
  draft ``decode_step`` + ONE target ``verify_step``) across every
  stage upgrade: speculation never recompiles mid-ladder.

The acceptance floor compares the best speculative config against
plain greedy at the final stage, both quantized-resident and both in
the per-token-observation serving mode (``sync=True`` — the same
semantics ``benchmarks/resident_serving.py`` reports): speculative
must clear **1.3x**. The async-window plain number is recorded
alongside for context, not asserted: on this CPU container the draft
pass reads the same container bytes as the target (zero extra weight
memory is the point), so the speculative win here comes from verify
batching + round-level sync amortization; on a real TPU the verify
kernel additionally amortizes the whole KV-cache HBM sweep over the
k+1 draft rows.

Emits ``artifacts/bench/BENCH_speculative.json``.

    PYTHONPATH=src python -m benchmarks.speculative_decode [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import ProgressiveServer
from repro.serving.speculative import SpecConfig, SpeculativeEngine

OUT_PATH = "artifacts/bench/BENCH_speculative.json"
DRAFT_BITS = (2, 4)
DRAFT_K = (2, 4, 8)
SPEEDUP_FLOOR = 1.3


def _batch(cfg, batch: int, prompt_len: int):
    return {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)}


def bench_plain(model, prog, cfg, *, steps: int, prompt_len: int,
                max_len: int) -> dict:
    """Plain greedy, quantized-resident, at the final stage — measured
    both block-per-token (the floor's baseline) and async-windowed."""
    srv = ProgressiveServer(model, prog, max_len=max_len,
                            resident="quantized")
    for _ in range(prog.n_stages):
        srv.receive_stage()
    batch = _batch(cfg, 2, prompt_len)
    srv.start(batch)
    srv.decode(2, sync=True)          # compile + warm
    srv.start(batch)
    res = srv.decode(steps, sync=True)
    sync_wall = sum(res.per_step_s)
    srv.start(batch)
    res_a = srv.decode(steps, dispatch_window=8)
    tokens_ref = np.asarray(res.tokens)
    return {
        "sync_tokens_per_s": steps / sync_wall,
        "sync_per_token_ms": sync_wall / steps * 1e3,
        "async_tokens_per_s": steps / max(res_a.tpot_s * steps, 1e-12),
        "tokens": tokens_ref,
    }


def bench_spec(model, prog, cfg, *, draft_bits: int, k: int, steps: int,
               prompt_len: int, max_len: int, stages) -> list[dict]:
    """One engine per (draft_bits, k); stages applied incrementally so
    every upgrade exercises the zero-recompile invariant of the SAME
    two executables."""
    spec = SpecConfig(draft_bits=draft_bits, k=k, k_max=max(DRAFT_K))
    eng = SpeculativeEngine(model, prog, max_len=max_len, spec=spec)
    batch = _batch(cfg, 2, prompt_len)
    rows = []
    warmed = set()
    for s in range(1, prog.n_stages + 1):
        eng.receive_stage()
        if s not in stages:
            continue
        gap = eng.received_bits_now() > draft_bits
        if gap not in warmed:          # one compile per round shape
            eng.start(batch)
            eng.decode(min(steps, 2 * (k + 1)))
            warmed.add(gap)
        eng.start(batch)
        t0 = time.perf_counter()
        res = eng.decode(steps)
        wall = time.perf_counter() - t0
        rows.append({
            "draft_bits": draft_bits, "k": k, "stage": s,
            "target_bits": eng.received_bits_now(),
            "tokens_per_s": 2 * steps / wall,   # 2 slots, steps each
            "per_token_ms": wall / (2 * steps) * 1e3,
            "acceptance_rate": res.acceptance_rate,
            "rounds": res.rounds,
            "drafted": res.drafted,
            "accepted": res.accepted,
            "decode_executables": eng.decode_cache_size(),
            "extra_draft_bytes": eng.resident_report()["extra_draft_bytes"],
            "tokens": np.asarray(res.tokens),
        })
    # a fixed-k engine compiles exactly one draft decode_step + one
    # verify_step... plus the degenerate k=0 verify when stages below
    # draft_bits were measured. The invariant asserted: once the gap is
    # open, every later stage reuses the same two executables.
    return rows


def bench(arch: str = "olmo-1b", *, steps: int = 32, prompt_len: int = 8,
          quick: bool = False) -> dict:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)
    max_len = prompt_len + steps + max(DRAFT_K) + 1
    stages = ((1, prog.n_stages // 2, prog.n_stages) if quick
              else tuple(range(1, prog.n_stages + 1)))

    t0 = time.time()
    plain = bench_plain(model, prog, cfg, steps=steps,
                        prompt_len=prompt_len, max_len=max_len)
    rows = []
    for db in DRAFT_BITS:
        for k in DRAFT_K:
            rows.extend(bench_spec(model, prog, cfg, draft_bits=db, k=k,
                                   steps=steps, prompt_len=prompt_len,
                                   max_len=max_len, stages=stages))

    # losslessness spot-check: every final-stage config emitted exactly
    # the plain greedy stream
    finals = [r for r in rows if r["stage"] == prog.n_stages]
    for r in finals:
        np.testing.assert_array_equal(
            r["tokens"], plain["tokens"],
            err_msg=f"speculative tokens diverged at draft_bits="
                    f"{r['draft_bits']} k={r['k']}")
    for r in rows:
        r["tokens"] = None  # not JSON material
    plain_tokens = plain.pop("tokens")
    del plain_tokens

    best = max(finals, key=lambda r: r["tokens_per_s"])
    speedup = best["tokens_per_s"] / plain["sync_tokens_per_s"]
    return {
        "bench": "speculative_decode",
        "arch": arch,
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "steps": steps,
        "plain": plain,
        "sweep": rows,
        "best_final_stage": {k: v for k, v in best.items() if k != "tokens"},
        "speedup_vs_plain_sync": speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "total_bench_s": time.time() - t0,
    }


def main(quick: bool = False, out: str = OUT_PATH) -> None:
    result = bench(steps=16 if quick else 32, quick=quick)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    print(f"\n== self-speculative decode ({result['arch']}, "
          f"{result['backend']}) ==")
    print(f"plain greedy (quantized, per-token sync): "
          f"{result['plain']['sync_tokens_per_s']:8.1f} tok/s "
          f"({result['plain']['sync_per_token_ms']:.2f} ms/token); "
          f"async-window reference: "
          f"{result['plain']['async_tokens_per_s']:8.1f} tok/s")
    print(f"{'bits':>5} {'k':>3} {'stage':>6} {'tok/s':>9} {'accept':>7} "
          f"{'execs':>6}")
    for r in result["sweep"]:
        print(f"{r['draft_bits']:5d} {r['k']:3d} {r['stage']:6d} "
              f"{r['tokens_per_s']:9.1f} {r['acceptance_rate']:7.2f} "
              f"{r['decode_executables']:6d}")
    best = result["best_final_stage"]
    print(f"best final-stage config: draft_bits={best['draft_bits']} "
          f"k={best['k']} -> {best['tokens_per_s']:.1f} tok/s = "
          f"{result['speedup_vs_plain_sync']:.2f}x plain "
          f"(floor {SPEEDUP_FLOOR}x)")
    assert best["extra_draft_bytes"] == 0, \
        "draft view must add zero resident weight bytes"
    assert best["decode_executables"] == 2, (
        f"a fixed-k speculative engine past the precision gap must hold "
        f"exactly 2 decode executables (draft decode_step + target "
        f"verify_step), got {best['decode_executables']}")
    assert result["speedup_vs_plain_sync"] >= SPEEDUP_FLOOR, (
        f"speculative decode regressed: best final-stage config is only "
        f"{result['speedup_vs_plain_sync']:.2f}x plain greedy "
        f"(floor {SPEEDUP_FLOOR}x)")
    print(f"-> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="alias for --quick (CI convention)")
    args = ap.parse_args()
    main(quick=args.quick or args.reduced)
