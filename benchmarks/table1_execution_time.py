"""Table I reproduction: total execution time, singleton vs progressive
(w/o and w/ concurrent transmission+inference).

The paper measures six CNNs in a browser at 1 MB/s. We measure our model
zoo (reduced variants runnable on this CPU) with *real* serialized plane
sizes and *measured* per-stage client costs, then derive the three
schedules with the Fig.-4 timeline algebra. The claim under test:

    w/ concurrency  : ~0% overhead vs singleton
    w/o concurrency : +20..80% overhead
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission.scheduler import (
    StageCost,
    overhead_pct,
    progressive_timeline,
    singleton_timeline,
)
from repro.transmission.simulator import Link

from benchmarks.common import measure_stage_costs

ARCHS = ["olmo-1b", "xlstm-125m", "minitron-4b", "mixtral-8x22b",
         "seamless-m4t-medium", "gemma3-27b"]
BANDWIDTH = 1e6  # paper setting: 1 MB/s


def bench_cfg(arch: str):
    """Paper-regime variant: big enough that the serialized model is
    several MB (the paper's 7-51 MB at 1 MB/s => download >> per-stage
    processing), small enough to infer on this CPU. The claim under test
    is about that regime; the tiny smoke configs (0.7 MB) sit in the
    opposite regime where processing dominates and even concurrent
    progressive transmission pays (documented in EXPERIMENTS.md)."""
    base = get_config(arch)
    return base.reduced(
        d_model=256,
        n_heads=4,
        n_kv=2,
        d_ff=512 if base.d_ff else 0,
        vocab=min(base.vocab, 16384),
        n_layers=2 * len(base.cycle),
    )


def run(quick: bool = False) -> list[dict]:
    rows = []
    archs = ARCHS[:3] if quick else ARCHS
    for arch in archs:
        cfg = bench_cfg(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prog = divide(params)

        batch = {"tokens": jnp.zeros((1, 32), jnp.int32)}
        if cfg.enc_layers:
            batch["enc_input"] = jnp.zeros((1, 8, cfg.d_model), cfg.dtype)
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (1, cfg.vision_tokens, cfg.d_vision), cfg.dtype)

        fwd = jax.jit(lambda p: model.forward(p, batch)[0])
        costs = measure_stage_costs(prog, fwd)

        hdr = len(wire.encode_header(prog))
        stage_bytes = [len(wire.encode_stage(prog, s))
                       for s in range(1, prog.n_stages + 1)]
        total_bytes = hdr + sum(stage_bytes)
        link = Link(bandwidth_bytes_per_s=BANDWIDTH)

        # singleton pays one concat+dequant+inference at the end
        single = singleton_timeline(total_bytes, link, costs[-1])
        prog_noc = progressive_timeline(stage_bytes, link, costs,
                                        concurrent=False, header_bytes=hdr)
        prog_con = progressive_timeline(stage_bytes, link, costs,
                                        concurrent=True, header_bytes=hdr)
        rows.append({
            "arch": arch,
            "bytes": total_bytes,
            "singleton_s": single.total_s,
            "prog_wo_concurrent_s": prog_noc.total_s,
            "wo_overhead_pct": overhead_pct(prog_noc, single),
            "prog_w_concurrent_s": prog_con.total_s,
            "w_overhead_pct": overhead_pct(prog_con, single),
            "first_result_s": prog_con.first_result_s,
        })
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick)
    print("\n== Table 1: total execution time (1 MB/s link) ==")
    print(f"{'arch':22s} {'size':>9s} {'single':>8s} {'prog w/o':>9s} "
          f"{'(+%)':>7s} {'prog w/':>8s} {'(+%)':>7s} {'1st result':>10s}")
    for r in rows:
        print(f"{r['arch']:22s} {r['bytes']/1e6:7.2f}MB "
              f"{r['singleton_s']:7.2f}s {r['prog_wo_concurrent_s']:8.2f}s "
              f"{r['wo_overhead_pct']:+6.1f}% {r['prog_w_concurrent_s']:7.2f}s "
              f"{r['w_overhead_pct']:+6.1f}% {r['first_result_s']:9.2f}s")


if __name__ == "__main__":
    main()
