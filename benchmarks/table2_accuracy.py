"""Table II reproduction: accuracy of intermediate models vs bit-width.

The paper converts pre-trained CNNs and reports ImageNet top-1 / COCO
boxAP at 2..16 received bits: garbage at <=4 bits, recovery by 8-10,
exact singleton match at 16. We reproduce the curve shape with:

  (a) the paper-family CNN (progressivenet-cnn) on a synthetic
      10-class image task, and
  (b) a small LM (olmo-1b reduced) on the Markov-motif stream,

both *trained here* then converted with the same divide/receive
pipeline (no quantization-aware training — matching the paper's
"just convert the pre-trained model" setting). Metrics: task accuracy
at each stage + top-1 agreement with the fp32 model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.progressivenet_cnn import cnn_apply, cnn_init
from repro.core.progressive import divide, ReceiverState
from repro.models.model import build_model
from repro.train import optimizer as opt
from repro.train.data import DataConfig, MarkovMotifDataset
from repro.train.loop import train

STAGE_BITS = [2, 4, 6, 8, 10, 12, 14, 16]


# -- synthetic image task ----------------------------------------------------

_TEMPLATES = jax.random.normal(jax.random.PRNGKey(42), (10, 16, 16, 3))


def make_image_data(key, n, noise=1.25):
    """Each class is a FIXED random template (shared by train and test);
    inputs are noisy copies."""
    kn, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, _TEMPLATES.shape[0])
    x = _TEMPLATES[labels] + noise * jax.random.normal(kn, (n, 16, 16, 3))
    return x, labels


def train_cnn(key, steps=300, batch=64):
    params = cnn_init(key, channels=(8, 16, 32), n_classes=10)
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                         weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            logits = cnn_apply(p, x)
            onehot = jax.nn.one_hot(y, 10)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(ocfg, grads, opt_state, params)
        return params, opt_state, loss

    for i in range(steps):
        x, y = make_image_data(jax.random.fold_in(key, i), batch)
        params, opt_state, loss = step(params, opt_state, x, y)
    return params


def accuracy_curve_cnn(quick: bool = False) -> dict:
    key = jax.random.PRNGKey(0)
    params = train_cnn(key, steps=100 if quick else 300)
    x_test, y_test = make_image_data(jax.random.PRNGKey(999), 512)

    @jax.jit
    def acc_fn(p):
        pred = jnp.argmax(cnn_apply(p, x_test), -1)
        return jnp.mean((pred == y_test).astype(jnp.float32))

    full_pred = jnp.argmax(cnn_apply(params, x_test), -1)
    prog = divide(params)
    st = ReceiverState.init(prog)
    curve, agree = [], []
    for s in range(1, prog.n_stages + 1):
        st = st.receive(prog.stage(s))
        approx = st.materialize()
        curve.append(float(acc_fn(approx)))
        pred = jnp.argmax(cnn_apply(approx, x_test), -1)
        agree.append(float(jnp.mean((pred == full_pred).astype(jnp.float32))))
    return {"model": "progressivenet-cnn", "orig": float(acc_fn(params)),
            "bits": STAGE_BITS, "accuracy": curve, "top1_agreement": agree}


# -- small LM ------------------------------------------------------------------

def accuracy_curve_lm(quick: bool = False) -> dict:
    cfg = get_config("olmo-1b").reduced(n_layers=2, d_model=128, d_ff=256,
                                        vocab=64, n_heads=4, n_kv=4)
    model = build_model(cfg)
    steps = 60 if quick else 150
    res = train(model, steps=steps,
                data_cfg=DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16),
                opt_cfg=opt.OptConfig(lr=1e-2, warmup_steps=20, total_steps=steps),
                log_every=steps)
    params = res.params

    # same stream structure (seed fixes transitions/motifs), held-out step
    ds = MarkovMotifDataset(DataConfig(vocab=cfg.vocab, seq_len=64,
                                       global_batch=64, seed=0))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(100_000).items()}

    @jax.jit
    def eval_fn(p):
        logits, _ = model.forward(p, batch)
        pred = jnp.argmax(logits, -1)
        return pred, jnp.mean((pred == batch["labels"]).astype(jnp.float32))

    full_pred, orig_acc = eval_fn(params)
    prog = divide(params)
    st = ReceiverState.init(prog)
    curve, agree = [], []
    for s in range(1, prog.n_stages + 1):
        st = st.receive(prog.stage(s))
        pred, acc = eval_fn(st.materialize())
        curve.append(float(acc))
        agree.append(float(jnp.mean((pred == full_pred).astype(jnp.float32))))
    return {"model": "olmo-1b (reduced, trained)", "orig": float(orig_acc),
            "bits": STAGE_BITS, "accuracy": curve, "top1_agreement": agree}


def run(quick: bool = False) -> list[dict]:
    return [accuracy_curve_cnn(quick), accuracy_curve_lm(quick)]


def main(quick: bool = False) -> None:
    rows = run(quick)
    print("\n== Table 2: accuracy vs received bit-width ==")
    hdr = "model".ljust(28) + "".join(f"{b:>7d}" for b in STAGE_BITS) + "   orig"
    print(hdr)
    for r in rows:
        print(r["model"].ljust(28)
              + "".join(f"{a:7.3f}" for a in r["accuracy"])
              + f"  {r['orig']:.3f}")
        print("  (top-1 agreement)".ljust(28)
              + "".join(f"{a:7.3f}" for a in r["top1_agreement"]))
        assert abs(r["accuracy"][-1] - r["orig"]) < 0.02, \
            "16-bit stage must match the original model"


if __name__ == "__main__":
    main()
