"""Table II reproduction: accuracy of intermediate models vs bit-width.

The paper converts pre-trained CNNs and reports ImageNet top-1 / COCO
boxAP at 2..16 received bits: garbage at <=4 bits, recovery by 8-10,
exact singleton match at 16. We reproduce the curve shape with:

  (a) the paper-family CNN (progressivenet-cnn) on a synthetic
      10-class image task, and
  (b) a small LM (olmo-1b reduced) on the Markov-motif stream,

both *trained here* then converted with the same divide/receive
pipeline (no quantization-aware training — matching the paper's
"just convert the pre-trained model" setting). Metrics: task accuracy
at each stage + top-1 agreement with the fp32 model.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.progressivenet_cnn import cnn_apply, cnn_init
from repro.core import wire
from repro.core.calibrate import calibrate_schedule
from repro.core.progressive import divide, rebuild_params, ReceiverState
from repro.models.model import build_model
from repro.train import optimizer as opt
from repro.train.data import DataConfig, MarkovMotifDataset
from repro.train.loop import train
from repro.transmission.client import ProgressiveClient

STAGE_BITS = [2, 4, 6, 8, 10, 12, 14, 16]


# -- synthetic image task ----------------------------------------------------

_TEMPLATES = jax.random.normal(jax.random.PRNGKey(42), (10, 16, 16, 3))


def make_image_data(key, n, noise=1.25):
    """Each class is a FIXED random template (shared by train and test);
    inputs are noisy copies."""
    kn, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, _TEMPLATES.shape[0])
    x = _TEMPLATES[labels] + noise * jax.random.normal(kn, (n, 16, 16, 3))
    return x, labels


def train_cnn(key, steps=300, batch=64):
    params = cnn_init(key, channels=(8, 16, 32), n_classes=10)
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                         weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            logits = cnn_apply(p, x)
            onehot = jax.nn.one_hot(y, 10)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(ocfg, grads, opt_state, params)
        return params, opt_state, loss

    for i in range(steps):
        x, y = make_image_data(jax.random.fold_in(key, i), batch)
        params, opt_state, loss = step(params, opt_state, x, y)
    return params


def accuracy_curve_cnn(quick: bool = False) -> dict:
    key = jax.random.PRNGKey(0)
    params = train_cnn(key, steps=100 if quick else 300)
    x_test, y_test = make_image_data(jax.random.PRNGKey(999), 512)

    @jax.jit
    def acc_fn(p):
        pred = jnp.argmax(cnn_apply(p, x_test), -1)
        return jnp.mean((pred == y_test).astype(jnp.float32))

    full_pred = jnp.argmax(cnn_apply(params, x_test), -1)
    prog = divide(params)
    st = ReceiverState.init(prog)
    curve, agree = [], []
    for s in range(1, prog.n_stages + 1):
        st = st.receive(prog.stage(s))
        approx = st.materialize()
        curve.append(float(acc_fn(approx)))
        pred = jnp.argmax(cnn_apply(approx, x_test), -1)
        agree.append(float(jnp.mean((pred == full_pred).astype(jnp.float32))))
    return {"model": "progressivenet-cnn", "orig": float(acc_fn(params)),
            "bits": STAGE_BITS, "accuracy": curve, "top1_agreement": agree}


# -- small LM ------------------------------------------------------------------

def _lm_setup(quick: bool = False):
    """Train the reduced LM once; both the per-stage accuracy curve and
    the accuracy-per-byte (scheduled + entropy-coded) row reuse it."""
    cfg = get_config("olmo-1b").reduced(n_layers=2, d_model=128, d_ff=256,
                                        vocab=64, n_heads=4, n_kv=4)
    model = build_model(cfg)
    steps = 60 if quick else 150
    res = train(model, steps=steps,
                data_cfg=DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16),
                opt_cfg=opt.OptConfig(lr=1e-2, warmup_steps=20, total_steps=steps),
                log_every=steps)
    params = res.params

    # same stream structure (seed fixes transitions/motifs), held-out step
    ds = MarkovMotifDataset(DataConfig(vocab=cfg.vocab, seq_len=64,
                                       global_batch=64, seed=0))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(100_000).items()}

    @jax.jit
    def eval_fn(p):
        logits, _ = model.forward(p, batch)
        pred = jnp.argmax(logits, -1)
        return pred, jnp.mean((pred == batch["labels"]).astype(jnp.float32))

    return cfg, model, params, batch, eval_fn


def accuracy_curve_lm(setup) -> dict:
    _, _, params, _, eval_fn = setup
    full_pred, orig_acc = eval_fn(params)
    prog = divide(params)
    st = ReceiverState.init(prog)
    curve, agree = [], []
    for s in range(1, prog.n_stages + 1):
        st = st.receive(prog.stage(s))
        pred, acc = eval_fn(st.materialize())
        curve.append(float(acc))
        agree.append(float(jnp.mean((pred == full_pred).astype(jnp.float32))))
    return {"model": "olmo-1b (reduced, trained)", "orig": float(orig_acc),
            "bits": STAGE_BITS, "accuracy": curve, "top1_agreement": agree}


# -- accuracy per byte: calibrated schedule + entropy coding -------------------

def accuracy_per_byte_lm(setup) -> dict:
    """The v2 wire's claim in one row: at every byte budget of the
    uniform ladder, the calibrated schedule + entropy-coded stream must
    be at least as accurate, and the full-fidelity stream must cost no
    more bytes than the raw uniform one."""
    cfg, model, params, _, eval_fn = setup
    prog = divide(params)

    # calibration batch: same stream family, DIFFERENT seed than eval
    cal_ds = MarkovMotifDataset(DataConfig(vocab=cfg.vocab, seq_len=64,
                                           global_batch=16, seed=1))
    cal_batch = {k: jnp.asarray(v) for k, v in cal_ds.batch(50_000).items()}

    @jax.jit
    def cal_ce(p):
        logits, _ = model.forward(p, cal_batch)
        logp = jax.nn.log_softmax(logits, -1)
        onehot = jax.nn.one_hot(cal_batch["labels"], cfg.vocab)
        return -jnp.mean(jnp.sum(onehot * logp, -1))

    def eval_loss(leaves):
        return float(cal_ce(rebuild_params(prog, leaves)))

    schedule = calibrate_schedule(prog, eval_loss)
    blob_uni = wire.encode(prog)  # v1 raw stage-major stream
    blob_sched = wire.encode(prog, schedule=schedule, entropy_coded=True)

    # finer-than-stage byte grid: the uniform ladder saturates within a
    # stage or two, so per-stage marks alone can't resolve the curve
    n_marks = 20
    meta, hdr = wire.decode_header(blob_uni)
    budgets = [hdr + int(round((len(blob_uni) - hdr) * (k + 1) / n_marks))
               for k in range(n_marks)]

    shapes = {wire.path_str(p): l.shape
              for p, l in jax.tree_util.tree_flatten_with_path(params)[0]}

    def acc_at(blob: bytes, budget: int) -> float:
        client = ProgressiveClient()
        client.feed(blob[:budget])
        leaves = {k: jnp.asarray(v).reshape(shapes[k])
                  for k, v in client.materialize().items()}
        _, acc = eval_fn(rebuild_params(prog, leaves,
                                        key_fn=wire.path_str))
        return float(acc)

    uniform = [acc_at(blob_uni, b) for b in budgets]
    scheduled = [acc_at(blob_sched, min(b, len(blob_sched)))
                 for b in budgets]
    return {"model": "olmo-1b (reduced, trained)",
            "schedule_units": len(schedule.units),
            "byte_checkpoints": budgets,
            "uniform_raw_accuracy": uniform,
            "scheduled_coded_accuracy": scheduled,
            "uniform_raw_total_bytes": len(blob_uni),
            "scheduled_coded_total_bytes": len(blob_sched)}


def run(quick: bool = False) -> list[dict]:
    lm = _lm_setup(quick)
    return [accuracy_curve_cnn(quick), accuracy_curve_lm(lm),
            accuracy_per_byte_lm(lm)]


OUT_PATH = "artifacts/bench/BENCH_table2_accuracy.json"


def main(quick: bool = False, out: str = OUT_PATH) -> None:
    rows = run(quick)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"bench": "table2_accuracy", "quick": quick,
                   "rows": rows}, f, indent=2, sort_keys=True)
    print("\n== Table 2: accuracy vs received bit-width ==")
    hdr = "model".ljust(28) + "".join(f"{b:>7d}" for b in STAGE_BITS) + "   orig"
    print(hdr)
    for r in rows:
        if "accuracy" not in r:
            continue
        print(r["model"].ljust(28)
              + "".join(f"{a:7.3f}" for a in r["accuracy"])
              + f"  {r['orig']:.3f}")
        print("  (top-1 agreement)".ljust(28)
              + "".join(f"{a:7.3f}" for a in r["top1_agreement"]))
        assert abs(r["accuracy"][-1] - r["orig"]) < 0.02, \
            "16-bit stage must match the original model"

    apb = next(r for r in rows if "scheduled_coded_accuracy" in r)
    print("\n== accuracy per byte: calibrated schedule + entropy coding ==")
    print("KB".ljust(22) + "".join(
        f"{b / 1024:6.0f}" for b in apb["byte_checkpoints"]))
    print("uniform raw (v1)".ljust(22) + "".join(
        f"{a:6.3f}" for a in apb["uniform_raw_accuracy"]))
    print("scheduled+coded (v2)".ljust(22) + "".join(
        f"{a:6.3f}" for a in apb["scheduled_coded_accuracy"]))
    print(f"total bytes at full fidelity: scheduled+coded "
          f"{apb['scheduled_coded_total_bytes']:,} vs uniform raw "
          f"{apb['uniform_raw_total_bytes']:,}")
    uni = apb["uniform_raw_accuracy"]
    sch = apb["scheduled_coded_accuracy"]
    # equal-or-better everywhere, up to held-out argmax noise: at the
    # saturated plateau both curves wobble by a token or two of the
    # 4k-token eval batch (~5e-4); NOISE_EPS must swallow that and
    # nothing more. Strict wins must clear a real margin instead.
    NOISE_EPS, STRICT_MARGIN = 2e-3, 1e-2
    assert all(s >= u - NOISE_EPS for s, u in zip(sch, uni)), \
        f"scheduled+coded curve must dominate the uniform ladder: {sch} vs {uni}"
    strictly = sum(s > u + STRICT_MARGIN for s, u in zip(sch, uni))
    assert strictly >= 3, (
        f"scheduled+coded must be strictly better at >=3 byte "
        f"checkpoints (got {strictly}): {sch} vs {uni}")
    assert apb["scheduled_coded_total_bytes"] <= \
        apb["uniform_raw_total_bytes"], \
        "entropy-coded stream must not exceed the raw uniform stream"
    print(f"-> {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="fewer training steps (CI tier-2); the models "
                         "are already the reduced configs")
    args = ap.parse_args()
    main(quick=args.quick or args.reduced)
