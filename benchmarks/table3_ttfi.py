"""Table III proxy: time-to-first-useful-inference vs bandwidth.

The paper's user study (57 humans) is not reproducible here; the
quantitative mechanism behind its result is: progressive transmission
puts a *useful* model in the user's hands several times earlier than the
singleton download. We report, at the paper's three bandwidths, the time
until the first useful stage (the stage where Table-2 accuracy first
reaches >=90% of the original — the paper finds 6-bit) against the
singleton's only milestone (everything downloaded).

Since the co-simulation refactor the numbers come from an *executed*
:class:`~repro.transmission.session.Session` — real wire bytes through
the real client on the trace's byte clock — and the run asserts they
match the Fig.-4 algebra to 1e-9 s, so the operational path and the
published timeline can't silently diverge.

    PYTHONPATH=src python -m benchmarks.table3_ttfi [--reduced] \
        [--event-log artifacts/ttfi_events.jsonl]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission.scheduler import (
    progressive_timeline, singleton_timeline, time_to_first_useful,
)
from repro.transmission.session import Session
from repro.transmission.simulator import BandwidthTrace

from benchmarks.common import measure_stage_costs

BANDWIDTHS = [0.1e6, 0.2e6, 0.5e6]  # paper's user-study settings
ALGEBRA_TOL_S = 1e-9


def run(useful_stage: int = 3, quick: bool = False, reduced: bool = False,
        event_log: str | None = None) -> list[dict]:
    """useful_stage=3 -> 6 bits under the paper's 2-bit schedule.

    Uses the paper-regime model size (download >> per-stage processing,
    like the paper's 7-51 MB zoo); see table1_execution_time.bench_cfg.
    ``reduced`` (and the orchestrator's ``quick``) swap in the tiny
    smoke config (CI-friendly; the regime claim no longer holds there,
    but the session/algebra agreement and milestones still do).
    """
    from benchmarks.table1_execution_time import bench_cfg

    cfg = (get_config("olmo-1b").reduced() if (reduced or quick)
           else bench_cfg("olmo-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)

    batch = {"tokens": jnp.zeros((1, 32), jnp.int32)}
    fwd = jax.jit(lambda p: model.forward(p, batch)[0])
    costs = measure_stage_costs(prog, fwd)

    blob = wire.encode(prog)
    meta, hdr = wire.decode_header(blob)
    stage_bytes = wire.layout_from_header(meta, hdr).stage_bytes
    total = len(blob)

    rows = []
    log_lines: list[str] = []
    for bw in BANDWIDTHS:
        trace = BandwidthTrace.constant(bw, name=f"const-{bw / 1e6:g}MBps")
        session = Session(blob, trace)
        result = session.run_timeline(costs, concurrent=True)
        prog_t = result.timeline

        # the executed session must match the Fig.-4 algebra exactly
        algebra = progressive_timeline(stage_bytes, trace, costs,
                                       concurrent=True, header_bytes=hdr)
        drift = max(
            max(abs(a - b) for a, b in
                zip(prog_t.download_done, algebra.download_done)),
            max(abs(a - b) for a, b in
                zip(prog_t.result_ready, algebra.result_ready)))
        if drift > ALGEBRA_TOL_S:
            raise AssertionError(
                f"session/algebra drift {drift:.3e}s at {bw / 1e6} MB/s")

        single = singleton_timeline(total, trace, costs[-1])
        ttfu = time_to_first_useful(prog_t, useful_stage)
        rows.append({
            "bandwidth_MBps": bw / 1e6,
            "singleton_first_result_s": single.total_s,
            "progressive_first_any_s": prog_t.first_result_s,
            "progressive_first_useful_s": ttfu,
            "speedup_to_useful": single.total_s / ttfu,
            "session_algebra_drift_s": drift,
        })
        if event_log:
            log_lines.extend(
                json.dumps({"bandwidth_MBps": bw / 1e6, "t_s": e.t_s,
                            "kind": e.kind, **e.data}, sort_keys=True)
                for e in result.events)
    if event_log:
        path = Path(event_log)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text("\n".join(log_lines) + "\n")
    return rows


def main(quick: bool = False, reduced: bool = False,
         event_log: str | None = None) -> None:
    rows = run(quick=quick, reduced=reduced, event_log=event_log)
    print("\n== Table 3 proxy: time-to-first-useful-inference ==")
    print(f"{'MB/s':>6s} {'singleton':>10s} {'prog 1st':>9s} "
          f"{'prog useful(6b)':>15s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['bandwidth_MBps']:6.1f} {r['singleton_first_result_s']:9.1f}s "
              f"{r['progressive_first_any_s']:8.1f}s "
              f"{r['progressive_first_useful_s']:14.1f}s "
              f"{r['speedup_to_useful']:7.2f}x")
    print(f"(session == algebra to {ALGEBRA_TOL_S:g}s at every milestone)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny smoke config (CI tier-2)")
    ap.add_argument("--event-log", default=None,
                    help="write session audit logs (JSONL) here")
    args = ap.parse_args()
    main(quick=args.quick, reduced=args.reduced, event_log=args.event_log)
