"""Table III proxy: time-to-first-useful-inference vs bandwidth.

The paper's user study (57 humans) is not reproducible here; the
quantitative mechanism behind its result is: progressive transmission
puts a *useful* model in the user's hands several times earlier than the
singleton download. We report, at the paper's three bandwidths, the time
until the first useful stage (the stage where Table-2 accuracy first
reaches >=90% of the original — the paper finds 6-bit) against the
singleton's only milestone (everything downloaded).

Since the co-simulation refactor the numbers come from an *executed*
:class:`~repro.transmission.session.Session` — real wire bytes through
the real client on the trace's byte clock — and the run asserts they
match the Fig.-4 algebra to 1e-9 s, so the operational path and the
published timeline can't silently diverge.

    PYTHONPATH=src python -m benchmarks.table3_ttfi [--reduced] \
        [--event-log artifacts/ttfi_events.jsonl]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.calibrate import weight_sse_schedule
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission.scenarios import get_scenario
from repro.transmission.scheduler import (
    progressive_timeline, singleton_timeline, time_to_first_useful,
)
from repro.transmission.session import Session
from repro.transmission.simulator import BandwidthTrace

from benchmarks.common import measure_stage_costs

BANDWIDTHS = [0.1e6, 0.2e6, 0.5e6]  # paper's user-study settings
ALGEBRA_TOL_S = 1e-9


def scheduled_blob(prog) -> bytes:
    """The v2 stream for an un-finetuned bench model: weight-SSE proxy
    calibration (no task data at this scale) + entropy-coded payloads.
    Checkpoints still land at the uniform ladder's byte marks, so
    stage-indexed milestones stay comparable across both streams."""
    return wire.encode(prog, schedule=weight_sse_schedule(prog),
                       entropy_coded=True)


def browser_3g_comparison(prog, blob_v1: bytes, costs,
                          useful_stage: int, seed: int = 0
                          ) -> tuple[list[dict], list[dict]]:
    """Run both streams through an executed Session on the browser-3g
    scenario trace; the scheduled + entropy-coded stream must reach the
    first-useful milestone earlier (it ships fewer bytes to the same
    checkpoint). Returns (rows, session events for the audit log)."""
    trace = get_scenario("browser-3g").make_trace(seed)
    rows, events = [], []
    for label, blob in (("uniform-raw-v1", blob_v1),
                        ("scheduled-coded-v2", scheduled_blob(prog))):
        meta, hdr = wire.decode_header(blob)
        stage_bytes = wire.layout_from_header(meta, hdr).stage_bytes
        session = Session(blob, trace)
        result = session.run_timeline(costs, concurrent=True)
        algebra = progressive_timeline(stage_bytes, trace, costs,
                                       concurrent=True, header_bytes=hdr)
        drift = max(
            max(abs(a - b) for a, b in
                zip(result.timeline.download_done, algebra.download_done)),
            max(abs(a - b) for a, b in
                zip(result.timeline.result_ready, algebra.result_ready)))
        if drift > ALGEBRA_TOL_S:
            raise AssertionError(
                f"browser-3g session/algebra drift {drift:.3e}s ({label})")
        rows.append({
            "stream": label,
            "total_bytes": len(blob),
            "first_useful_s": time_to_first_useful(result.timeline,
                                                   useful_stage),
            "first_any_s": result.timeline.first_result_s,
            "session_algebra_drift_s": drift,
        })
        events.extend({"scenario": "browser-3g", "stream": label,
                       "t_s": e.t_s, "kind": e.kind, **e.data}
                      for e in result.events)
    assert rows[1]["first_useful_s"] < rows[0]["first_useful_s"], (
        f"scheduled+coded stream must reach the useful milestone first: "
        f"{rows[1]['first_useful_s']:.2f}s vs {rows[0]['first_useful_s']:.2f}s")
    return rows, events


def run(useful_stage: int = 3, quick: bool = False, reduced: bool = False,
        event_log: str | None = None) -> list[dict]:
    """useful_stage=3 -> 6 bits under the paper's 2-bit schedule.

    Uses the paper-regime model size (download >> per-stage processing,
    like the paper's 7-51 MB zoo); see table1_execution_time.bench_cfg.
    ``reduced`` (and the orchestrator's ``quick``) swap in the tiny
    smoke config (CI-friendly; the regime claim no longer holds there,
    but the session/algebra agreement and milestones still do).
    """
    from benchmarks.table1_execution_time import bench_cfg

    cfg = (get_config("olmo-1b").reduced() if (reduced or quick)
           else bench_cfg("olmo-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)

    batch = {"tokens": jnp.zeros((1, 32), jnp.int32)}
    fwd = jax.jit(lambda p: model.forward(p, batch)[0])
    costs = measure_stage_costs(prog, fwd)

    blob = wire.encode(prog)
    meta, hdr = wire.decode_header(blob)
    stage_bytes = wire.layout_from_header(meta, hdr).stage_bytes
    total = len(blob)

    rows = []
    log_lines: list[str] = []
    for bw in BANDWIDTHS:
        trace = BandwidthTrace.constant(bw, name=f"const-{bw / 1e6:g}MBps")
        session = Session(blob, trace)
        result = session.run_timeline(costs, concurrent=True)
        prog_t = result.timeline

        # the executed session must match the Fig.-4 algebra exactly
        algebra = progressive_timeline(stage_bytes, trace, costs,
                                       concurrent=True, header_bytes=hdr)
        drift = max(
            max(abs(a - b) for a, b in
                zip(prog_t.download_done, algebra.download_done)),
            max(abs(a - b) for a, b in
                zip(prog_t.result_ready, algebra.result_ready)))
        if drift > ALGEBRA_TOL_S:
            raise AssertionError(
                f"session/algebra drift {drift:.3e}s at {bw / 1e6} MB/s")

        single = singleton_timeline(total, trace, costs[-1])
        ttfu = time_to_first_useful(prog_t, useful_stage)
        rows.append({
            "bandwidth_MBps": bw / 1e6,
            "singleton_first_result_s": single.total_s,
            "progressive_first_any_s": prog_t.first_result_s,
            "progressive_first_useful_s": ttfu,
            "speedup_to_useful": single.total_s / ttfu,
            "session_algebra_drift_s": drift,
        })
        if event_log:
            log_lines.extend(
                json.dumps({"bandwidth_MBps": bw / 1e6, "t_s": e.t_s,
                            "kind": e.kind, **e.data}, sort_keys=True)
                for e in result.events)

    rows_3g, events_3g = browser_3g_comparison(prog, blob, costs,
                                               useful_stage)
    if event_log:
        log_lines.extend(json.dumps(e, sort_keys=True) for e in events_3g)
        path = Path(event_log)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text("\n".join(log_lines) + "\n")
    return rows + rows_3g


def main(quick: bool = False, reduced: bool = False,
         event_log: str | None = None) -> None:
    rows = run(quick=quick, reduced=reduced, event_log=event_log)
    print("\n== Table 3 proxy: time-to-first-useful-inference ==")
    print(f"{'MB/s':>6s} {'singleton':>10s} {'prog 1st':>9s} "
          f"{'prog useful(6b)':>15s} {'speedup':>8s}")
    for r in rows:
        if "bandwidth_MBps" not in r:
            continue
        print(f"{r['bandwidth_MBps']:6.1f} {r['singleton_first_result_s']:9.1f}s "
              f"{r['progressive_first_any_s']:8.1f}s "
              f"{r['progressive_first_useful_s']:14.1f}s "
              f"{r['speedup_to_useful']:7.2f}x")
    print(f"(session == algebra to {ALGEBRA_TOL_S:g}s at every milestone)")

    rows_3g = [r for r in rows if "stream" in r]
    print("\n-- browser-3g (jittered cellular): uniform raw vs "
          "scheduled+coded --")
    for r in rows_3g:
        print(f"{r['stream']:>20s}: first useful "
              f"{r['first_useful_s']:7.1f}s, first any "
              f"{r['first_any_s']:6.1f}s, {r['total_bytes']:,} bytes")
    uni, sch = rows_3g[0], rows_3g[1]
    print(f"scheduled+coded reaches the useful milestone "
          f"{uni['first_useful_s'] / sch['first_useful_s']:.2f}x earlier")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny smoke config (CI tier-2)")
    ap.add_argument("--event-log", default=None,
                    help="write session audit logs (JSONL) here")
    args = ap.parse_args()
    main(quick=args.quick, reduced=args.reduced, event_log=args.event_log)
