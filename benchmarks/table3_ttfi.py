"""Table III proxy: time-to-first-useful-inference vs bandwidth.

The paper's user study (57 humans) is not reproducible here; the
quantitative mechanism behind its result is: progressive transmission
puts a *useful* model in the user's hands several times earlier than the
singleton download. We report, at the paper's three bandwidths, the time
until the first useful stage (the stage where Table-2 accuracy first
reaches >=90% of the original — the paper finds 6-bit) against the
singleton's only milestone (everything downloaded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission.scheduler import (
    StageCost, progressive_timeline, singleton_timeline, time_to_first_useful,
)
from repro.transmission.simulator import Link

from benchmarks.common import measure_stage_costs

BANDWIDTHS = [0.1e6, 0.2e6, 0.5e6]  # paper's user-study settings


def run(useful_stage: int = 3, quick: bool = False) -> list[dict]:
    """useful_stage=3 -> 6 bits under the paper's 2-bit schedule.

    Uses the paper-regime model size (download >> per-stage processing,
    like the paper's 7-51 MB zoo); see table1_execution_time.bench_cfg.
    """
    from benchmarks.table1_execution_time import bench_cfg

    cfg = bench_cfg("olmo-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)

    batch = {"tokens": jnp.zeros((1, 32), jnp.int32)}
    fwd = jax.jit(lambda p: model.forward(p, batch)[0])
    costs = measure_stage_costs(prog, fwd)

    hdr = len(wire.encode_header(prog))
    stage_bytes = [len(wire.encode_stage(prog, s))
                   for s in range(1, prog.n_stages + 1)]
    total = hdr + sum(stage_bytes)

    rows = []
    for bw in BANDWIDTHS:
        link = Link(bandwidth_bytes_per_s=bw)
        single = singleton_timeline(total, link, costs[-1])
        prog_t = progressive_timeline(stage_bytes, link, costs,
                                      concurrent=True, header_bytes=hdr)
        ttfu = time_to_first_useful(prog_t, useful_stage)
        rows.append({
            "bandwidth_MBps": bw / 1e6,
            "singleton_first_result_s": single.total_s,
            "progressive_first_any_s": prog_t.first_result_s,
            "progressive_first_useful_s": ttfu,
            "speedup_to_useful": single.total_s / ttfu,
        })
    return rows


def main(quick: bool = False) -> None:
    rows = run(quick=quick)
    print("\n== Table 3 proxy: time-to-first-useful-inference ==")
    print(f"{'MB/s':>6s} {'singleton':>10s} {'prog 1st':>9s} "
          f"{'prog useful(6b)':>15s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['bandwidth_MBps']:6.1f} {r['singleton_first_result_s']:9.1f}s "
              f"{r['progressive_first_any_s']:8.1f}s "
              f"{r['progressive_first_useful_s']:14.1f}s "
              f"{r['speedup_to_useful']:7.2f}x")


if __name__ == "__main__":
    main()
