"""Upgrade-latency microbenchmark: the PlaneStore's two claims.

1. A full-model stage upgrade is ONE batched ``plane_or_segments``
   launch (per container dtype), not one ``plane_or`` per tensor.
   SLIDE-style simultaneous download-and-inference lives or dies on
   this: the upgrade runs between decode steps, so its fixed dispatch
   overhead scales with launches, not tensors.
2. ``materialize()`` is incremental: after a partial shipment, only the
   tensors that received planes are re-dequantized; the rest are served
   from the leaf cache (ProgDTD-style cheap partial decode).

Reports wall time and launch counts for batched vs. per-tensor upgrade
and incremental vs. full materialize. On this CPU container the Pallas
kernels run interpreted, so *per-launch overhead dominates* — exactly
the regime where launch count matters; on a TPU the same launch-count
argument holds against ~10 us dispatch overheads.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.plane_store import PlaneStore, next_plane_shift
from repro.core.progressive import divide
from repro.kernels import ops


def _make_params(n_tensors: int, side: int):
    k = jax.random.PRNGKey(0)
    return {
        f"layer{i:03d}/w": jax.random.normal(jax.random.fold_in(k, i),
                                             (side, side))
        for i in range(n_tensors)
    }


def _timeit(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_upgrade(n_tensors: int, side: int, repeats: int = 3) -> dict:
    params = _make_params(n_tensors, side)
    prog = divide(params)
    stage1 = prog.stage(1)

    # -- per-tensor path: one plane_or launch per tensor (the old loop)
    store_a = PlaneStore.from_model(prog)

    def per_tensor():
        outs = []
        for idx, plane in stage1:
            t = store_a.slots[idx]
            sh = next_plane_shift(t.schedule, 0)
            outs.append(ops.plane_or(store_a.acc(idx),
                                     plane.astype(t.container), shift=sh))
        return outs

    per_tensor()  # warm the jit caches
    ops.reset_launch_counts()
    t_loop = _timeit(per_tensor, repeats)
    launches_loop = ops.LAUNCH_COUNTS["plane_or"] // repeats

    # -- batched path: the store's single segment-OR launch
    store_b = PlaneStore.from_model(prog)
    store_b.copy().ingest(stage1)  # warm
    ops.reset_launch_counts()

    def batched():
        st = store_b.copy()
        st.ingest(stage1)
        return list(st.buffers.values())

    t_batch = _timeit(batched, repeats)
    launches_batch = ops.LAUNCH_COUNTS["plane_or_segments"] // repeats

    return {
        "n_tensors": n_tensors,
        "per_tensor_s": t_loop,
        "per_tensor_launches": launches_loop,
        "batched_s": t_batch,
        "batched_launches": launches_batch,
        "speedup": t_loop / t_batch,
    }


def bench_materialize(n_tensors: int, side: int, repeats: int = 3) -> dict:
    params = _make_params(n_tensors, side)
    prog = divide(params)
    store = PlaneStore.from_model(prog)
    store.ingest(prog.stage(1))
    store.materialize_leaves()

    # One tensor receives its next plane; everyone else is clean. The
    # ingest happens OUTSIDE the timed region — we measure eq. (5) only.
    idx = 0
    staged = store.copy()
    staged.ingest([(idx, prog.tensors[idx].planes[1])])
    staged.copy().materialize_leaves()  # warm the dequant jit caches

    def incremental():
        return list(staged.copy().materialize_leaves().values())

    def full():
        st = staged.copy()
        st._leaf_cache.clear()
        st._dirty = set(range(st.n_tensors))
        return list(st.materialize_leaves().values())

    t_inc = _timeit(incremental, repeats)
    t_full = _timeit(full, repeats)
    return {
        "n_tensors": n_tensors,
        "dirty_tensors": 1,
        "incremental_s": t_inc,
        "full_s": t_full,
        "speedup": t_full / t_inc,
    }


def main(quick: bool = False) -> None:
    # Dispatch-overhead regime: many small tensors (a transformer's long
    # tail of norm scales / biases / small projections). Here per-launch
    # fixed costs dominate and the O(1)-launch claim shows up directly
    # in wall time. At large per-tensor sizes the CPU interpreter's
    # per-grid-step cost scales with the *whole* buffer (an interpret
    # artifact a TPU doesn't have: there, both paths move identical HBM
    # bytes and batching still saves n-1 dispatches).
    sweep = [32, 64] if quick else [64, 128, 256]
    side = 32

    print("\n== stage upgrade: batched segment-OR vs per-tensor loop ==")
    print(f"{'tensors':>8s} {'loop':>10s} {'launches':>8s} "
          f"{'batched':>10s} {'launches':>8s} {'speedup':>8s}")
    for n in sweep:
        r = bench_upgrade(n, side)
        print(f"{r['n_tensors']:8d} {r['per_tensor_s']*1e3:8.1f}ms "
              f"{r['per_tensor_launches']:8d} {r['batched_s']*1e3:8.1f}ms "
              f"{r['batched_launches']:8d} {r['speedup']:7.1f}x")
        assert r["batched_launches"] == 1, "upgrade must be O(1) launches"
        assert r["per_tensor_launches"] == r["n_tensors"]

    print("\n== materialize after a 1-tensor shipment: incremental vs full ==")
    print(f"{'tensors':>8s} {'full':>10s} {'incremental':>12s} {'speedup':>8s}")
    for n in sweep:
        r = bench_materialize(n, side)
        print(f"{r['n_tensors']:8d} {r['full_s']*1e3:8.1f}ms "
              f"{r['incremental_s']*1e3:10.1f}ms {r['speedup']:7.1f}x")


if __name__ == "__main__":
    main()
