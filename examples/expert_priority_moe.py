"""Beyond-paper policy demo: router-popularity-ordered expert planes.

For MoE models, not all tensors are equally urgent: experts that the
router uses most should reach the serving pod first. This example
measures router popularity on a calibration batch, builds an
ExpertPopularityPolicy, and shows that the *partial first stage* (cut
mid-stage, e.g. the link died) of the popularity-ordered stream yields a
better model than the default ordering at the same byte budget.

    PYTHONPATH=src python examples/expert_priority_moe.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import ExpertPopularityPolicy, UniformPolicy
from repro.core.progressive import divide, ReceiverState
from repro.models.model import build_model

cfg = get_config("dbrx-132b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 0. skew the routers (a trained MoE has popular experts; random init
# routes near-uniformly) so the demo shows the trained-model regime
scale = jnp.asarray([1.5, 0.8, 0.1, 0.05])[: cfg.n_experts]
def _skew(r):  # (R, d, E) stacked router weights: damp cold experts'
    # router columns so the hot ones win top-k for most tokens
    return r * scale[None, None, :]
for slot, blk in params["decoder"]["cycles"].items():
    if "moe" in blk:
        blk["moe"]["router"] = _skew(blk["moe"]["router"])

# 1. router popularity from a calibration batch
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab).astype(jnp.int32)}
x = model._embed(params, batch["tokens"])
moe_p = params["decoder"]["cycles"][next(
    s for s in params["decoder"]["cycles"] if "moe" in s)]["moe"]
router_w = jax.tree.map(lambda a: a[0], moe_p)["router"]  # first cycle's router
logits = x.astype(jnp.float32) @ router_w
top = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)[1]
counts = np.bincount(np.asarray(top).ravel(), minlength=cfg.n_experts)
pop = {i: c / counts.sum() for i, c in enumerate(counts)}
print("router popularity:", {k: round(v, 3) for k, v in pop.items()})

# 2. two streams: default order (whole expert banks) vs popularity order
#    (banks sliced per expert; hot experts' planes ship first, and each
#    slice gets its own tighter (min, max) quantization range)
#    both streams are expert-sliced and ship core tensors first; ONLY
#    the within-expert order differs (uniform vs popularity)
prog_default = divide(params, ExpertPopularityPolicy(popularity={},
                                                     n_experts=cfg.n_experts))
prog_pop = divide(params, ExpertPopularityPolicy(popularity=pop,
                                                 n_experts=cfg.n_experts))
print(f"tensors after expert slicing: {len(prog_pop.tensors)} "
      f"(vs {len(divide(params, UniformPolicy()).tensors)} unsliced)")


def eval_partial(prog, frac, upto_stage=3):
    """Receive stages 1..upto-1 fully, then `frac` of stage `upto`
    (the link cut mid-stage)."""
    st = ReceiverState.init(prog)
    for s in range(1, upto_stage):
        st = st.receive(prog.stage(s))
    planes = prog.stage(upto_stage)
    st = st.receive(planes[: max(1, int(len(planes) * frac))])
    approx = st.materialize()
    mses = []
    for seed in range(4):  # average over eval batches
        eb = {"tokens": jax.random.randint(jax.random.PRNGKey(100 + seed),
                                           (4, 64), 0, cfg.vocab).astype(jnp.int32)}
        logits, _ = model.forward(approx, eb)
        ref, _ = model.forward(params, eb)
        mses.append(float(jnp.mean((logits - ref) ** 2)))
    return sum(mses) / len(mses)


print("\nMSE to fp32 logits; stages 1-2 landed, stage 3 cut mid-flight:")
print(f"{'frac':>6s} {'default':>12s} {'popularity':>12s}")
for frac in (0.3, 0.5, 0.7):
    d = eval_partial(prog_default, frac)
    p = eval_partial(prog_pop, frac)
    print(f"{frac:6.1f} {d:12.4f} {p:12.4f}  "
          f"{'<- popularity wins' if p < d else ''}")
print("\n(the win shows where hot-expert slices displace cold ones at the "
"cut; at cuts\nwhere either order delivers the same expert coverage — or at "
"full stages —\nthe two streams are equivalent. Slicing also buys per-expert "
"(min,max) ranges:\nsee tests/test_progressive.py::test_expert_sliced_roundtrip)")
