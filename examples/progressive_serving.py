"""Progressive cold-start serving: a pod begins decoding from the 2-bit
planes and upgrades precision in place, mid-generation, as later planes
"arrive" over a simulated link — KV cache and compiled step survive
every upgrade (the paper's Fig. 4, pod-side).

    PYTHONPATH=src python examples/progressive_serving.py \
        [--arch mixtral-8x22b] [--bandwidth-mbps 2.5]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import ProgressiveServer
from repro.transmission.simulator import Link, simulate_transfer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--bandwidth-mbps", type=float, default=2.5)
    ap.add_argument("--decode-steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)

    stage_bytes = [len(wire.encode_stage(prog, s))
                   for s in range(1, prog.n_stages + 1)]
    hdr = len(wire.encode_header(prog))
    link = Link(bandwidth_bytes_per_s=args.bandwidth_mbps * 1e6)
    events = simulate_transfer(
        [("hdr", hdr)] + [(f"s{i}", b) for i, b in enumerate(stage_bytes, 1)], link)
    arrivals = [e.end_s for e in events[1:]]
    print(f"{args.arch} (reduced): {(hdr + sum(stage_bytes)) / 1e6:.2f} MB; "
          f"stage arrivals at {[round(a, 2) for a in arrivals]} s")

    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab).astype(jnp.int32)}
    if cfg.enc_layers:
        batch["enc_input"] = jnp.zeros((B, S // cfg.enc_seq_divisor, cfg.d_model),
                                       cfg.dtype)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros((B, cfg.vision_tokens, cfg.d_vision),
                                           cfg.dtype)

    server = ProgressiveServer(model, prog, max_len=S + args.decode_steps)
    server.receive_stage()
    print(f"cold start at t={arrivals[0]:.2f}s with 2-bit weights; decoding...")
    server.start(batch)

    # model a decode budget: tokens at a fixed cadence from cold start
    cadence = max((arrivals[-1] - arrivals[0]) / args.decode_steps, 1e-6)

    def stage_arrival(i):
        now = arrivals[0] + (i + 1) * cadence
        return server.stage < prog.n_stages and now >= arrivals[server.stage]

    res = server.decode(args.decode_steps, stage_arrival=stage_arrival)
    print("decode-step : " + " ".join(f"{i:3d}" for i in range(args.decode_steps)))
    print("bits/weight : " + " ".join(f"{2 * s:3d}" for s in res.stage_at_step))
    print("tokens[0]   : " + " ".join(f"{int(t):3d}" for t in res.tokens[0]))
    print(f"\n{len(res.upgrades)} in-place upgrades during generation; "
          f"final precision {2 * server.stage} bits — no recompile, no KV loss")


if __name__ == "__main__":
    main()
