"""Progressive cold-start serving: a pod begins decoding from the 2-bit
planes and upgrades precision in place, mid-generation, as later planes
arrive over a simulated network scenario — KV cache and compiled step
survive every upgrade (the paper's Fig. 4, pod-side).

The run is a deterministic co-simulation: real wire bytes stream
through the scenario's bandwidth trace into the real client/PlaneStore,
and the server decodes from that same store. Same seed, same tokens,
same event log — on any machine.

    PYTHONPATH=src python examples/progressive_serving.py \
        [--arch mixtral-8x22b] [--scenario browser-lte-handoff] [--seed 0]
    PYTHONPATH=src python examples/progressive_serving.py \
        --bandwidth-mbps 2.5   # constant link instead of a scenario
    PYTHONPATH=src python examples/progressive_serving.py \
        --resident quantized   # decode straight from the uint accumulators
    PYTHONPATH=src python examples/progressive_serving.py \
        --flash-crowd 6        # continuous batching: 6 clients, one pool

``--resident quantized`` serves the whole model from the PlaneStore's
uint accumulators: every matmul runs the fused dequant kernel, no fp
copy of the weights exists in HBM, and each precision upgrade is a
metadata refresh that re-uses the single compiled decode step (the
token stream is identical to --resident fp at every stage).

``--flash-crowd N`` swaps the lock-stepped stream for the slot-pool
engine: N clients join mid-download at staggered times, each is
admitted into a free slot (its prompt prefilled straight into the
slot's cache region), and every decode step is ONE batched ragged
kernel launch — per-slot positions, per-slot windows, one compiled
executable across all admissions, evictions and precision upgrades.

``--speculative`` turns the precision ladder into a throughput
multiplier: a truncated-bits view of the *same* accumulators (zero
extra weight bytes) drafts k tokens, the full-received-bits view
verifies the whole block in one pass, and the output stays
token-identical to plain greedy at every stage.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.launch.serve import _write_event_log, build_batch
from repro.models.model import build_model
from repro.transmission import BandwidthTrace, Session, get_scenario, list_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--scenario", default="browser-lte-handoff",
                    choices=list_scenarios())
    ap.add_argument("--bandwidth-mbps", type=float, default=None,
                    help="use a constant link instead of --scenario")
    ap.add_argument("--decode-steps", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resident", default="fp", choices=["fp", "quantized"],
                    help="'quantized' serves from the uint plane "
                         "accumulators: no fp weight copy, zero-recompile "
                         "upgrades, identical tokens")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decoding: the low-bit view of "
                         "the SAME accumulators drafts, the full view "
                         "verifies whole blocks — token-identical to plain "
                         "greedy, zero extra weight bytes")
    ap.add_argument("--draft-bits", type=int, default=4)
    ap.add_argument("--draft-k", type=int, default=None,
                    help="fixed draft length (default: adaptive)")
    ap.add_argument("--chunked-prefill", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="force chunked admission on/off for the flash-crowd "
                         "pool (default: auto — on for every arch without "
                         "cross-attention)")
    ap.add_argument("--flash-crowd", type=int, default=0, metavar="N",
                    help="> 0: serve N staggered clients through the "
                         "continuous-batching slot pool instead of one "
                         "lock-stepped stream")
    ap.add_argument("--scheduled", action="store_true",
                    help="stream the v2 wire: weight-SSE calibrated plane "
                         "order + entropy-coded payloads (decoded "
                         "transparently by the same client/PlaneStore; "
                         "final weights bit-identical to the v1 stream)")
    ap.add_argument("--event-log", default=None,
                    help="write the session audit log (JSONL) here")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)
    if args.scheduled:
        from repro.core.calibrate import weight_sse_schedule

        blob = wire.encode(prog, schedule=weight_sse_schedule(prog),
                           entropy_coded=True)
    else:
        blob = wire.encode(prog)

    if args.bandwidth_mbps is not None:
        session = Session(blob, BandwidthTrace.constant(args.bandwidth_mbps * 1e6))
        where = f"constant {args.bandwidth_mbps} MB/s"
    else:
        scenario = get_scenario(args.scenario)
        session = Session.from_scenario(blob, scenario, seed=args.seed)
        where = f"{scenario.name} (seed {args.seed}): {scenario.description}"
    arrivals = session.stage_arrival_times()
    wire_desc = " (scheduled+coded v2 wire)" if args.scheduled else ""
    print(f"{args.arch} (reduced): {len(blob) / 1e6:.2f} MB{wire_desc} "
          f"over {where}")
    print(f"stage arrivals at {[round(a, 2) for a in arrivals]} s")

    B, S = 2, 16
    batch = build_batch(cfg, B, S, seed=1)

    if args.flash_crowd > 0:
        from repro.transmission import flash_crowd_arrivals

        pool_spec = None
        if args.speculative:
            from repro.serving.speculative import SpecConfig

            pool_spec = SpecConfig(draft_bits=args.draft_bits,
                                   k=args.draft_k)
        n = args.flash_crowd
        prompts = [jax.random.randint(
            jax.random.PRNGKey(100 + i), (S,), 0, cfg.vocab
        ).astype(jnp.int32) for i in range(n)]
        offs = flash_crowd_arrivals(args.seed, n, span_s=1.0)
        res = session.run_serving_pool(
            model, prog, prompts=prompts, arrival_offsets_s=offs,
            max_new_tokens=args.decode_steps, n_slots=min(4, n),
            resident=None if pool_spec else args.resident,
            speculative=pool_spec,
            chunked_prefill=args.chunked_prefill)
        print(f"flash crowd: {n} clients admitted at "
              f"{[round(t, 2) for t, _ in res.admissions]}s "
              f"into {min(4, n)} slots"
              + (" (self-speculative rounds)" if args.speculative else ""))
        if args.speculative:
            s = res.speculation_summary()
            print(f"speculation: {s['rounds']} pool rounds, "
                  f"{s['accepted']}/{s['drafted']} drafts accepted; extra "
                  f"resident draft bytes: "
                  f"{res.server.resident_report()['extra_draft_bytes']}")
        for rid in sorted(res.tokens):
            stages = res.server.stage_log[rid]
            print(f"client {rid}: bits "
                  + " ".join(f"{2 * s:2d}" for s in stages)
                  + " | tokens " + " ".join(f"{t:3d}" for t in res.tokens[rid]))
        print(f"\n{len(res.upgrades)} in-place upgrades while the pool was "
              f"live; {res.server.decode_cache_size()} decode executable "
              f"across every admission/eviction/upgrade; "
              f"{len(res.events)} audited events")
        _write_event_log(res, args.event_log)
        return

    speculative = None
    max_len = S + args.decode_steps
    if args.speculative:
        from repro.serving.speculative import SpecConfig

        speculative = SpecConfig(draft_bits=args.draft_bits, k=args.draft_k)
        max_len += speculative.k_max + 1
    print(f"cold start at t={arrivals[0]:.2f}s with 2-bit weights "
          f"({'speculative' if args.speculative else args.resident}"
          f"-resident); decoding...")
    res = session.run_serving(model, prog, decode_steps=args.decode_steps,
                              batch=batch, max_len=max_len,
                              resident=None if speculative else args.resident,
                              speculative=speculative)
    print("decode-step : " + " ".join(f"{i:3d}" for i in range(args.decode_steps)))
    print("bits/weight : " + " ".join(f"{2 * s:3d}" for s in res.stage_at_step))
    print("tokens[0]   : " + " ".join(f"{int(t):3d}" for t in res.tokens[0]))
    print(f"\n{len(res.upgrades)} in-place upgrades during generation; "
          f"final precision {2 * res.server.stage} bits — no recompile, "
          f"no KV loss; {len(res.events)} audited events")
    if args.speculative:
        s = res.speculation_summary()
        rep = res.server.resident_report()
        print(f"speculation: {s['rounds']} rounds; "
              f"{s['accepted']}/{s['drafted']} drafts accepted; draft view "
              f"shares every buffer (extra resident draft bytes: "
              f"{rep['extra_draft_bytes']}); "
              f"{res.server.decode_cache_size()} decode executables "
              f"(draft decode + target verify)")
    if args.resident == "quantized" and not args.speculative:
        rep = res.server.resident_report()
        print(f"resident weights: {rep['quantized_leaves']} quantized leaves "
              f"({rep['quantized_bytes']} uint bytes), {rep['fp_leaves']} fp "
              f"leaves ({rep['fp_bytes']} bytes, non-matmul remainder); "
              f"decode executables compiled: {res.server.decode_cache_size()}")
    _write_event_log(res, args.event_log)


if __name__ == "__main__":
    main()
