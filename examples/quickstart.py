"""Quickstart: the paper's whole pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. build a model, 2. divide it into bit-plane stages (server side),
3. stream it over a simulated 1 MB/s link, 4. run inference at every
precision stage as it arrives (client side).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission.client import ProgressiveClient
from repro.transmission.simulator import Link, simulate_transfer

# 1. a model (any of the 10 assigned archs; reduced = CPU-friendly dims)
cfg = get_config("olmo-1b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. server side: quantize (eq. 2) + bit-divide (eq. 3) + serialize
prog = divide(params)  # paper default: 16 bits as 8 x 2-bit planes
blob = wire.encode(prog)
print(f"serialized: {len(blob) / 1e6:.2f} MB in {prog.n_stages} stages "
      f"(singleton 16-bit payload would be "
      f"{prog.singleton_payload_bytes() / 1e6:.2f} MB — no size increase)")

# 3. the link: when does each byte arrive at 1 MB/s?
link = Link(bandwidth_bytes_per_s=1e6)
events = simulate_transfer([("model", len(blob))], link)
print(f"full download takes {events[-1].end_s:.1f}s — but we don't wait:")

# 4. client side: feed the byte stream; infer at each completed stage
batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None, :]}
client = ProgressiveClient()
chunk = 64 * 1024
for off in range(0, len(blob), chunk):
    client.feed(blob[off : off + chunk])
    new_stage = client.stages_complete
    if new_stage and getattr(client, "_printed", 0) < new_stage:
        client._printed = new_stage
        flat = client.materialize()
        approx = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params),
            [jnp.asarray(flat[k]).reshape(l.shape).astype(l.dtype)
             for k, l in zip(
                 [wire.path_str(p) for p, _ in
                  jax.tree_util.tree_flatten_with_path(params)[0]],
                 jax.tree.leaves(params))],
        )
        logits, _ = model.forward(approx, batch)
        t = events[0].start_s + (off + chunk) / 1e6
        bits = 2 * new_stage
        print(f"  t={t:5.2f}s  stage {new_stage} ({bits:2d} bits/weight): "
              f"logits[0,-1,:4] = {logits[0, -1, :4]}")

print("done — the 16-bit stage equals the singleton quantized model exactly")
