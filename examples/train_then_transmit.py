"""End-to-end driver: train a ~small model for a few hundred steps, save
a PROGRESSIVE checkpoint, then cold-start inference from each precision
prefix — the deployment loop the paper proposes, on the training side.

    PYTHONPATH=src python examples/train_then_transmit.py [--steps 300]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import checkpoint, optimizer as opt
from repro.train.data import DataConfig, MarkovMotifDataset
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="olmo-1b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=2, d_model=128, d_ff=256,
                                        vocab=64, n_heads=4, n_kv=4)
    model = build_model(cfg)

    print(f"== training {args.arch} (reduced) for {args.steps} steps ==")
    res = train(
        model,
        steps=args.steps,
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16),
        opt_cfg=opt.OptConfig(lr=1e-2, warmup_steps=20, total_steps=args.steps),
        log_every=max(args.steps // 10, 1),
    )
    for h in res.history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.3f}  "
              f"grad_norm {h['grad_norm']:.2f}")

    ckpt_dir = os.path.join(tempfile.gettempdir(), "progressive_ckpt")
    print(f"\n== saving progressive checkpoint to {ckpt_dir} ==")
    checkpoint.save(res.params, ckpt_dir)
    man = checkpoint.manifest(ckpt_dir)
    print(f"  header {man['header_bytes']}B + stages "
          f"{[man['stage_bytes'][s] for s in sorted(man['stage_bytes'])]}")

    # held-out evaluation at each cold-start precision
    ds = MarkovMotifDataset(DataConfig(vocab=cfg.vocab, seq_len=64,
                                       global_batch=64, seed=0))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(10_000).items()}

    @jax.jit
    def acc_fn(p):
        logits, _ = model.forward(p, batch)
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                        .astype(jnp.float32))

    print("\n== cold-start accuracy by checkpoint prefix ==")
    full_acc = float(acc_fn(res.params))
    for stages in range(1, 9):
        approx = checkpoint.load_into(ckpt_dir, res.params, stages=stages)
        bytes_read = man["header_bytes"] + sum(
            man["stage_bytes"][s] for s in range(1, stages + 1))
        print(f"  stages 1..{stages} ({2 * stages:2d} bits, "
              f"{bytes_read / 1e6:.2f} MB): accuracy {float(acc_fn(approx)):.3f}")
    print(f"  fp32 reference: {full_acc:.3f}")


if __name__ == "__main__":
    main()
