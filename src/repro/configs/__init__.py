"""Architecture registry. Every assigned architecture is a module with a
CONFIG (exact published dims, source cited) and get_config()."""
from __future__ import annotations

import importlib

ARCHS = (
    "gemma3_27b",
    "xlstm_125m",
    "seamless_m4t_medium",
    "llama32_vision_90b",
    "starcoder2_15b",
    "zamba2_7b",
    "olmo_1b",
    "minitron_4b",
    "mixtral_8x22b",
    "dbrx_132b",
    "progressivenet_cnn",
)

_ALIASES = {
    "gemma3-27b": "gemma3_27b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "starcoder2-15b": "starcoder2_15b",
    "zamba2-7b": "zamba2_7b",
    "olmo-1b": "olmo_1b",
    "minitron-4b": "minitron_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "dbrx-132b": "dbrx_132b",
    "progressivenet-cnn": "progressivenet_cnn",
}


def get_config(name: str):
    mod_name = _ALIASES.get(name, name.replace("-", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS if a != "progressivenet_cnn"}
