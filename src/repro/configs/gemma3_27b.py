"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt family, scaled per assignment]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    cycle=("swa",) * 5 + ("global",),  # 5:1 local:global
    window=1024,
    qk_norm=True,
    rope_theta=10_000.0,
    logit_softcap=30.0,
    act="gelu",
)
