"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attn image layers every 5th layer. Vision
encoder (ViT) is a stub: input_specs provides patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=28672,
    vocab=128256,
    cycle=("attn",) * 4 + ("cross",),
    rope_theta=500_000.0,
    vision_tokens=1601,   # 1 tile of 560x560 / 14px patches + cls
    d_vision=1280,
    tie_embeddings=False,
)
