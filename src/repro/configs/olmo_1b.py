"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm. [arXiv:2402.00838]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=50304,
    cycle=("attn",),
    norm_type="nonparam_ln",
    act="silu",
)
