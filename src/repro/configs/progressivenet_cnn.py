"""The paper's own model family: a small convolutional classifier
(MobileNetV2-lite stand-in) used to reproduce the Table-II
accuracy-vs-bit-width curves end-to-end on CPU. Not one of the 10
assigned architectures; it exists so the *paper's* experiments have a
native subject.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="progressivenet-cnn",
    family="cnn",
    n_layers=4,
    d_model=64,
    n_heads=1,
    n_kv=1,
    d_ff=128,
    vocab=10,  # n_classes
    cycle=("attn",),  # unused; CNN has its own init/apply below
)


def cnn_init(key, *, channels=(16, 32, 64), n_classes=10, in_ch=3):
    ks = jax.random.split(key, len(channels) + 1)
    params = {}
    prev = in_ch
    for i, ch in enumerate(channels):
        # depthwise-separable pair (MobileNet-style)
        # depthwise kernel layout: (H, W, in/groups=1, out=prev)
        params[f"conv{i}_dw"] = 0.3 * jax.random.normal(ks[i], (3, 3, 1, prev), jnp.float32)
        params[f"conv{i}_pw"] = (2.0 / (prev + ch)) ** 0.5 * jax.random.normal(
            jax.random.fold_in(ks[i], 1), (1, 1, prev, ch), jnp.float32
        )
        params[f"bn{i}_scale"] = jnp.ones((ch,), jnp.float32)
        params[f"bn{i}_bias"] = jnp.zeros((ch,), jnp.float32)
        prev = ch
    params["head"] = (2.0 / (prev + n_classes)) ** 0.5 * jax.random.normal(
        ks[-1], (prev, n_classes), jnp.float32
    )
    return params


def cnn_apply(params, x):
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    i = 0
    while f"conv{i}_dw" in params:
        dw = jax.lax.conv_general_dilated(
            x,
            params[f"conv{i}_dw"],
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=x.shape[-1],
        )
        x = jax.lax.conv_general_dilated(
            dw,
            params[f"conv{i}_pw"],
            window_strides=(2, 2),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        mu = x.mean(axis=(0, 1, 2), keepdims=True)
        var = x.var(axis=(0, 1, 2), keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
        x = x * params[f"bn{i}_scale"] + params[f"bn{i}_bias"]
        x = jax.nn.relu(x)
        i += 1
    x = x.mean(axis=(1, 2))  # global average pool
    return x @ params["head"]
