"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206; enc-dec, multimodal. Audio frontend (mel + conv feature
extractor) is a stub: input_specs provides frame embeddings.
[arXiv:2308.11596]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    vocab=256206,
    cycle=("selfcross",),
    enc_layers=12,
    enc_seq_divisor=4,
    norm_type="layernorm",
    act="gelu",
    tie_embeddings=True,
)
