"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA + RoPE. [arXiv:2402.19173]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=4,
    d_ff=24576,
    vocab=49152,
    cycle=("attn",),
    rope_theta=100_000.0,
    norm_type="layernorm",
    act="gelu",
    tie_embeddings=False,
)
