"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks. [arXiv:2405.04517]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    cycle=("slstm", "mlstm"),
    lstm_proj_factor=2.0,
)
