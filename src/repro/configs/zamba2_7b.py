"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 blocks + one shared attention block
applied at intervals (per-use LoRA omitted; see DESIGN.md §7).
[arXiv:2411.15242]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    d_ff=14336,
    vocab=32000,
    cycle=("mamba2",) * 5 + ("shared_attn",),
    ssm_state=64,
    ssm_heads=112,   # d_inner=7168, head dim 64
    ssm_expand=2,
)
