"""Core of the paper's contribution: progressive quantization, bit
division/concatenation, and the progressive model container."""
from repro.core.quantize import (
    QuantizedTensor,
    quantize,
    dequantize,
    truncate,
    quantization_error_bound,
    container_dtype,
)
from repro.core.bitplanes import PlaneSchedule, PAPER_DEFAULT, split, concat
from repro.core.policy import (
    DivisionPolicy,
    UniformPolicy,
    LayerPriorityPolicy,
    ExpertPopularityPolicy,
    schedule_from_stages,
)
from repro.core.plane_store import PlaneStore, TensorSlot
from repro.core.progressive import (
    ProgressiveModel,
    ReceiverState,
    divide,
    transmit_reconstruct,
)

__all__ = [
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "truncate",
    "quantization_error_bound",
    "container_dtype",
    "PlaneSchedule",
    "PAPER_DEFAULT",
    "split",
    "concat",
    "DivisionPolicy",
    "UniformPolicy",
    "LayerPriorityPolicy",
    "ExpertPopularityPolicy",
    "schedule_from_stages",
    "PlaneStore",
    "TensorSlot",
    "ProgressiveModel",
    "ReceiverState",
    "divide",
    "transmit_reconstruct",
]
