"""Bit division and bit concatenation (paper eqs. 3 and 4).

Eq. (3) fetches the m-th fraction ("plane") of widths ``b`` from a k-bit
quantized integer:

    p<k, m> = (q<k> << b_{m-1}) >> (k - b_m + b_{m-1}),   b_0 = 0

where ``b_{m-1}`` here is the *cumulative* width of the planes before m
(the paper indexes cumulative widths; we make that explicit). Eq. (4)
reassembles whatever prefix of planes has been received:

    q'<k> = OR_m ( p<k, m> << (k - c_m) ),   c_m = b_1 + ... + b_m

Shifts are unsigned; everything is vectorized jnp and jit-safe, and the
same arithmetic is mirrored by the Pallas kernel in
``repro/kernels/bitplane.py`` (this module is its oracle's oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor, container_dtype


def validate_widths(bits: int, widths: Sequence[int]) -> tuple[int, ...]:
    widths = tuple(int(w) for w in widths)
    if any(w < 1 for w in widths):
        raise ValueError(f"plane widths must be >= 1, got {widths}")
    if sum(widths) != bits:
        raise ValueError(f"plane widths {widths} must sum to bits={bits}")
    return widths


def cumulative(widths: Sequence[int]) -> tuple[int, ...]:
    out, acc = [], 0
    for w in widths:
        acc += w
        out.append(acc)
    return tuple(out)


def split_plane(q: jax.Array, bits: int, widths: Sequence[int], m: int) -> jax.Array:
    """Eq. (3): extract plane m (1-indexed, MSB planes first)."""
    widths = validate_widths(bits, widths)
    if not (1 <= m <= len(widths)):
        raise ValueError(f"m={m} outside [1, {len(widths)}]")
    cum = (0,) + cumulative(widths)
    before = cum[m - 1]
    w = widths[m - 1]
    # Work in a container wide enough that `<< before` cannot overflow.
    wide = q.astype(jnp.uint32)
    mask = jnp.uint32(2**bits - 1)
    shifted = (wide << before) & mask          # unsigned left shift within k bits
    plane = shifted >> (bits - w)              # keep w top bits
    return plane.astype(container_dtype(w))


def split(qt: QuantizedTensor, widths: Sequence[int]) -> list[jax.Array]:
    """All planes of a quantized tensor, MSB-first."""
    widths = validate_widths(qt.bits, widths)
    return [split_plane(qt.q, qt.bits, widths, m + 1) for m in range(len(widths))]


def concat(planes: Sequence[jax.Array], bits: int, widths: Sequence[int]) -> jax.Array:
    """Eq. (4): OR together the received prefix of planes.

    ``planes`` may be any prefix (1..n planes); the result is the k-bit
    integer with the unreceived low bits zero.
    """
    widths = validate_widths(bits, widths)
    if not (1 <= len(planes) <= len(widths)):
        raise ValueError(f"got {len(planes)} planes for {len(widths)} widths")
    cum = cumulative(widths)
    acc = jnp.zeros(planes[0].shape, dtype=jnp.uint32)
    for m, p in enumerate(planes, start=1):
        acc = acc | (p.astype(jnp.uint32) << (bits - cum[m - 1]))
    return acc.astype(container_dtype(bits))


@dataclasses.dataclass(frozen=True)
class PlaneSchedule:
    """Static description of a bit-division: k bits into widths b."""

    bits: int
    widths: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "widths", validate_widths(self.bits, self.widths))

    @property
    def n_planes(self) -> int:
        return len(self.widths)

    @property
    def cumulative_bits(self) -> tuple[int, ...]:
        return cumulative(self.widths)

    def payload_bytes(self, n_elements: int, upto: int | None = None) -> int:
        """Dense-packed payload size of planes [1..upto]."""
        import math

        upto = self.n_planes if upto is None else upto
        return sum(math.ceil(n_elements * w / 8) for w in self.widths[:upto])


# The paper's default: 16-bit model sent as eight 2-bit planes
# (2 -> 4 -> 6 -> ... -> 16).
PAPER_DEFAULT = PlaneSchedule(bits=16, widths=(2,) * 8)


# ---------------------------------------------------------------------------
# Dense bit-packing: planes are transmitted packed (w bits per element),
# not one container-int per element — this is what keeps "no size
# increase" true on the wire.
# ---------------------------------------------------------------------------

def _bit_group(width: int) -> tuple[int, int]:
    """Smallest group of values whose packed bits land on a byte
    boundary: lcm(width, 8) bits = (values per group, bytes per group)."""
    import math

    L = width * 8 // math.gcd(width, 8)
    return L // width, L // 8


def pack_bits(plane: jax.Array, width: int) -> jax.Array:
    """Pack a width-bit plane into a dense uint8 byte stream (big-endian
    bit order). Pure-jnp; used by the wire format.

    Works at byte granularity: values are grouped so a group's bits fill
    whole bytes (lcm(width, 8) bits), and each output byte is assembled
    from the <= 2 + 8//width values overlapping it. Peak intermediate is
    O(n) — never the old (n, width) bit matrix, which at width=16 was a
    32x blowup over the packed payload.
    """
    flat = plane.astype(jnp.uint32).ravel()
    n = flat.shape[0]
    gv, gb = _bit_group(width)
    pad = (-n) % gv
    if pad:
        flat = jnp.pad(flat, (0, pad))
    vals = flat.reshape(-1, gv)
    out_cols = []
    for b in range(gb):
        lo_bit, hi_bit = 8 * b, 8 * b + 8
        acc = jnp.zeros((vals.shape[0],), jnp.uint32)
        for i in range(gv):
            v_lo, v_hi = i * width, (i + 1) * width
            o_lo, o_hi = max(lo_bit, v_lo), min(hi_bit, v_hi)
            if o_lo >= o_hi:
                continue
            nbits = o_hi - o_lo
            piece = (vals[:, i] >> (v_hi - o_hi)) & jnp.uint32(2**nbits - 1)
            acc = acc | (piece << (hi_bit - o_hi))
        out_cols.append(acc.astype(jnp.uint8))
    by = jnp.stack(out_cols, axis=1).ravel()
    return by[: -(-n * width // 8)]


def unpack_bits(packed: jax.Array, width: int, n_elements: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint32 values in [0, 2^w).
    Byte-granular like :func:`pack_bits`: O(n) peak intermediates.
    A payload too short for ``n_elements`` values raises (a truncated
    wire payload must never decode to silent zeros); extra trailing
    bytes are ignored."""
    need = -(-n_elements * width // 8)
    if packed.shape[0] < need:
        raise ValueError(
            f"packed payload has {packed.shape[0]} bytes, need {need} "
            f"for {n_elements} width-{width} values")
    gv, gb = _bit_group(width)
    groups = -(-n_elements // gv)
    by = packed[:need].astype(jnp.uint32)
    pad = groups * gb - need
    if pad:
        by = jnp.pad(by, (0, pad))
    bys = by.reshape(groups, gb)
    cols = []
    for i in range(gv):
        v_lo, v_hi = i * width, (i + 1) * width
        acc = jnp.zeros((groups,), jnp.uint32)
        for b in range(gb):
            lo_bit, hi_bit = 8 * b, 8 * b + 8
            o_lo, o_hi = max(lo_bit, v_lo), min(hi_bit, v_hi)
            if o_lo >= o_hi:
                continue
            nbits = o_hi - o_lo
            piece = (bys[:, b] >> (hi_bit - o_hi)) & jnp.uint32(2**nbits - 1)
            acc = acc | (piece << (v_hi - o_hi))
        cols.append(acc)
    return jnp.stack(cols, axis=1).ravel()[:n_elements]
