"""Importance calibration: measured accuracy-per-byte plane ordering.

The v1 wire ships planes in fixed stage-major order: stage s carries
plane s of EVERY tensor, so every byte of a stage buys the same
"importance" regardless of which tensor it refines. ProgDTD-style
measurement says the refinement order should be *calibrated*: truncate
one tensor's planes at a time against a calibration batch, measure the
loss delta each plane is worth, and ship planes globally in measured
gain-per-byte order.

:func:`calibrate_schedule` does exactly that, reusing the existing
truncation machinery (:func:`repro.core.quantize.truncate` over live
accumulator views — no extra quantization code):

1. build a fully-received :class:`~repro.core.plane_store.PlaneStore`
   and its float leaves;
2. for every leaf and every plane boundary ``c_m`` of its schedule,
   evaluate the calibration loss with THAT leaf truncated to ``c_m``
   bits and everything else at full precision — the marginal gain of
   plane ``m`` is the loss drop from ``c_{m-1}`` to ``c_m``;
3. convexify each tensor's per-plane gain/byte rates (merge consecutive
   planes until rates are non-increasing — planes of one tensor can
   only ship MSB-first, so a cheap valuable plane hiding behind an
   expensive dull one must be bought as a bundle);
4. merge the per-tensor bundles globally by gain/byte.

The result is a :class:`TransmissionSchedule`: a global (tensor, plane)
ship order that is MSB-first *within* each tensor (the eq.-(5) affine's
contiguous-prefix invariant — ``PlaneStore.ingest`` enforces planes
arrive in schedule order per tensor) while planes interleave freely
*across* tensors. Checkpoints partition the unit list into the same
number of "stages" as the uniform ladder, placed at (approximately) the
uniform ladder's cumulative byte marks, so timeline algebra and serving
stage semantics carry over unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.plane_store import PlaneStore
from repro.core.quantize import dequantize, truncate

FRAME_BYTES = 2  # per-unit wire frame (entropy mode flag); see core.wire


def plane_payload_bytes(shape: Sequence[int], width: int) -> int:
    """Raw packed bytes of one plane (ceil(n_elements * width / 8))."""
    n_el = int(np.prod(shape)) if len(shape) else 1
    return -(-n_el * width // 8)


@dataclasses.dataclass(frozen=True)
class TransmissionSchedule:
    """A global ordering of (tensor, plane) shipment units.

    ``units[k] = (tensor_idx, plane_idx)`` with ``plane_idx`` 0-based
    into the tensor's :class:`~repro.core.bitplanes.PlaneSchedule`;
    ``checkpoints`` is an ascending list of prefix unit counts — the
    v2 analogue of stage boundaries (clients flush + report
    "stage complete" when a checkpoint's last unit lands). The last
    checkpoint always covers every unit."""

    units: tuple[tuple[int, int], ...]
    checkpoints: tuple[int, ...]

    @property
    def n_stages(self) -> int:
        return len(self.checkpoints)

    def validate(self, plane_counts: Sequence[int]) -> None:
        """Raise unless this is a complete, MSB-first-per-tensor
        ordering of every plane of every tensor (``plane_counts[i]`` =
        tensor i's plane count) with well-formed checkpoints."""
        want = sum(plane_counts)
        if len(self.units) != want:
            raise ValueError(
                f"{len(self.units)} units for {want} planes")
        next_plane = [0] * len(plane_counts)
        for t, p in self.units:
            if not (0 <= t < len(plane_counts)):
                raise ValueError(f"unit references tensor {t} of "
                                 f"{len(plane_counts)}")
            if p != next_plane[t]:
                raise ValueError(
                    f"tensor {t}: plane {p} shipped out of order "
                    f"(expected {next_plane[t]} — schedules must be "
                    f"MSB-first within each tensor)")
            next_plane[t] += 1
        for t, got in enumerate(next_plane):
            if got != plane_counts[t]:
                raise ValueError(
                    f"tensor {t}: {got} of {plane_counts[t]} planes "
                    f"scheduled")
        if not self.checkpoints or list(self.checkpoints) != \
                sorted(set(self.checkpoints)):
            raise ValueError("checkpoints must be strictly ascending")
        if self.checkpoints[0] < 1 or self.checkpoints[-1] != len(self.units):
            raise ValueError(
                f"checkpoints must end at {len(self.units)} "
                f"(got {self.checkpoints})")

    # -- wire serialization (see core.wire v2 header) ----------------------
    def to_meta(self) -> dict:
        return {"units": [[t, p] for t, p in self.units],
                "checkpoints": list(self.checkpoints)}

    @classmethod
    def from_meta(cls, meta: Mapping) -> "TransmissionSchedule":
        return cls(units=tuple((int(t), int(p)) for t, p in meta["units"]),
                   checkpoints=tuple(int(c) for c in meta["checkpoints"]))


def uniform_schedule(model) -> TransmissionSchedule:
    """The v1 stage-major order as a TransmissionSchedule: stage s
    ships plane s of every tensor in priority order; checkpoints at
    stage ends. Encoding with this schedule reproduces the uniform
    ladder's semantics (useful as the entropy-only baseline)."""
    units: list[tuple[int, int]] = []
    checkpoints: list[int] = []
    for s in range(1, model.n_stages + 1):
        units.extend((i, s - 1) for i, _ in model.stage(s))
        checkpoints.append(len(units))
    sched = TransmissionSchedule(units=tuple(units),
                                 checkpoints=tuple(checkpoints))
    sched.validate([t.plan.schedule.n_planes for t in model.tensors])
    return sched


# ---------------------------------------------------------------------------
# sensitivity measurement
# ---------------------------------------------------------------------------

def _truncated_leaf(store: PlaneStore, idxs: list[int], bits: int):
    """One float leaf with every slot truncated to ``bits`` received
    bits (slices restacked along their slice axis). Offline path —
    eager per-slot dequant is fine here."""
    parts = []
    for i in idxs:
        t = store.slots[i]
        qt = truncate(store.quantized(i), bits)
        parts.append((t.slice_idx, t.slice_axis, dequantize(qt)))
    if len(parts) == 1 and parts[0][1] is None:
        return parts[0][2]
    axis = parts[0][1]
    parts.sort(key=lambda x: x[0])
    return jnp.stack([v for _, _, v in parts], axis=axis)


def measure_plane_gains(model, eval_loss: Callable[[dict], float],
                        ) -> dict[int, list[float]]:
    """Per-tensor marginal loss gain of each plane, measured one leaf
    at a time against everything-else-full-precision.

    ``eval_loss(leaves)`` maps a ``{path: array}`` leaf dict (same keys
    as ``PlaneStore.materialize_leaves`` on a model-built store) to a
    scalar calibration loss (lower = better). Returns
    ``{tensor_idx: [gain_plane_1, ..., gain_plane_P]}`` — slices of one
    leaf share their key's measurement (their planes ship adjacently
    anyway, and per-slice evals would multiply calibration cost by the
    slice count)."""
    store = PlaneStore.from_model(model)
    for s in range(1, model.n_stages + 1):
        store.ingest(model.stage(s))
    full = dict(store.materialize_leaves())

    by_key: dict = {}
    for i, slot in enumerate(store.slots):
        by_key.setdefault(slot.key, []).append(i)

    base = float(eval_loss(full))
    gains: dict[int, list[float]] = {}
    for key, idxs in by_key.items():
        sched = store.slots[idxs[0]].schedule
        levels = [0] + list(sched.cumulative_bits)  # c_0=0 .. c_P=bits
        losses = []
        for m in levels[:-1]:
            leaves = dict(full)
            leaves[key] = _truncated_leaf(store, idxs, m)
            losses.append(float(eval_loss(leaves)))
        losses.append(base)  # full precision == baseline
        per_plane = [max(losses[p] - losses[p + 1], 0.0)
                     for p in range(sched.n_planes)]
        for i in idxs:
            gains[i] = list(per_plane)
    return gains


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------

def _convexify(gains: Sequence[float], costs: Sequence[int]
               ) -> list[tuple[int, int, float, int]]:
    """Merge consecutive planes of ONE tensor into bundles with
    non-increasing gain/byte: a later plane scoring higher than its
    predecessor can only be bought together with it (MSB-first), so
    they fuse into one unit-sequence with the averaged rate. Returns
    ``[(p_start, p_end_exclusive, gain_sum, byte_sum), ...]``."""
    out: list[list] = []
    for p, (g, c) in enumerate(zip(gains, costs)):
        cur = [p, p + 1, float(g), int(c)]
        while out and cur[2] * out[-1][3] > out[-1][2] * cur[3]:
            prev = out.pop()
            cur = [prev[0], cur[1], prev[2] + cur[2], prev[3] + cur[3]]
        out.append(cur)
    return [tuple(b) for b in out]


def _checkpoints_at(unit_bytes: Sequence[int],
                    targets: Sequence[int]) -> tuple[int, ...]:
    """Prefix unit counts whose cumulative bytes first reach each
    target (the uniform ladder's stage byte marks), strictly
    increasing, last covering everything."""
    cum = np.cumsum(unit_bytes)
    cps: list[int] = []
    for t in targets[:-1]:
        k = int(np.searchsorted(cum, t)) + 1
        k = min(k, len(unit_bytes))
        if cps and k <= cps[-1]:
            k = cps[-1] + 1
        if k >= len(unit_bytes):
            break
        cps.append(k)
    cps.append(len(unit_bytes))
    return tuple(cps)


def _finalize(model, units: Sequence[tuple[int, int]],
              n_checkpoints: int | None) -> TransmissionSchedule:
    """Attach uniform-ladder byte-mark checkpoints to a unit order and
    validate it."""
    n_cp = n_checkpoints or model.n_stages
    uni_targets = np.cumsum(
        [model.stage_payload_bytes(s)
         + FRAME_BYTES * len(model.stage(s))
         for s in range(1, model.n_stages + 1)])
    if n_cp != model.n_stages:
        total = float(uni_targets[-1])
        uni_targets = np.asarray(
            [total * (k + 1) / n_cp for k in range(n_cp)])
    unit_bytes = [plane_payload_bytes(model.tensors[t].shape,
                                      model.tensors[t].plan.schedule.widths[p])
                  + FRAME_BYTES
                  for t, p in units]
    sched = TransmissionSchedule(
        units=tuple(units),
        checkpoints=_checkpoints_at(unit_bytes, list(uni_targets)))
    sched.validate([t.plan.schedule.n_planes for t in model.tensors])
    return sched


def build_schedule(model, gains: Mapping[int, Sequence[float]],
                   *, n_checkpoints: int | None = None
                   ) -> TransmissionSchedule:
    """Greedy gain-per-byte global ordering under the MSB-first-per-
    tensor constraint. Each tensor's planes are convexified into
    bundles (non-increasing rate), bundles merge across tensors by
    rate; checkpoints land at the uniform ladder's cumulative byte
    marks so stage-indexed consumers keep their semantics."""
    bundles: list[tuple[float, int, int, list[tuple[int, int]]]] = []
    for i, t in enumerate(model.tensors):
        sched = t.plan.schedule
        costs = [plane_payload_bytes(t.shape, w) + FRAME_BYTES
                 for w in sched.widths]
        g = list(gains.get(i, [0.0] * sched.n_planes))
        if len(g) != sched.n_planes:
            raise ValueError(
                f"tensor {i}: {len(g)} gains for {sched.n_planes} planes")
        for (p0, p1, gsum, csum) in _convexify(g, costs):
            rate = gsum / max(csum, 1)
            bundles.append((rate, i, p0,
                            [(i, p) for p in range(p0, p1)]))
    # stable descending-rate merge; (tensor, plane) tie-break keeps the
    # order deterministic and per-tensor bundles in MSB-first order
    # (convexified rates are non-increasing within a tensor; strictly
    # equal rates fall back to plane order)
    bundles.sort(key=lambda b: (-b[0], b[1], b[2]))
    units: list[tuple[int, int]] = []
    for _, _, _, us in bundles:
        units.extend(us)
    return _finalize(model, units, n_checkpoints)


def greedy_schedule(model, eval_loss: Callable[[dict], float],
                    *, n_checkpoints: int | None = None
                    ) -> TransmissionSchedule:
    """Context-aware greedy forward selection: walk the refinement
    ladder from all-tensors-at-zero-bits, and at every step evaluate
    each leaf's NEXT plane against the CURRENT partial model, shipping
    the one with the best measured loss drop per byte.

    One-leaf-at-a-time marginal gains (:func:`measure_plane_gains`)
    price every plane against a full-precision context, which overvalues
    deep planes of important tensors: the greedy merge then spends an
    early budget finishing one tensor while others sit at zero received
    bits — and a leaf at m=0 dequantizes to its range centre, which is
    catastrophic. Evaluating candidates in the *current* context prices
    exactly the decision the scheduler makes, so broad MSB coverage
    emerges naturally (while a plane the model provably doesn't care
    about still sinks to the tail). Slices of one leaf advance together,
    like everywhere else in calibration.

    Greedy-per-byte alone has one failure mode left: *complementary*
    tensors. Refining only one of two jointly-required tensors measures
    ~zero gain, so pure greedy can postpone BOTH behind cheap trivia —
    and the effect recurs at every refinement level, not just the first
    plane. Selection is therefore wave-banded: a leaf may run at most
    one level ahead of the slowest unfinished leaf, and measured
    gain-per-byte only decides the order *within* the current wave.
    Each wave then completes in measured-best-first order, so at any
    byte budget the stream carries the uniform ladder's coverage plus
    the most valuable planes of the next level — never a deep dive into
    one tensor while another sits broken."""
    store = PlaneStore.from_model(model)
    for s in range(1, model.n_stages + 1):
        store.ingest(model.stage(s))

    by_key: dict = {}
    for i, slot in enumerate(store.slots):
        by_key.setdefault(slot.key, []).append(i)
    keys = list(by_key)

    leaf_cache: dict = {}

    def leaf_at(key, level: int):
        if (key, level) not in leaf_cache:
            sched = store.slots[by_key[key][0]].schedule
            bits = ([0] + list(sched.cumulative_bits))[level]
            leaf_cache[(key, level)] = _truncated_leaf(
                store, by_key[key], bits)
        return leaf_cache[(key, level)]

    def level_bytes(key, level: int) -> int:
        # on-wire cost of shipping plane `level` of every slice of key
        total = 0
        for i in by_key[key]:
            t = model.tensors[i]
            total += plane_payload_bytes(
                t.shape, t.plan.schedule.widths[level]) + FRAME_BYTES
        return total

    levels = {key: 0 for key in keys}
    current = {key: leaf_at(key, 0) for key in keys}
    cur_loss = float(eval_loss(current))
    units: list[tuple[int, int]] = []
    while True:
        active = [k for k in keys
                  if levels[k] < store.slots[by_key[k][0]].schedule.n_planes]
        if not active:
            break
        wave = min(levels[k] for k in active)
        active = [k for k in active if levels[k] == wave]
        best = None
        for key in active:
            cand = dict(current)
            cand[key] = leaf_at(key, levels[key] + 1)
            loss = float(eval_loss(cand))
            rate = (cur_loss - loss) / level_bytes(key, levels[key])
            if best is None or rate > best[0]:
                best = (rate, key, loss)
        _, key, loss = best
        units.extend((i, levels[key]) for i in by_key[key])
        levels[key] += 1
        current[key] = leaf_at(key, levels[key])
        cur_loss = loss
    return _finalize(model, units, n_checkpoints)


def weight_sse_schedule(model, *, n_checkpoints: int | None = None
                        ) -> TransmissionSchedule:
    """Task-data-free proxy calibration: score each truncation by its
    summed squared weight error against the fully-received model.

    This is the serving-side default when no calibration batch exists
    (e.g. an un-finetuned bench model): SSE prices a plane by how much
    signal it restores, which already separates wide-range / large
    tensors from trivia. Under an additive per-leaf loss a leaf's
    marginal doesn't depend on the context it's measured in, so the
    greedy ladder would buy nothing — and SSE against the full model
    has a closed form: truncating at plane boundary p drops exactly the
    value carried by planes p..P-1 while the affine intercept cancels
    in the difference, so ``SSE(p) = Σ (scale · Σ_{j>=p} plane_j <<
    shift_j)²``. Computed straight off the server-side
    ``TensorPlanes.planes`` in one reverse numpy sweep — no PlaneStore
    build, no ingest launches, no jit (on the paper-regime bench models
    the eval-loss route costs minutes; this is seconds). Each slice of
    a sliced bank scores with its own range, matching the per-unit
    granularity the v2 wire ships at."""
    from repro.core.quantize import affine_span

    gains: dict[int, list[float]] = {}
    for i, t in enumerate(model.tensors):
        sched = t.plan.schedule
        bits = sched.bits
        cum = list(sched.cumulative_bits)  # c_1 .. c_P (c_P == bits)
        scale = np.asarray(affine_span(t.lo, t.hi),
                           np.float64) * 0.5 ** bits
        # float64 holds bits <= 16 plane arithmetic exactly
        resid = np.zeros(t.shape if t.shape else (), np.float64)
        sse = [0.0] * (sched.n_planes + 1)
        for p in range(sched.n_planes - 1, -1, -1):
            resid = resid + (np.asarray(t.planes[p]).astype(np.float64)
                             * 2.0 ** (bits - cum[p]))
            sse[p] = float(np.sum((scale * resid) ** 2))
        gains[i] = [max(sse[p] - sse[p + 1], 0.0)
                    for p in range(sched.n_planes)]
    return build_schedule(model, gains, n_checkpoints=n_checkpoints)


def calibrate_schedule(model, eval_loss: Callable[[dict], float],
                       *, n_checkpoints: int | None = None,
                       method: str = "greedy") -> TransmissionSchedule:
    """Measure + build in one call (see module docstring).

    ``method="greedy"`` (default) runs :func:`greedy_schedule`'s
    context-aware forward selection; ``method="marginal"`` runs the
    cheaper one-leaf-at-a-time :func:`measure_plane_gains` +
    :func:`build_schedule` pipeline."""
    if method == "greedy":
        return greedy_schedule(model, eval_loss,
                               n_checkpoints=n_checkpoints)
    if method == "marginal":
        gains = measure_plane_gains(model, eval_loss)
        return build_schedule(model, gains, n_checkpoints=n_checkpoints)
    raise ValueError(f"unknown calibration method {method!r}")
