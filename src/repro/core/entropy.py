"""Byte-aligned entropy codec for bit-plane payloads (wire v2).

High planes of affine-quantized weights are heavily skewed: floor
quantization (eq. 2) maps a roughly centered weight distribution into
the middle of ``[0, 2^bits)``, so the MSB plane is mostly one value and
near-MSB planes carry far less than ``width`` bits of real entropy per
element. The v2 wire exploits that with a per-plane choice between
three byte-aligned encodings of the *packed* plane bytes
(:func:`repro.core.bitplanes.pack_bits` output):

* ``MODE_RAW``  — the packed bytes verbatim;
* ``MODE_RLE``  — PackBits-style run-length coding (control byte:
  ``c < 128`` copies ``c+1`` literals, ``c >= 128`` repeats the next
  byte ``c - 126`` times) — wins on long constant runs;
* ``MODE_RANS`` — order-0 static rANS over bytes (12-bit
  probabilities, 16-bit renormalization, lane-interleaved so encode
  and decode are numpy-vectorized across lanes) — wins on skewed but
  run-free planes.

:func:`encode` measures all candidates and returns the smallest, so a
coded body is NEVER larger than the raw packed plane; the 2-byte
per-unit frame the wire adds on top is the total worst-case overhead.
Everything here is host-side numpy — the decoded bytes feed the
existing ``plane_or_segments`` ingest unchanged, and reconstruction is
bit-exact (pinned by property tests).
"""
from __future__ import annotations

import struct

import numpy as np

MODE_RAW = 0
MODE_RLE = 1
MODE_RANS = 2
MODES = (MODE_RAW, MODE_RLE, MODE_RANS)

# rANS parameters: 12-bit quantized probabilities, uint64 lane states
# kept in [2^16, 2^32) with 16-bit renormalization. With these bounds
# each symbol emits/reads exactly 0 or 1 u16 per step (see _rans_*).
PROB_BITS = 12
_M = 1 << PROB_BITS
_STATE_LO = 1 << 16
_MAX_LANES = 255  # lane count is a single header byte


# ---------------------------------------------------------------------------
# PackBits-style RLE
# ---------------------------------------------------------------------------

def _byte_runs(data: np.ndarray):
    """(starts, lengths) of maximal constant runs."""
    change = np.flatnonzero(data[1:] != data[:-1]) + 1
    starts = np.concatenate(([0], change))
    ends = np.concatenate((change, [data.size]))
    return starts, ends - starts


def _rle_encode(data: np.ndarray) -> bytes | None:
    """PackBits-style encode; None when clearly not worth attempting
    (run structure too fine — the Python sweep over runs would cost
    more than the bytes it could save)."""
    n = data.size
    if n == 0:
        return None
    starts, lengths = _byte_runs(data)
    if starts.size > max(64, n // 3):
        return None
    out = bytearray()
    lit_start = None  # start of the pending literal block

    def flush_literals(upto: int) -> None:
        nonlocal lit_start
        if lit_start is None:
            return
        pos = lit_start
        while pos < upto:
            c = min(128, upto - pos)
            out.append(c - 1)
            out.extend(data[pos:pos + c].tobytes())
            pos += c
        lit_start = None

    for s, ln in zip(starts.tolist(), lengths.tolist()):
        if ln >= 3:
            flush_literals(s)
            val = int(data[s])
            rem = ln
            while rem >= 2:
                c = min(129, rem)
                out.append(128 + c - 2)
                out.append(val)
                rem -= c
            if rem:  # 1-byte tail of a long run joins the next literals
                lit_start = s + ln - 1
        else:
            if lit_start is None:
                lit_start = s
    flush_literals(n)
    return bytes(out)


def _rle_decode(body: bytes, n_bytes: int) -> bytes:
    data = np.frombuffer(body, np.uint8)
    out = np.empty(n_bytes, np.uint8)
    i = pos = 0
    while pos < n_bytes:
        if i >= data.size:
            raise ValueError("RLE body truncated")
        c = int(data[i])
        i += 1
        if c < 128:
            ln = c + 1
            if i + ln > data.size or pos + ln > n_bytes:
                raise ValueError("RLE literal overruns payload")
            out[pos:pos + ln] = data[i:i + ln]
            i += ln
        else:
            ln = c - 126
            if i >= data.size or pos + ln > n_bytes:
                raise ValueError("RLE run overruns payload")
            out[pos:pos + ln] = data[i]
            i += 1
        pos += ln
    if i != data.size:
        raise ValueError("trailing bytes after RLE payload")
    return out.tobytes()


# ---------------------------------------------------------------------------
# order-0 static rANS, lane-interleaved
# ---------------------------------------------------------------------------

def _normalize_freqs(counts: np.ndarray) -> np.ndarray:
    """Scale byte counts to a (256,) table summing to exactly ``_M``,
    every present symbol >= 1."""
    total = int(counts.sum())
    present = np.flatnonzero(counts)
    f = np.maximum(
        1, (counts[present].astype(np.float64) * _M / total)
        .astype(np.int64))
    diff = _M - int(f.sum())
    while diff != 0:
        if diff > 0:
            f[int(np.argmax(f))] += diff
            diff = 0
        else:
            i = int(np.argmax(f))
            take = min(-diff, int(f[i]) - 1)
            if take == 0:
                raise AssertionError("cannot normalize frequency table")
            f[i] -= take
            diff += take
    freqs = np.zeros(256, np.int64)
    freqs[present] = f
    return freqs


def _n_lanes(n: int) -> int:
    return int(np.clip(n // 4096, 1, _MAX_LANES))


def _rans_overhead(n_sym: int, n_lanes: int) -> int:
    return 3 + 3 * n_sym + 8 * n_lanes


def _rans_encode(data: np.ndarray) -> bytes | None:
    n = data.size
    if n == 0:
        return None
    counts = np.bincount(data, minlength=256).astype(np.int64)
    freqs = _normalize_freqs(counts)
    cum = np.zeros(256, np.int64)
    cum[1:] = np.cumsum(freqs)[:-1]
    L = _n_lanes(n)
    f_all = freqs[data].astype(np.uint64)
    c_all = cum[data].astype(np.uint64)
    per_lane = np.array([(n - j + L - 1) // L for j in range(L)])
    T = int(per_lane.max())
    # (T, L) symbol matrices in REVERSE order per lane (rANS encodes
    # back-to-front so the decoder reads front-to-back); lane j owns
    # elements j, j+L, j+2L, ...
    F = np.ones((T, L), np.uint64)
    C = np.zeros((T, L), np.uint64)
    A = np.zeros((T, L), bool)
    for j in range(L):
        idx = np.arange(j, n, L)
        k = idx.size
        F[:k, j] = f_all[idx][::-1]
        C[:k, j] = c_all[idx][::-1]
        A[:k, j] = True
    x = np.full(L, _STATE_LO, np.uint64)
    emitted: list[list[int]] = [[] for _ in range(L)]
    u16 = np.uint64(16)
    u20 = np.uint64(20)
    pb = np.uint64(PROB_BITS)
    mask16 = np.uint64(0xFFFF)
    for t in range(T):
        act = A[t]
        f = F[t]
        # invariant x < 2^32; renorm target (f << 20) >= 2^20, so one
        # 16-bit emit always suffices (post-shift x < 2^16 <= f << 20)
        emit = act & (x >= (f << u20))
        if emit.any():
            for j in np.flatnonzero(emit):
                emitted[j].append(int(x[j] & mask16))
            x[emit] >>= u16
        xa = x[act]
        fa = f[act]
        x[act] = ((xa // fa) << pb) + (xa % fa) + C[t][act]
    present = np.flatnonzero(freqs)
    out = bytearray()
    out += struct.pack("<BH", L, present.size)
    for s in present.tolist():
        out += struct.pack("<BH", s, int(freqs[s]) & 0xFFFF)  # _M -> 0
    streams = []
    for j in range(L):
        # stream bytes in DECODE read order = reverse of emission
        vals = np.asarray(emitted[j][::-1], dtype="<u2")
        streams.append(vals.tobytes())
        out += struct.pack("<II", int(x[j]), len(streams[-1]))
    for s_bytes in streams:
        out += s_bytes
    return bytes(out)


def _rans_decode(body: bytes, n_bytes: int) -> bytes:
    if len(body) < 3:
        raise ValueError("rANS body truncated")
    L, n_sym = struct.unpack_from("<BH", body, 0)
    off = 3
    freqs = np.zeros(256, np.int64)
    for _ in range(n_sym):
        s, fq = struct.unpack_from("<BH", body, off)
        off += 3
        freqs[s] = fq if fq else _M  # 0 encodes the full-table freq _M
    if int(freqs.sum()) != _M:
        raise ValueError("rANS frequency table does not sum to 2^PROB_BITS")
    cum = np.zeros(256, np.int64)
    cum[1:] = np.cumsum(freqs)[:-1]
    present = np.flatnonzero(freqs)
    slot_sym = np.repeat(present, freqs[present]).astype(np.uint8)
    x = np.zeros(L, np.uint64)
    lane_off = np.zeros(L, np.int64)
    lane_end = np.zeros(L, np.int64)
    for j in range(L):
        st, ln = struct.unpack_from("<II", body, off)
        off += 8
        x[j] = st
        lane_off[j] = ln  # temp: lengths
    start = off
    for j in range(L):
        ln = int(lane_off[j])
        lane_off[j] = start
        lane_end[j] = start + ln
        start += ln
    if start != len(body):
        raise ValueError("rANS streams do not fill the body")
    data = np.frombuffer(body, np.uint8)
    out = np.empty(n_bytes, np.uint8)
    per_lane = np.array([(n_bytes - j + L - 1) // L for j in range(L)])
    T = int(per_lane.max()) if n_bytes else 0
    maskM = np.uint64(_M - 1)
    u16 = np.uint64(16)
    pb = np.uint64(PROB_BITS)
    lo = np.uint64(_STATE_LO)
    freqs_u = freqs.astype(np.uint64)
    cum_u = cum.astype(np.uint64)
    for t in range(T):
        act = t < per_lane
        slot = x & maskM
        sym = slot_sym[slot.astype(np.int64)]
        js = np.flatnonzero(act)
        out[js + t * L] = sym[js]
        f = freqs_u[sym]
        c = cum_u[sym]
        nx = f * (x >> pb) + slot - c
        x = np.where(act, nx, x)
        need = act & (x < lo)
        for j in np.flatnonzero(need):
            if lane_off[j] + 2 > lane_end[j]:
                raise ValueError("rANS lane stream exhausted")
            v = int(data[lane_off[j]]) | (int(data[lane_off[j] + 1]) << 8)
            x[j] = (x[j] << u16) | np.uint64(v)
            lane_off[j] += 2
    if not np.array_equal(lane_off, lane_end):
        raise ValueError("rANS lane stream not fully consumed")
    return out.tobytes()


# ---------------------------------------------------------------------------
# mode selection
# ---------------------------------------------------------------------------

def encode(data: bytes) -> tuple[int, bytes]:
    """Encode one packed plane payload; returns ``(mode, body)`` with
    the smallest body among raw / RLE / rANS — ``len(body) <=
    len(data)`` ALWAYS (raw is always a candidate)."""
    arr = np.frombuffer(data, np.uint8)
    best_mode, best = MODE_RAW, bytes(data)
    rle = _rle_encode(arr)
    if rle is not None and len(rle) < len(best):
        best_mode, best = MODE_RLE, rle
    if arr.size:
        counts = np.bincount(arr, minlength=256)
        p = counts[counts > 0] / arr.size
        est_bits = float(-(p * np.log2(p)).sum()) * arr.size
        est = est_bits / 8 + _rans_overhead(p.size, _n_lanes(arr.size))
        if est < len(best):
            rans = _rans_encode(arr)
            if rans is not None and len(rans) < len(best):
                best_mode, best = MODE_RANS, rans
    return best_mode, best


def decode(mode: int, body: bytes, n_bytes: int) -> bytes:
    """Exact inverse of :func:`encode` for a known decoded size."""
    if mode == MODE_RAW:
        if len(body) != n_bytes:
            raise ValueError(
                f"raw payload is {len(body)} bytes, expected {n_bytes}")
        return bytes(body)
    if mode == MODE_RLE:
        return _rle_decode(body, n_bytes)
    if mode == MODE_RANS:
        return _rans_decode(body, n_bytes)
    raise ValueError(f"unknown entropy mode {mode}")
