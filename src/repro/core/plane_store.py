"""PlaneStore: the single device-resident receiver runtime (eqs. 4+5).

Every client of progressive transmission — the pytree receiver
(``core/progressive.ReceiverState``), the byte-stream client
(``transmission/client.ProgressiveClient``), and the quantized-resident
serving path (``serving/quantized``) — used to carry its own copy of
the OR/shift/stacking arithmetic. They now all sit on this one store.

Layout
------
All tensors sharing a container dtype live in ONE flat 1-D uint buffer;
each tensor occupies a block-aligned segment ``[offset, offset+size)``
(padding between segments is dead space, < ``block`` elements per
tensor). Per-tensor metadata (shape, plane schedule, quantization
range, slice info) lives in :class:`TensorSlot` views.

Upgrades (eq. 4)
----------------
``ingest([(tensor_idx, plane), ...])`` assembles one flat plane buffer
plus a per-block int32 shift table and issues ONE batched
``plane_or_segments`` Pallas launch per container dtype — O(1) in the
number of tensors, vs. the old one-``pallas_call``-per-tensor loop.
Block alignment is what makes the per-block shift well defined: a block
never straddles two tensors.

Materialization (eq. 5)
-----------------------
``materialize()`` is *incremental*: only tensors whose accumulator
changed since the last call are re-dequantized; unchanged float leaves
come out of a cache (same array objects — downstream jit sees identical
buffer donations). Sliced tensors (expert banks) are restacked along
their slice axis only when one of their slices is dirty.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core.bitplanes import PlaneSchedule
from repro.core.quantize import (QuantizedTensor, affine_span,
                                 container_dtype, dequant_affine,
                                 dequant_constants, dequantize_buffers)
from repro.kernels import ops

# One grid step of plane_or_segments: 8 sublanes x 128 lanes.
DEFAULT_BLOCK = 1024


@functools.partial(jax.jit, static_argnames=("segs",))
def _scatter_segments(buf: jax.Array, out: jax.Array,
                      segs: tuple) -> jax.Array:
    """Write compact OR results back into the flat buffer. ``segs`` is
    ``((buf_offset, compact_pos, length), ...)``. One jitted call: the
    update chain fuses into a single new buffer (one allocation per
    round, not one full copy per segment as eager .at[].set would pay).
    NOT donated: ``copy()`` stores share buffer objects, so donating
    here would invalidate a sibling store's accumulator."""
    for off, pos, length in segs:
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, jax.lax.dynamic_slice_in_dim(out, pos, length), off, axis=0)
    return buf


def next_plane_shift(schedule: PlaneSchedule, received: int) -> int:
    """Eq. (4) shift for the next arriving plane: after ``received``
    planes, plane ``received+1`` lands at ``bits - c_{received+1}``.
    The ONLY place this arithmetic lives."""
    if received >= schedule.n_planes:
        raise ValueError(
            f"all {schedule.n_planes} planes already received")
    return schedule.bits - schedule.cumulative_bits[received]


def received_bits(schedule: PlaneSchedule, received: int) -> int:
    """Effective precision m = sum of the first ``received`` widths."""
    return schedule.cumulative_bits[received - 1] if received > 0 else 0


def _entries_from_model(model, indices: Sequence[int] | None = None
                        ) -> list[dict]:
    """Per-tensor descriptor dicts from a server-side ProgressiveModel
    (keys are pytree paths) — the pre-layout form both the flat
    :class:`PlaneStore` and the per-shard sub-stores of
    :class:`ShardedPlaneStore` build from."""
    tensors = (model.tensors if indices is None
               else [model.tensors[i] for i in indices])
    return [{"key": t.path, "schedule": t.plan.schedule, "lo": t.lo,
             "hi": t.hi, "shape": tuple(t.shape),
             "orig_dtype": t.orig_dtype, "slice_axis": t.slice_axis,
             "slice_idx": t.slice_idx} for t in tensors]


def _entries_from_wire_meta(meta: Mapping) -> list[dict]:
    """Per-tensor descriptor dicts from a decoded wire header (keys are
    path strings)."""
    return [{"key": t["path"],
             "schedule": PlaneSchedule(bits=t["bits"],
                                       widths=tuple(t["widths"])),
             "lo": jnp.float32(t["lo"]), "hi": jnp.float32(t["hi"]),
             "shape": tuple(t["shape"]), "orig_dtype": np.dtype(t["dtype"]),
             "slice_axis": t.get("slice_axis"),
             "slice_idx": t.get("slice_idx", 0)} for t in meta["tensors"]]


@dataclasses.dataclass(frozen=True)
class TensorSlot:
    """Static per-tensor metadata: a view descriptor into a flat buffer."""

    key: Any                  # opaque leaf key (tuple path or path string)
    schedule: PlaneSchedule
    lo: jax.Array
    hi: jax.Array
    shape: tuple
    orig_dtype: Any
    offset: int               # element offset within the dtype's buffer
    size: int                 # n elements
    padded: int               # block-aligned span (size rounded up)
    slice_axis: int | None = None
    slice_idx: int = 0

    @property
    def bits(self) -> int:
        return self.schedule.bits

    @property
    def container(self):
        return container_dtype(self.bits)


class PlaneStore:
    """Device-resident accumulators for one progressive model.

    ``device`` commits every buffer (and every ingest upload) to one
    specific device — the per-shard sub-stores of
    :class:`ShardedPlaneStore` use this so each shard's planes are
    OR-ed on the device that owns them (shard-local ingest, no
    replicated OR). ``None`` keeps jax's default placement."""

    def __init__(self, slots: list[TensorSlot], *, block: int = DEFAULT_BLOCK,
                 device=None):
        self.block = block
        self.slots = slots
        self.device = device
        self.received = [0] * len(slots)
        # dtype name -> flat uint buffer (length: multiple of block)
        self.buffers: dict[str, jax.Array] = {}
        sizes: dict[str, int] = {}
        for t in slots:
            dt = np.dtype(t.container).name
            sizes[dt] = max(sizes.get(dt, 0), t.offset + t.padded)
        for dt, n in sizes.items():
            buf = jnp.zeros((n,), dtype=np.dtype(dt))
            if device is not None:
                buf = jax.device_put(buf, device)
            self.buffers[dt] = buf
        self._dirty: set[int] = set(range(len(slots)))
        self._leaf_cache: dict[Any, jax.Array] = {}
        self._qleaf_cache: dict[Any, QuantizedTensor] = {}
        self._qtrunc_cache: dict[tuple, QuantizedTensor] = {}
        self._acc_cache: dict[int, jax.Array] = {}
        # stacked eq.-(5) constants per batch of slot indices; lo/hi/
        # bits never change after the header, so never invalidated
        self._consts_cache: dict[tuple, tuple] = {}
        # per-key quantized-view affine constants (placed lo/hi/scale +
        # host lo/span mirrors); m-independent, so — unlike
        # _qleaf_cache — survives every ingest
        self._qmeta_cache: dict[Any, dict] = {}

    # -- construction ------------------------------------------------------
    @staticmethod
    def _layout(entries, block):
        """Assign (offset, padded) per entry, grouped by container dtype."""
        cursors: dict[str, int] = {}
        out = []
        for e in entries:
            dt = np.dtype(container_dtype(e["schedule"].bits)).name
            size = int(np.prod(e["shape"])) if e["shape"] else 1
            padded = -(-size // block) * block
            off = cursors.get(dt, 0)
            cursors[dt] = off + padded
            out.append((off, size, padded))
        return out

    @classmethod
    def _from_entries(cls, entries: list[dict], *,
                      block: int = DEFAULT_BLOCK, device=None) -> "PlaneStore":
        """Build from per-tensor descriptor dicts (no layout yet):
        key/schedule/lo/hi/shape/orig_dtype[/slice_axis/slice_idx]."""
        layout = cls._layout(entries, block)
        slots = [
            TensorSlot(
                key=e["key"], schedule=e["schedule"], lo=e["lo"], hi=e["hi"],
                shape=tuple(e["shape"]), orig_dtype=e["orig_dtype"],
                offset=off, size=size, padded=padded,
                slice_axis=e.get("slice_axis"),
                slice_idx=e.get("slice_idx", 0),
            )
            for e, (off, size, padded) in zip(entries, layout)
        ]
        return cls(slots, block=block, device=device)

    @classmethod
    def from_model(cls, model, *, block: int = DEFAULT_BLOCK,
                   indices: Sequence[int] | None = None) -> "PlaneStore":
        """Build from a server-side :class:`ProgressiveModel` (keys are
        pytree paths). ``indices`` restricts the store to a subset of
        the model's tensors (slot i is then ``model.tensors[indices[i]]``
        — a single-tensor store allocates one tensor's buffer, not the
        whole model's)."""
        return cls._from_entries(_entries_from_model(model, indices),
                                 block=block)

    @classmethod
    def from_wire_meta(cls, meta: Mapping, *, block: int = DEFAULT_BLOCK
                       ) -> "PlaneStore":
        """Build from a decoded wire header (keys are path strings)."""
        return cls._from_entries(_entries_from_wire_meta(meta), block=block)

    def copy(self) -> "PlaneStore":
        """Cheap snapshot: buffers are immutable jax arrays, so sharing
        them is safe; bookkeeping is shallow-copied. Lets the functional
        ``ReceiverState.receive`` keep value semantics for free."""
        new = object.__new__(PlaneStore)
        new.block = self.block
        new.slots = self.slots
        new.device = self.device
        new.received = list(self.received)
        new.buffers = dict(self.buffers)
        new._dirty = set(self._dirty)
        new._leaf_cache = dict(self._leaf_cache)
        new._qleaf_cache = dict(self._qleaf_cache)
        new._qtrunc_cache = dict(self._qtrunc_cache)
        new._acc_cache = dict(self._acc_cache)
        new._consts_cache = dict(self._consts_cache)
        new._qmeta_cache = dict(self._qmeta_cache)
        return new

    # -- views -------------------------------------------------------------
    def _slice_acc(self, i: int) -> jax.Array:
        t = self.slots[i]
        dt = np.dtype(t.container).name
        return self.buffers[dt][t.offset:t.offset + t.size].reshape(t.shape)

    def acc(self, i: int) -> jax.Array:
        """Tensor i's accumulator: a view into the flat buffer. Cached
        until the tensor's next ingest, so eager hot paths (per-token
        ``QuantizedLinearState.matmul``) don't re-slice per call. The
        cache fills only on explicit ``acc`` access — one-shot readers
        (materialize) slice without caching, so they don't pin a second
        copy of every accumulator."""
        got = self._acc_cache.get(i)
        if got is None:
            got = self._slice_acc(i)
            self._acc_cache[i] = got
        return got

    def quantized(self, i: int) -> QuantizedTensor:
        t = self.slots[i]
        return QuantizedTensor(q=self._slice_acc(i), lo=t.lo, hi=t.hi,
                               bits=t.bits, orig_dtype=t.orig_dtype)

    def effective_bits(self, i: int) -> int:
        return received_bits(self.slots[i].schedule, self.received[i])

    @property
    def n_tensors(self) -> int:
        return len(self.slots)

    def resident_bytes(self) -> int:
        return sum(b.size * b.dtype.itemsize for b in self.buffers.values())

    def fingerprint(self) -> dict[str, int]:
        """CRC32 of each flat accumulator buffer's bytes, keyed by
        container dtype. Two stores with the same layout have equal
        fingerprints iff their accumulator state is bit-identical —
        the cheap audit the fault-tolerance tests use to prove that a
        quarantined-and-repaired stream matches the clean stream at
        every checkpoint (and that a force-ingested corrupt plane
        diverges forever). Pulls buffers to host; debugging/audit use,
        not a hot path."""
        return {dt: int(zlib.crc32(np.asarray(buf).tobytes()))
                for dt, buf in sorted(self.buffers.items())}

    # -- eq. (4): batched upgrade -----------------------------------------
    def ingest(self, items: Sequence[tuple[int, jax.Array]]) -> None:
        """OR a shipment of planes into the store. ``items`` holds
        ``(tensor_idx, plane_values)`` pairs; each plane is the *next*
        plane of its tensor's schedule (the wire delivers them in
        order). One ``plane_or_segments`` launch per container dtype per
        round; a shipment carrying several planes of the same tensor is
        split into rounds (distinct shifts for the same segment can't
        share one OR).

        The whole shipment is validated up front, so a bad item leaves
        the store untouched — callers (e.g. the client's ``_flush``)
        may safely retry the identical shipment after a failure."""
        pending = list(items)
        counts: dict[int, int] = {}
        for idx, plane in pending:
            t = self.slots[idx]
            n = int(np.prod(np.shape(plane)) or 1)
            if n != t.size:
                raise ValueError(
                    f"plane for tensor {idx} has {n} elements, "
                    f"expected {t.size}")
            counts[idx] = counts.get(idx, 0) + 1
        for idx, c in counts.items():
            have, total = self.received[idx], self.slots[idx].schedule.n_planes
            if have + c > total:
                raise ValueError(
                    f"tensor {idx}: {have} planes received + {c} arriving "
                    f"exceeds schedule of {total}")
        while pending:
            round_items: dict[int, jax.Array] = {}
            rest = []
            for idx, plane in pending:
                if idx in round_items:
                    rest.append((idx, plane))
                else:
                    round_items[idx] = plane
            self._ingest_round(round_items)
            pending = rest

    def _ingest_round(self, items: dict[int, jax.Array]) -> None:
        """One OR round: the accumulator never round-trips through the
        host. Touched segments are gathered into a *compact* buffer
        (cheap XLA slices/concat, no kernel launches), the single
        ``plane_or_segments`` launch sweeps only those blocks, and the
        results go back via one fused scatter — a sparse shipment's OR
        work and transfers are O(touched bytes); the write-back is a
        single whole-buffer update (immutable arrays), not one per
        segment."""
        if _obs.enabled():
            reg = _obs.get_registry()
            reg.counter("store_or_rounds_total",
                        "batched plane-OR rounds").inc()
            reg.histogram("store_or_round_planes",
                          "planes per OR round").observe(len(items))
        by_dtype: dict[str, list[int]] = {}
        for idx in items:
            dt = np.dtype(self.slots[idx].container).name
            by_dtype.setdefault(dt, []).append(idx)
        for dt, idxs in by_dtype.items():
            buf = self.buffers[dt]
            idxs.sort(key=lambda i: self.slots[i].offset)
            total = sum(self.slots[i].padded for i in idxs)
            full = total == buf.shape[0]
            shifts = np.empty((total // self.block,), np.int32)
            pos = 0
            for idx in idxs:
                t = self.slots[idx]
                sh = next_plane_shift(t.schedule, self.received[idx])
                shifts[pos // self.block:(pos + t.padded) // self.block] = sh
                pos += t.padded
            shifts = (jnp.asarray(shifts) if self.device is None
                      else jax.device_put(shifts, self.device))
            # Plane assembly: on an accelerator, keep device-resident
            # planes (engine path) on device — pad+concat is cheap XLA
            # work and avoids a blocking D2H+H2D round trip. On the CPU
            # backend host assembly is the DMA landing zone (one memcpy
            # pass + one upload) and measurably faster. The ACCUMULATOR
            # never leaves the device on either path.
            if jax.default_backend() != "cpu":
                parts = []
                for idx in idxs:
                    t = self.slots[idx]
                    p = jnp.asarray(items[idx]).reshape(-1).astype(buf.dtype)
                    if t.padded != t.size:
                        p = jnp.pad(p, (0, t.padded - t.size))
                    parts.append(p)
                plane = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
                if self.device is not None:
                    plane = jax.device_put(plane, self.device)
            else:
                plane_np = np.zeros((total,), dtype=buf.dtype)
                pos = 0
                for idx in idxs:
                    t = self.slots[idx]
                    plane_np[pos:pos + t.size] = (
                        np.asarray(items[idx]).reshape(-1))
                    pos += t.padded
                plane = (jnp.asarray(plane_np) if self.device is None
                         else jax.device_put(plane_np, self.device))
            if full:
                # Whole buffer touched (the common full-stage upgrade):
                # segments are dense by layout, no gather/scatter needed.
                self.buffers[dt] = ops.plane_or_segments(
                    buf, plane, shifts, block=self.block)
            else:
                # Sparse shipment: sweep only the touched blocks —
                # O(touched bytes), not O(whole per-dtype buffer).
                compact = (buf[self.slots[idxs[0]].offset:
                               self.slots[idxs[0]].offset + total]
                           if len(idxs) == 1 else
                           jnp.concatenate([
                               buf[self.slots[i].offset:
                                   self.slots[i].offset + self.slots[i].padded]
                               for i in idxs]))
                out = ops.plane_or_segments(
                    compact, plane, shifts, block=self.block)
                segs, pos = [], 0
                for idx in idxs:
                    t = self.slots[idx]
                    segs.append((t.offset, pos, t.padded))
                    pos += t.padded
                self.buffers[dt] = _scatter_segments(buf, out, tuple(segs))
        for idx in items:
            self.received[idx] += 1
            self._dirty.add(idx)
            self._acc_cache.pop(idx, None)
            key = self.slots[idx].key
            self._leaf_cache.pop(key, None)
            self._qleaf_cache.pop(key, None)
            for tk in [t for t in self._qtrunc_cache if t[0] == key]:
                self._qtrunc_cache.pop(tk)

    # -- eq. (5): incremental materialization ------------------------------
    def _by_key(self) -> dict[Any, list[int]]:
        by_key: dict[Any, list[int]] = {}
        for i, t in enumerate(self.slots):
            by_key.setdefault(t.key, []).append(i)
        return by_key

    def _refresh_fp_leaves(self, stale: list[tuple[Any, list[int]]]) -> None:
        """Batch-dequantize every slot of the given keys and refill the
        leaf cache. The whole set is one :func:`dequantize_batch` call —
        O(1) host dispatches however many tensors an upgrade dirtied —
        with the stacked eq.-(5) constants cached across upgrades (lo/
        hi/bits are fixed at the header). This is what keeps an
        ``resident='fp'`` upgrade's refresh an enqueue, not a stall."""
        if not stale:
            return
        jobs = [i for _, idxs in stale for i in idxs]
        if _obs.enabled():
            reg = _obs.get_registry()
            reg.counter("store_refresh_dispatches_total",
                        "batched eq.-(5) refresh dispatches").inc()
            reg.histogram("store_refresh_slots",
                          "tensor slots per refresh dispatch").observe(
                              len(jobs))
        consts = self._consts_cache.get(tuple(jobs))
        if consts is None:
            consts = dequant_constants([self.slots[i].lo for i in jobs],
                                       [self.slots[i].hi for i in jobs],
                                       [self.slots[i].bits for i in jobs])
            self._consts_cache[tuple(jobs)] = consts
        vals = iter(dequantize_buffers(
            self.buffers,
            [(np.dtype(self.slots[i].container).name, self.slots[i].offset,
              self.slots[i].size, self.slots[i].shape) for i in jobs],
            [self.slots[i].bits for i in jobs],
            [self.effective_bits(i) for i in jobs],
            [np.dtype(self.slots[i].orig_dtype).name for i in jobs],
            constants=consts))
        for key, idxs in stale:
            parts = [(self.slots[i].slice_idx, self.slots[i].slice_axis,
                      next(vals)) for i in idxs]
            if len(parts) == 1 and parts[0][1] is None:
                leaf = parts[0][2]
            else:
                axis = parts[0][1]
                parts.sort(key=lambda x: x[0])
                leaf = jnp.stack([v for _, _, v in parts], axis=axis)
            self._leaf_cache[key] = leaf

    def _fp_leaf(self, key: Any, idxs: list[int]) -> jax.Array:
        """One dequantized float leaf (sliced tensors restacked), served
        from the leaf cache when untouched since the last rebuild —
        ``ingest`` pops touched keys, so cache presence means fresh."""
        cached = self._leaf_cache.get(key)
        if cached is not None and not any(i in self._dirty for i in idxs):
            return cached
        self._refresh_fp_leaves([(key, idxs)])
        return self._leaf_cache[key]

    def materialize_leaves(self) -> dict[Any, jax.Array]:
        """Dequantize into ``{key: array}``, restacking sliced tensors
        along their slice axis. Only keys touched since the last call
        are recomputed — batched into one :func:`dequantize_batch`
        call — and the rest are served from the leaf cache."""
        by_key = self._by_key()
        self._refresh_fp_leaves(
            [(key, idxs) for key, idxs in by_key.items()
             if self._leaf_cache.get(key) is None
             or any(i in self._dirty for i in idxs)])
        out = {key: self._leaf_cache[key] for key in by_key}
        self._dirty.clear()
        return out

    # -- quantized-resident views ------------------------------------------
    def _quantized_leaf(self, key: Any, idxs: list[int]
                        ) -> QuantizedTensor | None:
        """One leaf as a live :class:`QuantizedTensor`: ``q`` is the
        accumulator (a view into the flat buffer; sliced tensors restack
        their *uint* segments — still no float copy), and the eq.-(5)
        affine rides along as traced arrays shaped
        ``q.shape[:-2] + (1, 1)`` — exactly what ``lax.scan`` slices to
        the per-layer ``(1, 1)`` kernel operands. Returns None when the
        leaf can't feed a dequant matmul (ndim < 2, or slices along one
        of the two contracting dims)."""
        slots = [self.slots[i] for i in idxs]
        if len({s.bits for s in slots}) != 1:
            return None
        if len(idxs) == 1 and slots[0].slice_axis is None:
            q = self._slice_acc(idxs[0])
            if q.ndim < 2:
                return None
            order = [(idxs[0], slots[0])]
            ax = None
        else:
            ax = slots[0].slice_axis
            if ax is None or any(s.slice_axis != ax for s in slots):
                return None
            stacked_ndim = len(slots[0].shape) + 1
            if ax >= stacked_ndim - 2:
                return None
            order = sorted(zip(idxs, slots), key=lambda p: p[1].slice_idx)
            q = jnp.stack([self._slice_acc(i) for i, _ in order], axis=ax)
        meta_shape = q.shape[:-2] + (1, 1)

        def place(vals, dtype) -> jax.Array:
            """Per-slice scalars -> broadcastable metadata: values vary
            along the slice axis, broadcast everywhere else."""
            a = jnp.asarray(vals, dtype)
            if ax is not None:
                shp = [1] * q.ndim
                shp[ax] = len(order)
                a = a.reshape(tuple(shp))
            return jnp.broadcast_to(a, meta_shape)

        # Only `offset` and `received_bits` depend on the planes
        # received so far; lo/hi/scale are fixed at the header. They are
        # built (and their host mirrors captured) exactly once per key,
        # so a precision upgrade's metadata refresh is a handful of
        # dispatches, not a per-slice eager affine recomputation — the
        # host cost that made sharded upgrades look like stalls.
        const = self._qmeta_cache.get(key)
        if const is None:
            scales = [dequant_affine(s.lo, s.hi, s.bits)[0]
                      for _, s in order]
            spans = [affine_span(s.lo, s.hi) for _, s in order]
            const = {
                "lo": place([s.lo for _, s in order], jnp.float32),
                "hi": place([s.hi for _, s in order], jnp.float32),
                "scale": place(scales, jnp.float32),
                # exact f32 bits of the jnp computation, pulled once
                "lo_np": np.asarray(jnp.stack(
                    [jnp.asarray(s.lo, jnp.float32) for _, s in order])),
                "span_np": np.asarray(jnp.stack(spans)),
            }
            self._qmeta_cache[key] = const
        ms = np.asarray([received_bits(s.schedule, self.received[i])
                         for i, s in order], np.int32)
        # offset = lo + span * 0.5**(m+1): same two f32 ops on the same
        # f32 values as dequant_affine (its m == 0 branch equals the
        # closed form at m = 0), so the recompute is bit-identical
        half_lsb = np.ldexp(np.float32(1.0), -(ms + 1)).astype(np.float32)
        off = const["lo_np"] + const["span_np"] * half_lsb

        def shape_np(a: np.ndarray) -> np.ndarray:
            """Host-side reshape/broadcast — free views, no dispatch."""
            if ax is not None:
                shp = [1] * q.ndim
                shp[ax] = len(order)
                a = a.reshape(tuple(shp))
            return np.ascontiguousarray(np.broadcast_to(a, meta_shape))

        # both per-upgrade metadata fields in ONE transfer
        off_b, ms_b = shape_np(off.astype(np.float32)), shape_np(ms)
        if self.device is None:
            off_d, ms_d = jnp.asarray(off_b), jnp.asarray(ms_b)
        else:
            off_d, ms_d = jax.device_put((off_b, ms_b), self.device)
        return QuantizedTensor(
            q=q,
            lo=const["lo"],
            hi=const["hi"],
            bits=slots[0].bits,
            orig_dtype=slots[0].orig_dtype,
            scale=const["scale"],
            offset=off_d,
            received_bits=ms_d,
        )

    def quantized_leaves(self, eligible=None, *, bits: int | None = None
                         ) -> dict[Any, Any]:
        """The param pytree's leaves with weight tensors as *live*
        :class:`QuantizedTensor` views over the flat accumulators —
        the quantized-resident serving surface. ``eligible`` is an
        optional ``key -> bool`` predicate restricting which leaves go
        quantized (e.g. matmul weights only); everything else — and any
        leaf a dequant matmul can't consume — falls back to the same
        incremental float materialization ``materialize_leaves`` uses.

        ``bits=b`` hands out the *truncated-precision* view instead: the
        same accumulators, behaving as if only ``min(b, received)`` bits
        had arrived (:meth:`QuantizedTensor.truncate` — a deferred plane
        mask plus a recomputed eq.-(5) affine; ``q`` is the *same*
        array object as the full view's, so a draft model built from
        this view adds zero resident weight bytes next to the target).
        Ineligible leaves fall back to the *shared* full-precision float
        leaf — tiny non-matmul remainders are not worth degrading.

        Like ``materialize_leaves`` this is incremental: clean keys come
        out of a cache as the *same* leaf objects, so a jitted consumer
        sees identical buffers for untouched weights. After an
        ``ingest``, only touched keys rebuild — a precision upgrade is
        the ingest plus this metadata refresh, no ``materialize()``."""
        out: dict[Any, Any] = {}
        for key, idxs in self._by_key().items():
            if eligible is None or eligible(key):
                got = self._qleaf_cache.get(key)
                if got is None:
                    got = self._quantized_leaf(key, idxs)
                    if got is not None:
                        self._qleaf_cache[key] = got
                if got is not None:
                    if bits is not None:
                        # clamp per leaf: schedules may differ per
                        # tensor, and bits >= the leaf's own width just
                        # means "full precision, masked form" — the
                        # no-op mask keeps the draft and target views
                        # treedef-identical, so one decode executable
                        # serves both
                        b_eff = min(bits, got.bits)
                        trunc = self._qtrunc_cache.get((key, b_eff))
                        if trunc is None:
                            trunc = got.truncate(b_eff)
                            self._qtrunc_cache[(key, b_eff)] = trunc
                        got = trunc
                    out[key] = got
                    continue
            out[key] = self._fp_leaf(key, idxs)
        self._dirty.clear()
        return out

    def dirty_keys(self) -> set:
        return {self.slots[i].key for i in self._dirty}


def _key_path_str(key) -> str:
    """Leaf key as an 'a/b/c' path string (wire stores already use
    strings; pull-mode stores use jax tree-path tuples)."""
    if isinstance(key, str):
        return key
    from repro.core.wire import path_str

    return path_str(key)


class ShardedPlaneStore:
    """Multi-device PlaneStore: per-model-shard sub-stores, shard-local
    ingest, globally-sharded leaf views.

    Each model shard ``j`` owns an ordinary :class:`PlaneStore`
    committed to ``mesh`` device column ``j`` — the same flat per-dtype
    uint accumulators, block-aligned layout and batched
    ``plane_or_segments`` upgrade, just device-pinned. A tensor routes
    to the sub-stores one of three ways, along the same axes
    :func:`repro.launch.sharding.serving_spec_for_param` shards the
    param it backs:

    * **expert slices** (``slice_axis`` set, slice count divisible by
      the shard count): each per-expert slice is already its own store
      tensor, so slice ``e`` goes *whole* to shard ``e // (E/n)`` —
      expert-parallel ingest with no plane surgery;
    * **split dense** (>= 2-D, serving spec shards a dim divisibly):
      each arriving plane is split along that dim and each segment is
      uploaded to — and OR-ed on — its owning shard only;
    * **whole** (1-D, indivisible, or unshardable): round-robin to one
      sub-store; the leaf is replicated at materialization.

    Every plane row is OR-ed exactly once on exactly one device (no
    host gather of accumulators, no replicated OR); launch counts are
    the per-sub-store sums. Leaves come back as *global* jax arrays:
    sharded leaves are zero-copy-assembled from the sub-stores' buffer
    views via ``jax.make_array_from_single_device_arrays`` (plus
    per-data-row replica transfers when the mesh has a data axis > 1),
    whole-routed leaves are replicated. The eq.-(5) affine constants
    stay *shard-local*: each sub-store batches its own
    ``dequantize_buffers`` refresh with its own cached constants, so an
    upgrade stays O(1) host dispatches per shard. Everything is
    dispatch-only — ingest and refresh never block on device results,
    preserving the zero-stall upgrade property."""

    def __init__(self, entries: list[dict], mesh, *,
                 block: int = DEFAULT_BLOCK):
        if mesh.axis_names != ("data", "model"):
            raise ValueError(
                f"ShardedPlaneStore wants a ('data', 'model') mesh, got "
                f"axes {mesh.axis_names}")
        self.mesh = mesh
        self.block = block
        self._n_model = int(mesh.shape["model"])
        self._n_data = int(mesh.shape["data"])
        self._devs = np.asarray(mesh.devices).reshape(
            self._n_data, self._n_model)
        self.keys = [e["key"] for e in entries]
        self.schedules = [e["schedule"] for e in entries]
        self.shapes = [tuple(e["shape"]) for e in entries]
        self.received = [0] * len(entries)
        # key -> ordered global tensor idxs (slices group under one key)
        self._groups: dict[Any, list[int]] = {}
        for i, k in enumerate(self.keys):
            self._groups.setdefault(k, []).append(i)
        # routing (per key): ("expert", axis) | ("split", axis) |
        # ("whole", owner_shard)
        self._route: dict[Any, tuple] = {}
        # global idx -> [(shard, plane_segment_index)] in shard order
        self._placement: list[list[tuple[int, int]]] = [
            [] for _ in entries]
        per_shard: list[list[dict]] = [[] for _ in range(self._n_model)]
        # key -> shard -> local slot idxs (for per-shard leaf refresh)
        self._local_by_key: dict[Any, dict[int, list[int]]] = {}
        rr = 0  # round-robin cursor for whole-routed groups
        for key, idxs in self._groups.items():
            locs = self._local_by_key.setdefault(key, {})

            def _place(i: int, j: int, entry: dict) -> None:
                self._placement[i].append((j, len(per_shard[j])))
                locs.setdefault(j, []).append(len(per_shard[j]))
                per_shard[j].append(entry)

            e0 = entries[idxs[0]]
            ax = e0.get("slice_axis")
            if (ax is not None and len(idxs) > 1
                    and len(idxs) % self._n_model == 0
                    and all(entries[i].get("slice_axis") == ax
                            for i in idxs)):
                ordered = sorted(idxs, key=lambda i: entries[i]["slice_idx"])
                per = len(ordered) // self._n_model
                for r, i in enumerate(ordered):
                    _place(i, r // per, entries[i])
                self._route[key] = ("expert", ax)
                continue
            split_ax = (self._split_axis(e0) if len(idxs) == 1 and ax is None
                        else None)
            if split_ax is not None:
                i = idxs[0]
                shape = list(e0["shape"])
                shape[split_ax] //= self._n_model
                local = dict(e0, shape=tuple(shape))
                for j in range(self._n_model):
                    _place(i, j, local)
                self._route[key] = ("split", split_ax)
                continue
            owner = rr % self._n_model
            rr += 1
            for i in idxs:
                _place(i, owner, entries[i])
            self._route[key] = ("whole", owner)
        self.substores = [
            PlaneStore._from_entries(per_shard[j], block=block,
                                     device=self._devs[0, j])
            for j in range(self._n_model)
        ]
        self._g_dirty: set[int] = set(range(len(entries)))
        self._g_leaf_cache: dict[Any, jax.Array] = {}
        self._g_qleaf_cache: dict[Any, QuantizedTensor] = {}
        self._g_qtrunc_cache: dict[tuple, QuantizedTensor] = {}
        # globally-placed lo/hi/scale per key (m-independent — survives
        # ingest; only offset/received_bits reassemble per upgrade)
        self._g_qmeta_cache: dict[Any, dict] = {}

    def _split_axis(self, entry: dict) -> int | None:
        """Dim to split a dense tensor on, from the serving sharding
        rule (reuses launch/sharding's spec; lazy import, launch sits
        above core)."""
        from repro.launch.sharding import serving_spec_for_param

        shape = entry["shape"]
        if len(shape) < 2:
            return None
        spec = serving_spec_for_param(_key_path_str(entry["key"]), shape,
                                      self.mesh)
        for d, name in enumerate(spec):
            if name == "model":
                return d
        return None

    # -- construction ------------------------------------------------------
    @classmethod
    def from_model(cls, model, mesh, *,
                   block: int = DEFAULT_BLOCK) -> "ShardedPlaneStore":
        return cls(_entries_from_model(model), mesh, block=block)

    @classmethod
    def from_wire_meta(cls, meta: Mapping, mesh, *,
                       block: int = DEFAULT_BLOCK) -> "ShardedPlaneStore":
        return cls(_entries_from_wire_meta(meta), mesh, block=block)

    def copy(self) -> "ShardedPlaneStore":
        new = object.__new__(ShardedPlaneStore)
        for attr in ("mesh", "block", "_n_model", "_n_data", "_devs",
                     "keys", "schedules", "shapes", "_groups", "_route",
                     "_placement", "_local_by_key"):
            setattr(new, attr, getattr(self, attr))
        new.received = list(self.received)
        new.substores = [s.copy() for s in self.substores]
        new._g_dirty = set(self._g_dirty)
        new._g_leaf_cache = dict(self._g_leaf_cache)
        new._g_qleaf_cache = dict(self._g_qleaf_cache)
        new._g_qtrunc_cache = dict(self._g_qtrunc_cache)
        new._g_qmeta_cache = dict(self._g_qmeta_cache)
        return new

    # -- basic views -------------------------------------------------------
    @property
    def n_tensors(self) -> int:
        return len(self.keys)

    def effective_bits(self, i: int) -> int:
        return received_bits(self.schedules[i], self.received[i])

    def resident_bytes(self) -> int:
        return sum(s.resident_bytes() for s in self.substores)

    def fingerprint(self) -> dict[str, int]:
        """Per-shard accumulator CRCs (``shard<j>/<dtype>`` keys) — the
        sharded counterpart of :meth:`PlaneStore.fingerprint`."""
        out: dict[str, int] = {}
        for j, s in enumerate(self.substores):
            for dt, crc in s.fingerprint().items():
                out[f"shard{j}/{dt}"] = crc
        return out

    def dirty_keys(self) -> set:
        return {self.keys[i] for i in self._g_dirty}

    def acc(self, i: int) -> jax.Array:
        """Tensor i's accumulator, re-joined across shards (compat /
        debug surface; the serving path reads the sharded leaves and
        never host-gathers)."""
        kind, _ = self._route[self.keys[i]]
        if kind != "split":
            j, lidx = self._placement[i][0]
            return self.substores[j].acc(lidx)
        ax = self._route[self.keys[i]][1]
        return jnp.concatenate(
            [jnp.asarray(np.asarray(self.substores[j].acc(lidx)))
             for j, lidx in self._placement[i]], axis=ax)

    def quantized(self, i: int) -> QuantizedTensor:
        t0 = self.substores[self._placement[i][0][0]].slots[
            self._placement[i][0][1]]
        return QuantizedTensor(q=self.acc(i), lo=t0.lo, hi=t0.hi,
                               bits=t0.bits, orig_dtype=t0.orig_dtype)

    # -- eq. (4): shard-local batched upgrade ------------------------------
    def ingest(self, items: Sequence[tuple[int, jax.Array]]) -> None:
        """Route a shipment to the owning shards and OR it there.
        Validation is global and up front (a bad item leaves every
        sub-store untouched); each sub-store then runs its own batched
        ``plane_or_segments`` rounds on its own device — launches are
        the per-shard sums, and no accumulator bytes cross devices."""
        pending = list(items)
        counts: dict[int, int] = {}
        for idx, plane in pending:
            size = int(np.prod(self.shapes[idx]) or 1)
            n = int(np.prod(np.shape(plane)) or 1)
            if n != size:
                raise ValueError(
                    f"plane for tensor {idx} has {n} elements, "
                    f"expected {size}")
            counts[idx] = counts.get(idx, 0) + 1
        for idx, c in counts.items():
            have, total = self.received[idx], self.schedules[idx].n_planes
            if have + c > total:
                raise ValueError(
                    f"tensor {idx}: {have} planes received + {c} arriving "
                    f"exceeds schedule of {total}")
        sub_items: list[list[tuple[int, Any]]] = [
            [] for _ in range(self._n_model)]
        for idx, plane in pending:
            key = self.keys[idx]
            kind, ax = self._route[key]
            if kind == "split":
                # Host planes (the wire path) split on host — zero-copy
                # views, one direct H2D per shard. Device-resident
                # planes (pull-mode serving) split ON DEVICE: np.asarray
                # here would be a blocking D2H sync on the upgrade path.
                if isinstance(plane, jax.Array):
                    arr = jnp.reshape(plane, self.shapes[idx])
                    pieces = jnp.split(arr, self._n_model, axis=ax)
                else:
                    arr = np.asarray(plane).reshape(self.shapes[idx])
                    pieces = np.split(arr, self._n_model, axis=ax)
                for (j, lidx), piece in zip(self._placement[idx], pieces):
                    sub_items[j].append((lidx, piece))
            else:
                j, lidx = self._placement[idx][0]
                sub_items[j].append((lidx, plane))
        for j, its in enumerate(sub_items):
            if its:
                self.substores[j].ingest(its)
        for idx, _ in pending:
            self.received[idx] += 1
            self._g_dirty.add(idx)
            key = self.keys[idx]
            self._g_leaf_cache.pop(key, None)
            self._g_qleaf_cache.pop(key, None)
            for tk in [t for t in self._g_qtrunc_cache if t[0] == key]:
                self._g_qtrunc_cache.pop(tk)

    # -- global leaf assembly ----------------------------------------------
    def _assemble(self, pieces: list, global_shape: tuple, spec) -> jax.Array:
        """Zero-copy global array from per-shard pieces: piece ``j`` is
        normally already committed to device column ``j`` (a lazy view
        of that sub-store's buffer or a shard-local dequant result), so
        the row-0 ``device_put`` is a no-op view; host-built pieces
        (per-slice metadata) get committed here, and extra data rows get
        async replica transfers."""
        from jax.sharding import NamedSharding

        sharding = NamedSharding(self.mesh, spec)
        # one batched transfer for all (data row, shard) targets — the
        # per-piece device_put loop was most of an upgrade's assembly
        # dispatch cost
        srcs = [p for _ in range(self._n_data) for p in pieces]
        devs = [self._devs[i, j] for i in range(self._n_data)
                for j in range(len(pieces))]
        arrs = jax.device_put(srcs, devs)
        return jax.make_array_from_single_device_arrays(
            tuple(global_shape), sharding, arrs)

    def _replicated(self, x):
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.device_put(x, NamedSharding(self.mesh, PartitionSpec()))

    def _spec_at(self, ndim: int, ax: int):
        from jax.sharding import PartitionSpec

        names = [None] * ndim
        names[ax] = "model"
        return PartitionSpec(*names)

    def _refresh_fp(self, keys: list) -> None:
        """Per-shard batched eq.-(5) refresh for the given keys: ONE
        ``dequantize_buffers`` dispatch per sub-store (shard-local
        affine constants via each sub-store's own consts cache), then
        global assembly of each leaf."""
        if not keys:
            return
        for j, sub in enumerate(self.substores):
            stale = [(key, self._local_by_key[key][j]) for key in keys
                     if j in self._local_by_key[key]]
            if stale:
                sub._refresh_fp_leaves(stale)
                for _, lidxs in stale:
                    sub._dirty.difference_update(lidxs)
        for key in keys:
            kind, ax = self._route[key]
            if kind == "whole":
                leaf = self._replicated(self.substores[ax]._leaf_cache[key])
            else:
                shards = sorted(self._local_by_key[key])
                pieces = [self.substores[j]._leaf_cache[key] for j in shards]
                shape = list(pieces[0].shape)
                shape[ax] *= self._n_model
                leaf = self._assemble(pieces, tuple(shape),
                                      self._spec_at(len(shape), ax))
            self._g_leaf_cache[key] = leaf

    def _fp_leaf(self, key) -> jax.Array:
        cached = self._g_leaf_cache.get(key)
        if cached is not None and not any(
                i in self._g_dirty for i in self._groups[key]):
            return cached
        self._refresh_fp([key])
        return self._g_leaf_cache[key]

    def materialize_leaves(self) -> dict[Any, jax.Array]:
        """Global ``{key: array}`` view; stale keys are re-dequantized
        in one batched dispatch per sub-store and re-assembled, clean
        keys come back as the *same* global array objects."""
        stale = [key for key, idxs in self._groups.items()
                 if self._g_leaf_cache.get(key) is None
                 or any(i in self._g_dirty for i in idxs)]
        self._refresh_fp(stale)
        out = {key: self._g_leaf_cache[key] for key in self._groups}
        self._g_dirty.clear()
        return out

    # -- quantized-resident views ------------------------------------------
    def _sub_qleaf(self, j: int, key) -> QuantizedTensor | None:
        sub = self.substores[j]
        got = sub._qleaf_cache.get(key)
        if got is None:
            got = sub._quantized_leaf(key, self._local_by_key[key][j])
            if got is not None:
                sub._qleaf_cache[key] = got
        return got

    def _quantized_leaf(self, key) -> QuantizedTensor | None:
        # lo/hi/scale are fixed at the header, so their global placement
        # (_g_qmeta_cache) happens once per key; an upgrade's refresh
        # only reassembles q + offset + received_bits — the per-upgrade
        # host dispatch count is what makes sharded upgrades enqueues.
        kind, ax = self._route[key]
        const_fields = ("lo", "hi", "scale")
        live_fields = ("offset", "received_bits")
        if kind == "whole":
            local = self._sub_qleaf(ax, key)
            if local is None:
                return None
            const = self._g_qmeta_cache.get(key)
            if const is None:
                const = {f: self._replicated(getattr(local, f))
                         for f in const_fields}
                self._g_qmeta_cache[key] = const
            q_r, off_r, rb_r = self._replicated(
                (local.q, local.offset, local.received_bits))
            return QuantizedTensor(
                q=q_r, bits=local.bits, orig_dtype=local.orig_dtype,
                offset=off_r, received_bits=rb_r, **const)
        shards = sorted(self._local_by_key[key])
        locals_ = [self._sub_qleaf(j, key) for j in shards]
        if any(l is None for l in locals_):
            return None
        l0 = locals_[0]
        gshape = list(l0.q.shape)
        gshape[ax] *= self._n_model
        q = self._assemble([l.q for l in locals_], tuple(gshape),
                           self._spec_at(len(gshape), ax))
        const = self._g_qmeta_cache.get(key)
        if ax < len(gshape) - 2:
            # the sharded dim survives into the metadata shape
            # (q.shape[:-2] + (1, 1)): shard the metadata exactly like
            # q's dim — per-expert affines vary along it, per-tensor
            # affines broadcast along it, either way the shapes align
            mshape = list(l0.scale.shape)
            mshape[ax] *= self._n_model
            mspec = self._spec_at(len(mshape), ax)
            if const is None:
                const = {f: self._assemble([getattr(l, f) for l in locals_],
                                           tuple(mshape), mspec)
                         for f in const_fields}
                self._g_qmeta_cache[key] = const
            live = {f: self._assemble([getattr(l, f) for l in locals_],
                                      tuple(mshape), mspec)
                    for f in live_fields}
        else:
            # split on a contraction-adjacent dim (last two): the
            # metadata collapses it to 1 and the per-tensor affine is
            # identical on every shard — replicate shard 0's
            if const is None:
                const = {f: self._replicated(getattr(l0, f))
                         for f in const_fields}
                self._g_qmeta_cache[key] = const
            live = {f: self._replicated(getattr(l0, f))
                    for f in live_fields}
        return QuantizedTensor(q=q, bits=l0.bits, orig_dtype=l0.orig_dtype,
                               **const, **live)

    def quantized_leaves(self, eligible=None, *, bits: int | None = None
                         ) -> dict[Any, Any]:
        """Globally-sharded mirror of
        :meth:`PlaneStore.quantized_leaves`: eligible leaves are live
        QuantizedTensor views whose ``q`` is a global sharded array over
        the sub-stores' accumulators; truncated (``bits=b``) draft views
        share those exact global buffers (zero extra weight bytes,
        sharded or not)."""
        out: dict[Any, Any] = {}
        for key, idxs in self._groups.items():
            if eligible is None or eligible(key):
                got = self._g_qleaf_cache.get(key)
                if got is None:
                    got = self._quantized_leaf(key)
                    if got is not None:
                        self._g_qleaf_cache[key] = got
                if got is not None:
                    if bits is not None:
                        b_eff = min(bits, got.bits)
                        trunc = self._g_qtrunc_cache.get((key, b_eff))
                        if trunc is None:
                            trunc = got.truncate(b_eff)
                            self._g_qtrunc_cache[(key, b_eff)] = trunc
                        got = trunc
                    out[key] = got
                    continue
            out[key] = self._fp_leaf(key)
        self._g_dirty.clear()
        return out
