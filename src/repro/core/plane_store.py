"""PlaneStore: the single device-resident receiver runtime (eqs. 4+5).

Every client of progressive transmission — the pytree receiver
(``core/progressive.ReceiverState``), the byte-stream client
(``transmission/client.ProgressiveClient``), and the quantized-resident
serving path (``serving/quantized``) — used to carry its own copy of
the OR/shift/stacking arithmetic. They now all sit on this one store.

Layout
------
All tensors sharing a container dtype live in ONE flat 1-D uint buffer;
each tensor occupies a block-aligned segment ``[offset, offset+size)``
(padding between segments is dead space, < ``block`` elements per
tensor). Per-tensor metadata (shape, plane schedule, quantization
range, slice info) lives in :class:`TensorSlot` views.

Upgrades (eq. 4)
----------------
``ingest([(tensor_idx, plane), ...])`` assembles one flat plane buffer
plus a per-block int32 shift table and issues ONE batched
``plane_or_segments`` Pallas launch per container dtype — O(1) in the
number of tensors, vs. the old one-``pallas_call``-per-tensor loop.
Block alignment is what makes the per-block shift well defined: a block
never straddles two tensors.

Materialization (eq. 5)
-----------------------
``materialize()`` is *incremental*: only tensors whose accumulator
changed since the last call are re-dequantized; unchanged float leaves
come out of a cache (same array objects — downstream jit sees identical
buffer donations). Sliced tensors (expert banks) are restacked along
their slice axis only when one of their slices is dirty.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplanes import PlaneSchedule
from repro.core.quantize import (QuantizedTensor, container_dtype,
                                 dequant_affine, dequant_constants,
                                 dequantize_buffers)
from repro.kernels import ops

# One grid step of plane_or_segments: 8 sublanes x 128 lanes.
DEFAULT_BLOCK = 1024


@functools.partial(jax.jit, static_argnames=("segs",))
def _scatter_segments(buf: jax.Array, out: jax.Array,
                      segs: tuple) -> jax.Array:
    """Write compact OR results back into the flat buffer. ``segs`` is
    ``((buf_offset, compact_pos, length), ...)``. One jitted call: the
    update chain fuses into a single new buffer (one allocation per
    round, not one full copy per segment as eager .at[].set would pay).
    NOT donated: ``copy()`` stores share buffer objects, so donating
    here would invalidate a sibling store's accumulator."""
    for off, pos, length in segs:
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, jax.lax.dynamic_slice_in_dim(out, pos, length), off, axis=0)
    return buf


def next_plane_shift(schedule: PlaneSchedule, received: int) -> int:
    """Eq. (4) shift for the next arriving plane: after ``received``
    planes, plane ``received+1`` lands at ``bits - c_{received+1}``.
    The ONLY place this arithmetic lives."""
    if received >= schedule.n_planes:
        raise ValueError(
            f"all {schedule.n_planes} planes already received")
    return schedule.bits - schedule.cumulative_bits[received]


def received_bits(schedule: PlaneSchedule, received: int) -> int:
    """Effective precision m = sum of the first ``received`` widths."""
    return schedule.cumulative_bits[received - 1] if received > 0 else 0


@dataclasses.dataclass(frozen=True)
class TensorSlot:
    """Static per-tensor metadata: a view descriptor into a flat buffer."""

    key: Any                  # opaque leaf key (tuple path or path string)
    schedule: PlaneSchedule
    lo: jax.Array
    hi: jax.Array
    shape: tuple
    orig_dtype: Any
    offset: int               # element offset within the dtype's buffer
    size: int                 # n elements
    padded: int               # block-aligned span (size rounded up)
    slice_axis: int | None = None
    slice_idx: int = 0

    @property
    def bits(self) -> int:
        return self.schedule.bits

    @property
    def container(self):
        return container_dtype(self.bits)


class PlaneStore:
    """Device-resident accumulators for one progressive model."""

    def __init__(self, slots: list[TensorSlot], *, block: int = DEFAULT_BLOCK):
        self.block = block
        self.slots = slots
        self.received = [0] * len(slots)
        # dtype name -> flat uint buffer (length: multiple of block)
        self.buffers: dict[str, jax.Array] = {}
        sizes: dict[str, int] = {}
        for t in slots:
            dt = np.dtype(t.container).name
            sizes[dt] = max(sizes.get(dt, 0), t.offset + t.padded)
        for dt, n in sizes.items():
            self.buffers[dt] = jnp.zeros((n,), dtype=np.dtype(dt))
        self._dirty: set[int] = set(range(len(slots)))
        self._leaf_cache: dict[Any, jax.Array] = {}
        self._qleaf_cache: dict[Any, QuantizedTensor] = {}
        self._qtrunc_cache: dict[tuple, QuantizedTensor] = {}
        self._acc_cache: dict[int, jax.Array] = {}
        # stacked eq.-(5) constants per batch of slot indices; lo/hi/
        # bits never change after the header, so never invalidated
        self._consts_cache: dict[tuple, tuple] = {}

    # -- construction ------------------------------------------------------
    @staticmethod
    def _layout(entries, block):
        """Assign (offset, padded) per entry, grouped by container dtype."""
        cursors: dict[str, int] = {}
        out = []
        for e in entries:
            dt = np.dtype(container_dtype(e["schedule"].bits)).name
            size = int(np.prod(e["shape"])) if e["shape"] else 1
            padded = -(-size // block) * block
            off = cursors.get(dt, 0)
            cursors[dt] = off + padded
            out.append((off, size, padded))
        return out

    @classmethod
    def from_model(cls, model, *, block: int = DEFAULT_BLOCK,
                   indices: Sequence[int] | None = None) -> "PlaneStore":
        """Build from a server-side :class:`ProgressiveModel` (keys are
        pytree paths). ``indices`` restricts the store to a subset of
        the model's tensors (slot i is then ``model.tensors[indices[i]]``
        — a single-tensor store allocates one tensor's buffer, not the
        whole model's)."""
        tensors = (model.tensors if indices is None
                   else [model.tensors[i] for i in indices])
        entries = [{"schedule": t.plan.schedule, "shape": t.shape}
                   for t in tensors]
        layout = cls._layout(entries, block)
        slots = [
            TensorSlot(
                key=t.path, schedule=t.plan.schedule, lo=t.lo, hi=t.hi,
                shape=tuple(t.shape), orig_dtype=t.orig_dtype,
                offset=off, size=size, padded=padded,
                slice_axis=t.slice_axis, slice_idx=t.slice_idx,
            )
            for t, (off, size, padded) in zip(tensors, layout)
        ]
        return cls(slots, block=block)

    @classmethod
    def from_wire_meta(cls, meta: Mapping, *, block: int = DEFAULT_BLOCK
                       ) -> "PlaneStore":
        """Build from a decoded wire header (keys are path strings)."""
        entries = [
            {"schedule": PlaneSchedule(bits=t["bits"],
                                       widths=tuple(t["widths"])),
             "shape": tuple(t["shape"])}
            for t in meta["tensors"]
        ]
        layout = cls._layout(entries, block)
        slots = [
            TensorSlot(
                key=t["path"], schedule=e["schedule"],
                lo=jnp.float32(t["lo"]), hi=jnp.float32(t["hi"]),
                shape=tuple(t["shape"]), orig_dtype=np.dtype(t["dtype"]),
                offset=off, size=size, padded=padded,
                slice_axis=t.get("slice_axis"), slice_idx=t.get("slice_idx", 0),
            )
            for t, e, (off, size, padded)
            in zip(meta["tensors"], entries, layout)
        ]
        return cls(slots, block=block)

    def copy(self) -> "PlaneStore":
        """Cheap snapshot: buffers are immutable jax arrays, so sharing
        them is safe; bookkeeping is shallow-copied. Lets the functional
        ``ReceiverState.receive`` keep value semantics for free."""
        new = object.__new__(PlaneStore)
        new.block = self.block
        new.slots = self.slots
        new.received = list(self.received)
        new.buffers = dict(self.buffers)
        new._dirty = set(self._dirty)
        new._leaf_cache = dict(self._leaf_cache)
        new._qleaf_cache = dict(self._qleaf_cache)
        new._qtrunc_cache = dict(self._qtrunc_cache)
        new._acc_cache = dict(self._acc_cache)
        new._consts_cache = dict(self._consts_cache)
        return new

    # -- views -------------------------------------------------------------
    def _slice_acc(self, i: int) -> jax.Array:
        t = self.slots[i]
        dt = np.dtype(t.container).name
        return self.buffers[dt][t.offset:t.offset + t.size].reshape(t.shape)

    def acc(self, i: int) -> jax.Array:
        """Tensor i's accumulator: a view into the flat buffer. Cached
        until the tensor's next ingest, so eager hot paths (per-token
        ``QuantizedLinearState.matmul``) don't re-slice per call. The
        cache fills only on explicit ``acc`` access — one-shot readers
        (materialize) slice without caching, so they don't pin a second
        copy of every accumulator."""
        got = self._acc_cache.get(i)
        if got is None:
            got = self._slice_acc(i)
            self._acc_cache[i] = got
        return got

    def quantized(self, i: int) -> QuantizedTensor:
        t = self.slots[i]
        return QuantizedTensor(q=self._slice_acc(i), lo=t.lo, hi=t.hi,
                               bits=t.bits, orig_dtype=t.orig_dtype)

    def effective_bits(self, i: int) -> int:
        return received_bits(self.slots[i].schedule, self.received[i])

    @property
    def n_tensors(self) -> int:
        return len(self.slots)

    def resident_bytes(self) -> int:
        return sum(b.size * b.dtype.itemsize for b in self.buffers.values())

    # -- eq. (4): batched upgrade -----------------------------------------
    def ingest(self, items: Sequence[tuple[int, jax.Array]]) -> None:
        """OR a shipment of planes into the store. ``items`` holds
        ``(tensor_idx, plane_values)`` pairs; each plane is the *next*
        plane of its tensor's schedule (the wire delivers them in
        order). One ``plane_or_segments`` launch per container dtype per
        round; a shipment carrying several planes of the same tensor is
        split into rounds (distinct shifts for the same segment can't
        share one OR).

        The whole shipment is validated up front, so a bad item leaves
        the store untouched — callers (e.g. the client's ``_flush``)
        may safely retry the identical shipment after a failure."""
        pending = list(items)
        counts: dict[int, int] = {}
        for idx, plane in pending:
            t = self.slots[idx]
            n = int(np.prod(np.shape(plane)) or 1)
            if n != t.size:
                raise ValueError(
                    f"plane for tensor {idx} has {n} elements, "
                    f"expected {t.size}")
            counts[idx] = counts.get(idx, 0) + 1
        for idx, c in counts.items():
            have, total = self.received[idx], self.slots[idx].schedule.n_planes
            if have + c > total:
                raise ValueError(
                    f"tensor {idx}: {have} planes received + {c} arriving "
                    f"exceeds schedule of {total}")
        while pending:
            round_items: dict[int, jax.Array] = {}
            rest = []
            for idx, plane in pending:
                if idx in round_items:
                    rest.append((idx, plane))
                else:
                    round_items[idx] = plane
            self._ingest_round(round_items)
            pending = rest

    def _ingest_round(self, items: dict[int, jax.Array]) -> None:
        """One OR round: the accumulator never round-trips through the
        host. Touched segments are gathered into a *compact* buffer
        (cheap XLA slices/concat, no kernel launches), the single
        ``plane_or_segments`` launch sweeps only those blocks, and the
        results go back via one fused scatter — a sparse shipment's OR
        work and transfers are O(touched bytes); the write-back is a
        single whole-buffer update (immutable arrays), not one per
        segment."""
        by_dtype: dict[str, list[int]] = {}
        for idx in items:
            dt = np.dtype(self.slots[idx].container).name
            by_dtype.setdefault(dt, []).append(idx)
        for dt, idxs in by_dtype.items():
            buf = self.buffers[dt]
            idxs.sort(key=lambda i: self.slots[i].offset)
            total = sum(self.slots[i].padded for i in idxs)
            full = total == buf.shape[0]
            shifts = np.empty((total // self.block,), np.int32)
            pos = 0
            for idx in idxs:
                t = self.slots[idx]
                sh = next_plane_shift(t.schedule, self.received[idx])
                shifts[pos // self.block:(pos + t.padded) // self.block] = sh
                pos += t.padded
            shifts = jnp.asarray(shifts)
            # Plane assembly: on an accelerator, keep device-resident
            # planes (engine path) on device — pad+concat is cheap XLA
            # work and avoids a blocking D2H+H2D round trip. On the CPU
            # backend host assembly is the DMA landing zone (one memcpy
            # pass + one upload) and measurably faster. The ACCUMULATOR
            # never leaves the device on either path.
            if jax.default_backend() != "cpu":
                parts = []
                for idx in idxs:
                    t = self.slots[idx]
                    p = jnp.asarray(items[idx]).reshape(-1).astype(buf.dtype)
                    if t.padded != t.size:
                        p = jnp.pad(p, (0, t.padded - t.size))
                    parts.append(p)
                plane = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            else:
                plane_np = np.zeros((total,), dtype=buf.dtype)
                pos = 0
                for idx in idxs:
                    t = self.slots[idx]
                    plane_np[pos:pos + t.size] = (
                        np.asarray(items[idx]).reshape(-1))
                    pos += t.padded
                plane = jnp.asarray(plane_np)
            if full:
                # Whole buffer touched (the common full-stage upgrade):
                # segments are dense by layout, no gather/scatter needed.
                self.buffers[dt] = ops.plane_or_segments(
                    buf, plane, shifts, block=self.block)
            else:
                # Sparse shipment: sweep only the touched blocks —
                # O(touched bytes), not O(whole per-dtype buffer).
                compact = (buf[self.slots[idxs[0]].offset:
                               self.slots[idxs[0]].offset + total]
                           if len(idxs) == 1 else
                           jnp.concatenate([
                               buf[self.slots[i].offset:
                                   self.slots[i].offset + self.slots[i].padded]
                               for i in idxs]))
                out = ops.plane_or_segments(
                    compact, plane, shifts, block=self.block)
                segs, pos = [], 0
                for idx in idxs:
                    t = self.slots[idx]
                    segs.append((t.offset, pos, t.padded))
                    pos += t.padded
                self.buffers[dt] = _scatter_segments(buf, out, tuple(segs))
        for idx in items:
            self.received[idx] += 1
            self._dirty.add(idx)
            self._acc_cache.pop(idx, None)
            key = self.slots[idx].key
            self._leaf_cache.pop(key, None)
            self._qleaf_cache.pop(key, None)
            for tk in [t for t in self._qtrunc_cache if t[0] == key]:
                self._qtrunc_cache.pop(tk)

    # -- eq. (5): incremental materialization ------------------------------
    def _by_key(self) -> dict[Any, list[int]]:
        by_key: dict[Any, list[int]] = {}
        for i, t in enumerate(self.slots):
            by_key.setdefault(t.key, []).append(i)
        return by_key

    def _refresh_fp_leaves(self, stale: list[tuple[Any, list[int]]]) -> None:
        """Batch-dequantize every slot of the given keys and refill the
        leaf cache. The whole set is one :func:`dequantize_batch` call —
        O(1) host dispatches however many tensors an upgrade dirtied —
        with the stacked eq.-(5) constants cached across upgrades (lo/
        hi/bits are fixed at the header). This is what keeps an
        ``resident='fp'`` upgrade's refresh an enqueue, not a stall."""
        if not stale:
            return
        jobs = [i for _, idxs in stale for i in idxs]
        consts = self._consts_cache.get(tuple(jobs))
        if consts is None:
            consts = dequant_constants([self.slots[i].lo for i in jobs],
                                       [self.slots[i].hi for i in jobs],
                                       [self.slots[i].bits for i in jobs])
            self._consts_cache[tuple(jobs)] = consts
        vals = iter(dequantize_buffers(
            self.buffers,
            [(np.dtype(self.slots[i].container).name, self.slots[i].offset,
              self.slots[i].size, self.slots[i].shape) for i in jobs],
            [self.slots[i].bits for i in jobs],
            [self.effective_bits(i) for i in jobs],
            [np.dtype(self.slots[i].orig_dtype).name for i in jobs],
            constants=consts))
        for key, idxs in stale:
            parts = [(self.slots[i].slice_idx, self.slots[i].slice_axis,
                      next(vals)) for i in idxs]
            if len(parts) == 1 and parts[0][1] is None:
                leaf = parts[0][2]
            else:
                axis = parts[0][1]
                parts.sort(key=lambda x: x[0])
                leaf = jnp.stack([v for _, _, v in parts], axis=axis)
            self._leaf_cache[key] = leaf

    def _fp_leaf(self, key: Any, idxs: list[int]) -> jax.Array:
        """One dequantized float leaf (sliced tensors restacked), served
        from the leaf cache when untouched since the last rebuild —
        ``ingest`` pops touched keys, so cache presence means fresh."""
        cached = self._leaf_cache.get(key)
        if cached is not None and not any(i in self._dirty for i in idxs):
            return cached
        self._refresh_fp_leaves([(key, idxs)])
        return self._leaf_cache[key]

    def materialize_leaves(self) -> dict[Any, jax.Array]:
        """Dequantize into ``{key: array}``, restacking sliced tensors
        along their slice axis. Only keys touched since the last call
        are recomputed — batched into one :func:`dequantize_batch`
        call — and the rest are served from the leaf cache."""
        by_key = self._by_key()
        self._refresh_fp_leaves(
            [(key, idxs) for key, idxs in by_key.items()
             if self._leaf_cache.get(key) is None
             or any(i in self._dirty for i in idxs)])
        out = {key: self._leaf_cache[key] for key in by_key}
        self._dirty.clear()
        return out

    # -- quantized-resident views ------------------------------------------
    def _quantized_leaf(self, key: Any, idxs: list[int]
                        ) -> QuantizedTensor | None:
        """One leaf as a live :class:`QuantizedTensor`: ``q`` is the
        accumulator (a view into the flat buffer; sliced tensors restack
        their *uint* segments — still no float copy), and the eq.-(5)
        affine rides along as traced arrays shaped
        ``q.shape[:-2] + (1, 1)`` — exactly what ``lax.scan`` slices to
        the per-layer ``(1, 1)`` kernel operands. Returns None when the
        leaf can't feed a dequant matmul (ndim < 2, or slices along one
        of the two contracting dims)."""
        slots = [self.slots[i] for i in idxs]
        if len({s.bits for s in slots}) != 1:
            return None
        if len(idxs) == 1 and slots[0].slice_axis is None:
            q = self._slice_acc(idxs[0])
            if q.ndim < 2:
                return None
            order = [(idxs[0], slots[0])]
            ax = None
        else:
            ax = slots[0].slice_axis
            if ax is None or any(s.slice_axis != ax for s in slots):
                return None
            stacked_ndim = len(slots[0].shape) + 1
            if ax >= stacked_ndim - 2:
                return None
            order = sorted(zip(idxs, slots), key=lambda p: p[1].slice_idx)
            q = jnp.stack([self._slice_acc(i) for i, _ in order], axis=ax)
        meta_shape = q.shape[:-2] + (1, 1)

        def place(vals, dtype) -> jax.Array:
            """Per-slice scalars -> broadcastable metadata: values vary
            along the slice axis, broadcast everywhere else."""
            a = jnp.asarray(vals, dtype)
            if ax is not None:
                shp = [1] * q.ndim
                shp[ax] = len(order)
                a = a.reshape(tuple(shp))
            return jnp.broadcast_to(a, meta_shape)

        ms = [received_bits(s.schedule, self.received[i]) for i, s in order]
        affines = [dequant_affine(s.lo, s.hi, s.bits, m)
                   for (_, s), m in zip(order, ms)]
        return QuantizedTensor(
            q=q,
            lo=place([s.lo for _, s in order], jnp.float32),
            hi=place([s.hi for _, s in order], jnp.float32),
            bits=slots[0].bits,
            orig_dtype=slots[0].orig_dtype,
            scale=place([a[0] for a in affines], jnp.float32),
            offset=place([a[1] for a in affines], jnp.float32),
            received_bits=place(ms, jnp.int32),
        )

    def quantized_leaves(self, eligible=None, *, bits: int | None = None
                         ) -> dict[Any, Any]:
        """The param pytree's leaves with weight tensors as *live*
        :class:`QuantizedTensor` views over the flat accumulators —
        the quantized-resident serving surface. ``eligible`` is an
        optional ``key -> bool`` predicate restricting which leaves go
        quantized (e.g. matmul weights only); everything else — and any
        leaf a dequant matmul can't consume — falls back to the same
        incremental float materialization ``materialize_leaves`` uses.

        ``bits=b`` hands out the *truncated-precision* view instead: the
        same accumulators, behaving as if only ``min(b, received)`` bits
        had arrived (:meth:`QuantizedTensor.truncate` — a deferred plane
        mask plus a recomputed eq.-(5) affine; ``q`` is the *same*
        array object as the full view's, so a draft model built from
        this view adds zero resident weight bytes next to the target).
        Ineligible leaves fall back to the *shared* full-precision float
        leaf — tiny non-matmul remainders are not worth degrading.

        Like ``materialize_leaves`` this is incremental: clean keys come
        out of a cache as the *same* leaf objects, so a jitted consumer
        sees identical buffers for untouched weights. After an
        ``ingest``, only touched keys rebuild — a precision upgrade is
        the ingest plus this metadata refresh, no ``materialize()``."""
        out: dict[Any, Any] = {}
        for key, idxs in self._by_key().items():
            if eligible is None or eligible(key):
                got = self._qleaf_cache.get(key)
                if got is None:
                    got = self._quantized_leaf(key, idxs)
                    if got is not None:
                        self._qleaf_cache[key] = got
                if got is not None:
                    if bits is not None:
                        # clamp per leaf: schedules may differ per
                        # tensor, and bits >= the leaf's own width just
                        # means "full precision, masked form" — the
                        # no-op mask keeps the draft and target views
                        # treedef-identical, so one decode executable
                        # serves both
                        b_eff = min(bits, got.bits)
                        trunc = self._qtrunc_cache.get((key, b_eff))
                        if trunc is None:
                            trunc = got.truncate(b_eff)
                            self._qtrunc_cache[(key, b_eff)] = trunc
                        got = trunc
                    out[key] = got
                    continue
            out[key] = self._fp_leaf(key, idxs)
        self._dirty.clear()
        return out

    def dirty_keys(self) -> set:
        return {self.slots[i].key for i in self._dirty}
