"""Division policies: how a model's tensors are cut into transmission
stages.

The paper exposes ``b`` (plane widths) as the user-facing knob and ships
every tensor's m-th plane in stage m. We keep that as the default
(``UniformPolicy``) and add two beyond-paper policies that exploit
structure a browser client doesn't have:

* ``LayerPriorityPolicy`` — within a stage, order tensors by a priority
  score (e.g. first/last layers first, embeddings first), so the earliest
  *partial* stage is already maximally useful.
* ``ExpertPopularityPolicy`` — for MoE models: planes of popular experts
  (by router statistics) ship before unpopular ones; a serving pod
  becomes useful for the majority of tokens earlier.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.bitplanes import PlaneSchedule, PAPER_DEFAULT


@dataclasses.dataclass(frozen=True)
class TensorPlan:
    """Per-tensor plan: the plane schedule plus a stage->order priority."""

    schedule: PlaneSchedule
    priority: float = 0.0  # lower ships earlier within a stage


class DivisionPolicy:
    """Maps a tensor path (tuple of pytree keys) to a TensorPlan."""

    def plan(self, path: tuple, shape: tuple, dtype, slice_idx: int | None = None
             ) -> TensorPlan:  # pragma: no cover - interface
        raise NotImplementedError

    def slice_spec(self, path: tuple, shape: tuple) -> int | None:
        """Return an axis to slice this tensor along (one sub-tensor per
        index, each with its own quantization range and priority), or
        None to keep it whole. Used for expert banks: per-expert slices
        give (a) priority ordering by router popularity and (b) tighter
        per-expert (min, max) ranges."""
        return None

    @property
    def n_stages(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformPolicy(DivisionPolicy):
    """The paper's policy: one PlaneSchedule shared by every tensor."""

    schedule: PlaneSchedule = PAPER_DEFAULT

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        return TensorPlan(schedule=self.schedule)

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


def _path_str(path: tuple) -> str:
    from repro.core.wire import path_str

    return path_str(path)


@dataclasses.dataclass(frozen=True)
class LayerPriorityPolicy(DivisionPolicy):
    """Uniform widths, but tensors ordered within a stage by a scoring
    function over their path (lower score first)."""

    schedule: PlaneSchedule = PAPER_DEFAULT
    score: Callable[[str], float] = staticmethod(lambda p: 0.0)

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        return TensorPlan(schedule=self.schedule, priority=self.score(_path_str(path)))

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


def embeddings_first_score(path: str) -> float:
    """Heuristic: embeddings and final norm/head first, then shallow to
    deep layers. A truncated first stage then covers the I/O surfaces."""
    p = path.lower()
    if "embed" in p or "head" in p or "final" in p:
        return 0.0
    import re

    m = re.search(r"(\d+)", p)
    return 1.0 + (int(m.group(1)) if m else 0)


_EXPERT_BANK_RE = r"we_(gate|up|down)"


@dataclasses.dataclass(frozen=True)
class ExpertPopularityPolicy(DivisionPolicy):
    """MoE-aware (beyond-paper): expert banks are *sliced* along the
    expert axis, each slice quantized with its own (min, max) and given
    priority = -popularity, so the most-routed experts' planes ship
    first and each expert-parallel chip can fetch only its slices.
    ``popularity`` maps expert index -> routing fraction (router stats);
    ``n_experts`` identifies the expert axis (the dim of that size)."""

    schedule: PlaneSchedule = PAPER_DEFAULT
    popularity: Mapping[int, float] = dataclasses.field(default_factory=dict)
    n_experts: int = 0
    # expert slices ship after core tensors (priority 0) by default;
    # within experts, hot ones first
    expert_base_priority: float = 1.0

    def slice_spec(self, path, shape) -> int | None:
        import re

        if not re.search(_EXPERT_BANK_RE, _path_str(path)):
            return None
        if not self.n_experts:
            return None
        for ax, d in enumerate(shape):
            if d == self.n_experts:
                return ax
        return None

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        prio = 0.0
        if slice_idx is not None:
            prio = self.expert_base_priority - float(
                self.popularity.get(slice_idx, 0.0))
        return TensorPlan(schedule=self.schedule, priority=prio)

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


# ---------------------------------------------------------------------------
# Speculative-decoding control (beyond-paper): the precision ladder as a
# draft-model knob
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpeculationController:
    """Tunes the self-speculative draft (length k, draft bits) from the
    observed acceptance rate — which *changes as planes arrive*: early
    in the download the truncated draft view equals the target
    (received <= draft bits), so drafting buys nothing and the round
    degenerates to plain decode (k = 0, verify-only); once the received
    precision pulls ahead, the gap opens and long drafts pay off
    whenever the coarse model keeps predicting the refined one.

    k moves over a fixed ladder (powers of two up to ``k_max``) on an
    EWMA of the per-round acceptance fraction: high acceptance climbs
    the ladder, low acceptance steps down. Keeping k on a small ladder
    bounds the set of compiled draft/verify executables (one pair per
    distinct k); a *continuous* k would compile per value. Upgrades
    never touch k directly — they reset the EWMA toward its prior,
    since fresh planes change the draft/target gap.

    Draft *bits* adapt too: when rejection persists even at the ladder
    floor (k == 1), the coarse view simply isn't predictive, so the
    draft climbs ``bits_step`` planes (up to ``max_draft_bits``) — a
    finer prefix of the SAME accumulators. A draft-bits move is
    recompile-free by construction (the deferred mask rides in traced
    ``keep_bits``), so the controller can walk the precision ladder as
    freely as the download does; the EWMA resets toward its prior
    because acceptance evidence against the old draft is void.
    """

    draft_bits: int = 4
    k_max: int = 8
    k_init: int = 4
    bits_step: int = 2         # draft-precision increment on rejection
    max_draft_bits: int = 8    # never draft finer than this
    ewma: float = 0.6          # weight of history in the acceptance EWMA
    raise_at: float = 0.8      # climb the ladder above this rate
    lower_at: float = 0.4      # step down below this rate
    rate: float = 0.5          # EWMA state (prior: an even coin)
    k: int = dataclasses.field(default=-1)

    def __post_init__(self):
        if self.k < 0:
            self.k = min(self.k_init, self.k_max)
        self._ladder = [0] + [2 ** i for i in range(0, 32)
                              if 2 ** i <= self.k_max]
        # snap k onto the ladder (a non-power-of-two k_max would
        # otherwise strand k off-ladder and confuse the index walk)
        self.k = max(v for v in self._ladder[1:] if v <= max(self.k, 1))

    def choose_k(self, received_bits: int) -> int:
        """Draft length for the next round. No precision gap -> no
        cheaper draft exists -> plain decode (k = 0)."""
        if received_bits <= self.draft_bits:
            return 0
        return self.k

    def update(self, accepted: int, proposed: int) -> None:
        """Fold one round's outcome (``accepted`` of ``proposed`` draft
        tokens) into the EWMA and move k along the ladder — or, when
        rejection persists at the ladder floor, move the draft itself
        up the precision ladder instead."""
        if proposed <= 0:
            return
        r = accepted / proposed
        self.rate = self.ewma * self.rate + (1.0 - self.ewma) * r
        i = self._ladder.index(self.k)  # always on-ladder (post_init)
        if self.rate >= self.raise_at and self.k < self.k_max:
            self.k = self._ladder[min(i + 1, len(self._ladder) - 1)]
        elif self.rate <= self.lower_at:
            if i > 1:
                # never adapt down to 0: k = 0 is reserved for the
                # no-gap regime (choose_k), not for unlucky streaks
                self.k = self._ladder[i - 1]
            elif self.draft_bits < self.max_draft_bits:
                # shortest drafts still bounce: the view is too coarse
                self.draft_bits = min(self.draft_bits + self.bits_step,
                                      self.max_draft_bits)
                self.rate = 0.5  # evidence against the old draft is void

    def on_upgrade(self) -> None:
        """A precision stage landed: the draft/target gap changed, so
        past acceptance evidence is stale — relax toward the prior."""
        self.rate = 0.5 * (self.rate + 0.5)


def schedule_from_stages(bits: int, stage_bits: Sequence[int]) -> PlaneSchedule:
    """Convenience: the paper's '2 -> 4 -> 6 -> ... -> 16' notation gives
    cumulative bits; convert to widths."""
    widths, prev = [], 0
    for c in stage_bits:
        widths.append(c - prev)
        prev = c
    return PlaneSchedule(bits=bits, widths=tuple(widths))
