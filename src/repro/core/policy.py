"""Division policies: how a model's tensors are cut into transmission
stages.

The paper exposes ``b`` (plane widths) as the user-facing knob and ships
every tensor's m-th plane in stage m. We keep that as the default
(``UniformPolicy``) and add two beyond-paper policies that exploit
structure a browser client doesn't have:

* ``LayerPriorityPolicy`` — within a stage, order tensors by a priority
  score (e.g. first/last layers first, embeddings first), so the earliest
  *partial* stage is already maximally useful.
* ``ExpertPopularityPolicy`` — for MoE models: planes of popular experts
  (by router statistics) ship before unpopular ones; a serving pod
  becomes useful for the majority of tokens earlier.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.bitplanes import PlaneSchedule, PAPER_DEFAULT


@dataclasses.dataclass(frozen=True)
class TensorPlan:
    """Per-tensor plan: the plane schedule plus a stage->order priority."""

    schedule: PlaneSchedule
    priority: float = 0.0  # lower ships earlier within a stage


class DivisionPolicy:
    """Maps a tensor path (tuple of pytree keys) to a TensorPlan."""

    def plan(self, path: tuple, shape: tuple, dtype, slice_idx: int | None = None
             ) -> TensorPlan:  # pragma: no cover - interface
        raise NotImplementedError

    def slice_spec(self, path: tuple, shape: tuple) -> int | None:
        """Return an axis to slice this tensor along (one sub-tensor per
        index, each with its own quantization range and priority), or
        None to keep it whole. Used for expert banks: per-expert slices
        give (a) priority ordering by router popularity and (b) tighter
        per-expert (min, max) ranges."""
        return None

    @property
    def n_stages(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformPolicy(DivisionPolicy):
    """The paper's policy: one PlaneSchedule shared by every tensor."""

    schedule: PlaneSchedule = PAPER_DEFAULT

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        return TensorPlan(schedule=self.schedule)

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


def _path_str(path: tuple) -> str:
    from repro.core.wire import path_str

    return path_str(path)


@dataclasses.dataclass(frozen=True)
class LayerPriorityPolicy(DivisionPolicy):
    """Uniform widths, but tensors ordered within a stage by a scoring
    function over their path (lower score first)."""

    schedule: PlaneSchedule = PAPER_DEFAULT
    score: Callable[[str], float] = staticmethod(lambda p: 0.0)

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        return TensorPlan(schedule=self.schedule, priority=self.score(_path_str(path)))

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


def embeddings_first_score(path: str) -> float:
    """Heuristic: embeddings and final norm/head first, then shallow to
    deep layers. A truncated first stage then covers the I/O surfaces."""
    p = path.lower()
    if "embed" in p or "head" in p or "final" in p:
        return 0.0
    import re

    m = re.search(r"(\d+)", p)
    return 1.0 + (int(m.group(1)) if m else 0)


_EXPERT_BANK_RE = r"we_(gate|up|down)"


@dataclasses.dataclass(frozen=True)
class ExpertPopularityPolicy(DivisionPolicy):
    """MoE-aware (beyond-paper): expert banks are *sliced* along the
    expert axis, each slice quantized with its own (min, max) and given
    priority = -popularity, so the most-routed experts' planes ship
    first and each expert-parallel chip can fetch only its slices.
    ``popularity`` maps expert index -> routing fraction (router stats);
    ``n_experts`` identifies the expert axis (the dim of that size)."""

    schedule: PlaneSchedule = PAPER_DEFAULT
    popularity: Mapping[int, float] = dataclasses.field(default_factory=dict)
    n_experts: int = 0
    # expert slices ship after core tensors (priority 0) by default;
    # within experts, hot ones first
    expert_base_priority: float = 1.0

    def slice_spec(self, path, shape) -> int | None:
        import re

        if not re.search(_EXPERT_BANK_RE, _path_str(path)):
            return None
        if not self.n_experts:
            return None
        for ax, d in enumerate(shape):
            if d == self.n_experts:
                return ax
        return None

    def plan(self, path, shape, dtype, slice_idx=None) -> TensorPlan:
        prio = 0.0
        if slice_idx is not None:
            prio = self.expert_base_priority - float(
                self.popularity.get(slice_idx, 0.0))
        return TensorPlan(schedule=self.schedule, priority=prio)

    @property
    def n_stages(self) -> int:
        return self.schedule.n_planes


def schedule_from_stages(bits: int, stage_bits: Sequence[int]) -> PlaneSchedule:
    """Convenience: the paper's '2 -> 4 -> 6 -> ... -> 16' notation gives
    cumulative bits; convert to widths."""
    widths, prev = [], 0
    for c in stage_bits:
        widths.append(c - prev)
        prev = c
    return PlaneSchedule(bits=bits, widths=tuple(widths))
