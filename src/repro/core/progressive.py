"""ProgressiveModel: the paper's pipeline (Fig. 3) lifted to pytrees.

Server side (once, before deployment):
    ``divide(params, policy)`` -> ProgressiveModel
        quantize every float leaf (eq. 2), bit-divide it (eq. 3), and
        organize planes into transmission *stages*.

Client side (per stage arrival):
    ``ReceiverState.receive(stage)`` OR-accumulates planes (eq. 4);
    ``ReceiverState.materialize()`` dequantizes (eq. 5) into a params
    pytree of the original structure/dtypes, usable by the unmodified
    model ``apply``.

Non-float leaves (ints, bools — e.g. RoPE tables built on the fly don't
exist in params, but masks might) ship verbatim in stage 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes
from repro.core.plane_store import PlaneStore
from repro.core.policy import DivisionPolicy, UniformPolicy, TensorPlan
from repro.core.quantize import quantize


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


@dataclasses.dataclass
class TensorPlanes:
    """Server-side per-tensor artifact: metadata + all planes.

    A leaf may be sliced along ``slice_axis`` (expert banks): one
    TensorPlanes per slice, each with its own (lo, hi) range and
    priority; ``shape`` is then the slice's shape (axis removed) and the
    receiver stacks slices back along ``slice_axis``."""

    path: tuple
    plan: TensorPlan
    lo: jax.Array
    hi: jax.Array
    shape: tuple
    orig_dtype: Any
    planes: list[jax.Array]  # MSB-first, len == n_planes
    slice_axis: int | None = None
    slice_idx: int = 0
    n_slices: int = 1

    @property
    def bits(self) -> int:
        return self.plan.schedule.bits


@dataclasses.dataclass
class ProgressiveModel:
    """The divided model, ready for staged transmission."""

    tensors: list[TensorPlanes]
    treedef: Any
    n_stages: int
    passthrough: list[tuple[tuple, Any]]  # (path, non-float leaf)

    def stage(self, s: int) -> list[tuple[int, jax.Array]]:
        """Planes shipped in stage s (1-indexed): [(tensor_idx, plane)],
        ordered by the policy's priority."""
        if not (1 <= s <= self.n_stages):
            raise ValueError(f"stage {s} outside [1, {self.n_stages}]")
        out = []
        for i, t in enumerate(self.tensors):
            if s <= t.plan.schedule.n_planes:
                out.append((i, t.planes[s - 1]))
        out.sort(key=lambda it: (self.tensors[it[0]].plan.priority, it[0]))
        return out

    def stage_payload_bytes(self, s: int) -> int:
        total = 0
        for i, plane in self.stage(s):
            t = self.tensors[i]
            w = t.plan.schedule.widths[s - 1]
            total += -(-int(np.prod(t.shape)) * w // 8)  # ceil
        return total

    def total_payload_bytes(self) -> int:
        return sum(self.stage_payload_bytes(s) for s in range(1, self.n_stages + 1))

    def singleton_payload_bytes(self) -> int:
        """Bytes of the non-progressive k-bit quantized model (the
        paper's baseline). total_payload_bytes() equals this up to
        per-plane byte-boundary padding (< 1 byte per plane per tensor)
        — the paper's 'no size increase' property. See
        ``padding_overhead_bound``."""
        total = 0
        for t in self.tensors:
            total += -(-int(np.prod(t.shape)) * t.bits // 8)
        return total

    def padding_overhead_bound(self) -> int:
        """Max extra wire bytes vs. singleton from rounding each plane up
        to a byte boundary."""
        return sum(t.plan.schedule.n_planes for t in self.tensors)


def divide(params, policy: DivisionPolicy | None = None) -> ProgressiveModel:
    """Quantize + bit-divide a params pytree (paper steps 1-2)."""
    policy = policy or UniformPolicy()
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(params)
    tensors: list[TensorPlanes] = []
    passthrough: list[tuple[tuple, Any]] = []
    for path, leaf in leaves_with_paths:
        if not _is_float(leaf):
            passthrough.append((path, leaf))
            continue
        arr = jnp.asarray(leaf)
        axis = policy.slice_spec(path, arr.shape)
        if axis is None:
            slices = [(None, 0, 1, arr)]
        else:
            n = arr.shape[axis]
            slices = [(axis, e, n, jnp.take(arr, e, axis=axis))
                      for e in range(n)]
        for slice_axis, idx, n_slices, sub in slices:
            plan = policy.plan(path, sub.shape, arr.dtype,
                               slice_idx=None if slice_axis is None else idx)
            qt = quantize(sub, plan.schedule.bits)
            planes = bitplanes.split(qt, plan.schedule.widths)
            tensors.append(
                TensorPlanes(
                    path=path,
                    plan=plan,
                    lo=qt.lo,
                    hi=qt.hi,
                    shape=tuple(sub.shape),
                    orig_dtype=arr.dtype,
                    planes=planes,
                    slice_axis=slice_axis,
                    slice_idx=idx,
                    n_slices=n_slices,
                )
            )
    return ProgressiveModel(
        tensors=tensors,
        treedef=treedef,
        n_stages=policy.n_stages,
        passthrough=passthrough,
    )


@dataclasses.dataclass
class ReceiverState:
    """Client-side accumulator (paper steps 3-4), a thin functional
    shell over the shared :class:`~repro.core.plane_store.PlaneStore`.

    ``receive`` is the eq. (4) OR — one batched integer Pallas launch
    per container dtype, no float work — and ``materialize`` is eq. (5),
    incremental: tensors that received nothing since the last call come
    back from the store's leaf cache. The store is device-resident, so
    in the serving engine a precision upgrade never stalls decoding.
    """

    model_meta: ProgressiveModel  # planes unused client-side; meta only
    store: PlaneStore
    received_stages: int = 0

    @classmethod
    def init(cls, model: ProgressiveModel, *, mesh=None) -> "ReceiverState":
        """``mesh=None`` (default): single-device flat-buffer store.
        With a serving mesh, the accumulators shard across its model
        axis (:class:`~repro.core.plane_store.ShardedPlaneStore`) along
        the same axes ``launch.sharding.serving_spec_for_param`` gives
        the params they back — same eq. (4)/(5) semantics, shard-local
        ingest."""
        if mesh is not None:
            from repro.core.plane_store import ShardedPlaneStore
            return cls(model_meta=model,
                       store=ShardedPlaneStore.from_model(model, mesh),
                       received_stages=0)
        return cls(model_meta=model, store=PlaneStore.from_model(model),
                   received_stages=0)

    @property
    def acc(self) -> list[jax.Array]:
        """Per-tensor accumulator views (compat with the pre-PlaneStore
        API; the storage is the store's flat buffers)."""
        return [self.store.acc(i) for i in range(self.store.n_tensors)]

    def receive(self, stage_planes: Sequence[tuple[int, jax.Array]]) -> "ReceiverState":
        store = self.store.copy()
        store.ingest(stage_planes)
        return dataclasses.replace(
            self, store=store, received_stages=self.received_stages + 1)

    def effective_bits(self, tensor_idx: int) -> int:
        return self.store.effective_bits(tensor_idx)

    def materialize(self):
        """Dequantize the current accumulators into the original pytree
        (stacking sliced tensors back along their slice axis)."""
        return rebuild_params(self.model_meta, self.store.materialize_leaves())

    def materialize_resident(self, eligible=None, *, bits=None):
        """The quantized-resident view of the same pytree: eligible
        weight leaves stay :class:`~repro.core.quantize.QuantizedTensor`
        views over the store's accumulators (no fp copy); the rest
        dequantize as in :meth:`materialize`. ``eligible`` defaults to
        the model dispatch's matmul-leaf predicate — a bare ``None``
        would quantize every >=2-D leaf, including ones (conv kernels,
        recurrence matrices) the model consumes without dispatch.
        ``bits=b`` hands out the truncated-precision draft view instead
        (same accumulators, deferred plane mask — zero extra weight
        bytes; see ``PlaneStore.quantized_leaves``)."""
        if eligible is None:
            from repro.models.common import quantized_resident_eligible
            eligible = quantized_resident_eligible
        return rebuild_params(
            self.model_meta,
            self.store.quantized_leaves(eligible=eligible, bits=bits))


def rebuild_params(model: ProgressiveModel, tensor_leaves: Mapping,
                   *, key_fn: Callable[[tuple], Any] | None = None):
    """Rebuild the original params pytree from materialized float leaves.

    ``tensor_leaves`` maps ``key_fn(path)`` -> dequantized array (one
    entry per *leaf*; sliced tensors are already restacked by the
    store). Non-float passthrough leaves come from the model meta. The
    default key is the raw path tuple (``ReceiverState``); the wire
    client keys its store by ``wire.path_str``, so a server sitting on a
    wire-fed store passes ``key_fn=wire.path_str``.
    """
    key_fn = key_fn or (lambda p: p)
    ordered = []
    for path, kind in _all_paths(model):
        ordered.append(kind[1] if kind[0] == "p"
                       else tensor_leaves[key_fn(path)])
    return jax.tree_util.tree_unflatten(model.treedef, ordered)


def _all_paths(model: ProgressiveModel):
    """All (path, kind) in original flatten order."""
    tensor_paths = {t.path: ("t", i) for i, t in enumerate(model.tensors)}
    pass_paths = {p: ("p", leaf) for p, leaf in model.passthrough}
    # tree_flatten_with_path order == tree_flatten order; reconstruct it
    # from the union, sorted by the order we saw them (tensors and
    # passthrough were appended in flatten order, so merge by key lookup).
    # We stored them separately; rebuild by walking both lists.
    merged: list[tuple[tuple, Any]] = []
    ti = pi = 0
    # flatten order is recoverable because each path appears exactly once;
    # we re-flatten a skeleton of the treedef to get the order.
    n = len({t.path for t in model.tensors}) + len(model.passthrough)
    skeleton = jax.tree_util.tree_unflatten(model.treedef, list(range(n)))
    flat, _ = jax.tree_util.tree_flatten_with_path(skeleton)
    for path, _leaf in flat:
        merged.append((path, tensor_paths.get(path) or pass_paths.get(path)))
    return merged


def transmit_reconstruct(params, policy: DivisionPolicy | None = None, upto_stage: int | None = None):
    """One-shot helper: divide, 'transmit' stages [1..upto], materialize.

    The workhorse of tests and accuracy benchmarks: returns the
    approximate params a client would hold after ``upto_stage`` stages.
    """
    model = divide(params, policy)
    upto = model.n_stages if upto_stage is None else upto_stage
    st = ReceiverState.init(model)
    for s in range(1, upto + 1):
        st = st.receive(model.stage(s))
    return st.materialize()
