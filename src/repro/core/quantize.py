"""Floor-quantization and dequantization (paper eqs. 2 and 5).

The paper quantizes every floating-point tensor of a model to a k-bit
unsigned integer with a *flooring* quantizer (eq. 2); flooring — rather
than rounding — is what makes bit-plane prefixes exact (Jin et al.,
AdaBits): the first m planes of a floor-quantized value are themselves
the floor-quantization of that value at Σ_{i<=m} b_i bits.

Dequantization (eq. 5) adds the half-LSB revision factor ``1/2^{k+1}``
that re-centres the floor error, so the expected reconstruction error is
zero and the worst case is half an LSB of the *received* precision.

All functions are jit-able and operate on single arrays; pytree plumbing
lives in :mod:`repro.core.progressive`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Container dtype for quantized values. k <= 16 everywhere in the paper;
# we keep the container at uint16 for k <= 16 and uint32 above.
MAX_BITS = 16


def container_dtype(k: int) -> jnp.dtype:
    if k <= 8:
        return jnp.uint8
    if k <= 16:
        return jnp.uint16
    if k <= 32:
        return jnp.uint32
    raise ValueError(f"k={k} exceeds 32-bit container")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A k-bit floor-quantized tensor plus its dequantization range.

    ``q`` holds unsigned integers in [0, 2^k); ``lo``/``hi`` are the
    original per-tensor min/max (float32 arrays), ``bits`` the
    quantization width k (static).

    As a registered pytree node this doubles as a *live parameter leaf*
    for quantized-resident serving: ``q`` is then a view into the
    PlaneStore's flat accumulator and ``scale``/``offset`` carry the
    eq.-(5) affine (:func:`dequant_affine`) as traced arrays of shape
    ``q.shape[:-2] + (1, 1)``, with ``received_bits`` riding along as
    traced metadata. Everything that changes across a precision upgrade
    (q values, scale, offset, received_bits) is a pytree *child*, and
    everything static (bits, orig_dtype) is aux data — so a jitted
    consumer keeps one cache entry across every upgrade.

    ``keep_bits`` is the *deferred plane mask* of a truncated-precision
    view (:meth:`truncate`): when set, consumers keep only the top
    ``keep_bits`` bits of ``q`` — the mask is applied inside the
    consuming op (models/common dispatch), so the masked uint never
    exists as a second weight buffer; ``q`` stays the *same* array
    object as the full-precision view's. None means no masking (and no
    masking ops in the consumer's jaxpr).
    """

    q: jax.Array
    lo: jax.Array
    hi: jax.Array
    bits: int
    orig_dtype: Any = jnp.float32
    scale: jax.Array | None = None      # traced eq.-(5) slope
    offset: jax.Array | None = None     # traced eq.-(5) intercept
    received_bits: jax.Array | None = None  # traced effective precision m
    keep_bits: jax.Array | None = None  # traced deferred-mask width

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return ((self.q, self.lo, self.hi, self.scale, self.offset,
                 self.received_bits, self.keep_bits),
                (self.bits, self.orig_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, lo, hi, scale, offset, received_bits, keep_bits = children
        bits, orig_dtype = aux
        return cls(q=q, lo=lo, hi=hi, bits=bits, orig_dtype=orig_dtype,
                   scale=scale, offset=offset, received_bits=received_bits,
                   keep_bits=keep_bits)

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def T(self) -> "QuantizedTensor":
        """Transposed view (2-D only): ``q`` transposes, the per-tensor
        affine is invariant. Lets ``x @ embed.T`` (tied unembedding)
        ride the same dequant-matmul dispatch."""
        if self.q.ndim != 2:
            raise ValueError(f"T needs a 2-D tensor, got shape {self.shape}")
        return dataclasses.replace(self, q=self.q.T)

    @property
    def nbytes_payload(self) -> int:
        """Payload bytes if packed densely at ``bits`` bits per element."""
        import math

        return math.ceil(self.q.size * self.bits / 8)

    def truncate(self, b: int) -> "QuantizedTensor":
        """Truncated-precision *view*: behave as if only the first planes
        totalling ``b`` bits had been received, without copying ``q``.

        The returned leaf shares this tensor's ``q`` buffer verbatim and
        carries the truncation as a deferred mask (``keep_bits``) plus a
        recomputed eq.-(5) affine for ``min(b, received)`` effective
        bits. Consumers (the dequant dispatch in ``models/common``) mask
        ``q`` on the fly, so no second weight buffer ever exists — this
        is the self-speculative draft view. The floor-quantization prefix
        property makes the masked value bit-identical to freshly
        quantizing the source at ``b`` bits (pinned by tests).
        """
        if not (0 <= b <= self.bits):
            raise ValueError(f"b={b} outside [0, {self.bits}]")
        if self.received_bits is not None:
            recv = jnp.minimum(self.received_bits, jnp.int32(b))
        else:
            recv = jnp.broadcast_to(
                jnp.int32(b), self.q.shape[:-2] + (1, 1)
                if self.q.ndim >= 2 else ())
        span = self.hi.astype(jnp.float32) - self.lo.astype(jnp.float32)
        span = span + _range_eps(self.lo, self.hi)
        # eq. (5) at m = recv effective bits, with q left in the k-bit
        # container: scale is unchanged (span * 2^-k); only the half-LSB
        # revision in the offset moves to the truncated precision. recv
        # is traced, so jnp.where keeps the m == 0 centre-of-range case
        # recompile-free. ldexp builds the exact power of two, so the
        # offset is bit-identical to dequant_affine's 0.5 ** (m + 1).
        lo32 = jnp.asarray(self.lo, jnp.float32)
        half_lsb = jnp.ldexp(jnp.float32(1.0), -(recv.astype(jnp.int32) + 1))
        offset = jnp.where(recv > 0,
                           lo32 + span * half_lsb,
                           lo32 + span * 0.5)
        shape = self.scale.shape if self.scale is not None else offset.shape
        scale = (self.scale if self.scale is not None
                 else jnp.broadcast_to(span * (0.5 ** self.bits), shape))
        return dataclasses.replace(
            self, scale=scale,
            offset=jnp.broadcast_to(offset, shape),
            received_bits=jnp.broadcast_to(recv, shape).astype(jnp.int32),
            keep_bits=jnp.broadcast_to(recv, shape).astype(jnp.int32))


# ε of eq. (2): keeps the scaled value strictly below 2^k so floor lands
# in [0, 2^k). Relative so it behaves across magnitudes.
_EPS_REL = 1e-6
_EPS_ABS = 1e-12


def _range_eps(lo: jax.Array, hi: jax.Array) -> jax.Array:
    span = hi - lo
    return span * _EPS_REL + _EPS_ABS


def quantize(x: jax.Array, bits: int) -> QuantizedTensor:
    """Eq. (2): q<k> = floor(2^k * (x - min) / (max - min + eps))."""
    if not (1 <= bits <= 32):
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf)
    hi = jnp.max(xf)
    span = hi - lo + _range_eps(lo, hi)
    scaled = (xf - lo) / span
    q = jnp.floor(jnp.ldexp(scaled, bits))
    # Guard: numerical edge can land exactly on 2^k; clamp into range.
    q = jnp.clip(q, 0, 2.0**bits - 1)
    return QuantizedTensor(
        q=q.astype(container_dtype(bits)),
        lo=lo,
        hi=hi,
        bits=bits,
        orig_dtype=x.dtype,
    )


def affine_span(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """The eq.-(5) ε-widened range ``hi - lo + ε`` — the quantity both
    ``scale`` and ``offset`` are proportional to. Exposed so callers
    that cache affine constants across precision upgrades (the
    PlaneStore's quantized-resident metadata) derive them from the
    same expression ``dequant_affine`` uses."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    return hi - lo + _range_eps(lo, hi)


def dequant_affine(lo: jax.Array, hi: jax.Array, bits: int,
                   received_bits: int | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Eq. (5) as an affine map: ``w = scale * q + offset``.

    THE one place the dequantization slope/intercept (and its ε-widened
    span — the same :func:`_range_eps` eq. (2) uses) is computed.
    ``quantize.dequantize``, the fused ``kernels/dequant_matmul``
    wrapper, and the ``kernels/ref`` oracles all call this, so the
    half-LSB revision factor cannot drift between the materialized and
    the fused path.

    ``received_bits`` is the effective precision m = Σ b_i of the planes
    OR-ed in so far; the revision factor is half *that* LSB, which is
    what makes truncated models unbiased. With m == 0 the offset is the
    range centre (q is all-zero, so ``scale`` is moot).

    Returns float32 arrays shaped like ``lo``/``hi`` (broadcastable
    against ``q``). Callers that feed the Pallas kernel reshape them to
    the traced ``(1, 1)`` operands it expects.
    """
    k = bits
    m = k if received_bits is None else received_bits
    if not (0 <= m <= k):
        raise ValueError(f"received_bits={m} outside [0, {k}]")
    lo = jnp.asarray(lo, jnp.float32)
    span = affine_span(lo, hi)
    scale = span * (0.5 ** k)
    if m > 0:
        offset = lo + span * (0.5 ** (m + 1))
    else:
        # Nothing received: centre of the whole range.
        offset = lo + span * 0.5
    return scale, offset


def dequantize(qt: QuantizedTensor, received_bits: int | None = None) -> jax.Array:
    """Eq. (5): M' = (max-min) * q'/2^k + min + 1/2^{k+1} * (max-min).

    The paper writes the revision factor as ``1/2^{k+1}``; dimensional
    consistency (and the reference implementation) put it in the *value*
    domain, i.e. scaled by the range — half an LSB of the received
    precision. Computed as ``scale * q + offset`` via
    :func:`dequant_affine` — the *same* expression, evaluated in the
    same order, as the fused dequant-matmul kernel, so the materialized
    and the quantized-resident serving paths see bit-identical weights.
    """
    scale, offset = dequant_affine(qt.lo, qt.hi, qt.bits, received_bits)
    val = qt.q.astype(jnp.float32) * scale + offset
    return val.astype(qt.orig_dtype)


# -- batched eq. (5): the upgrade hot path -------------------------------
#
# A precision upgrade re-dequantizes every dirty tensor. Doing that with
# per-tensor `dequantize` costs ~10 eager op dispatches per leaf — tens
# of milliseconds of host time for a whole model, which is the entire
# stall budget of a double-buffered upgrade. The batched path below does
# the same eq. (5) for N tensors in O(1) dispatches.
#
# Bit-exactness constraint: the obvious fix — one jitted
# `q * scale + offset` per leaf — is WRONG: XLA:CPU's LLVM backend
# contracts a multiply feeding an add into an FMA (and strips
# `optimization_barrier` before codegen), drifting the materialized
# weights one ulp off the eagerly-evaluated oracle and the fused
# dequant-matmul kernel. So the batch runs as:
#
#   * the affine constants, evaluated EAGERLY but vectorized over
#     stacked (N,) lo/hi — elementwise ops are per-element identical to
#     the scalar evaluation, and each eager op is its own executable so
#     nothing can contract across them;
#   * one jitted executable of multiplies only (`q.astype(f32) * scale`)
#     and one of adds only (`prod + offset`, then the output cast) —
#     neither jaxpr contains an add fed by a multiply, so there is
#     nothing for LLVM to contract and the boundary between them forces
#     the product to round to f32, exactly like the eager oracle.


def dequant_constants(los: Sequence[jax.Array], his: Sequence[jax.Array],
                      bits_seq: Sequence[int]
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stacked per-tensor eq.-(5) constants ``(lo, span, scale)`` that do
    not depend on received bits — computable once per store and reused
    across every upgrade. Same expressions, same evaluation order as
    :func:`dequant_affine`."""
    lo = jnp.stack([jnp.asarray(l, jnp.float32) for l in los])
    hi = jnp.stack([jnp.asarray(h, jnp.float32) for h in his])
    span = hi - lo + _range_eps(lo, hi)
    c = jnp.asarray(np.array([0.5 ** k for k in bits_seq], np.float32))
    return lo, span, span * c


def dequant_offsets(constants: tuple[jax.Array, jax.Array, jax.Array],
                    bits_seq: Sequence[int],
                    received_seq: Sequence[int | None]) -> jax.Array:
    """Stacked per-tensor eq.-(5) offsets at the given received
    precisions — the only affine term an upgrade actually changes.
    Two eager dispatches regardless of N."""
    lo, span, _ = constants
    cs = []
    for k, m in zip(bits_seq, received_seq):
        m = k if m is None else m
        if not (0 <= m <= k):
            raise ValueError(f"received_bits={m} outside [0, {k}]")
        cs.append(0.5 ** (m + 1) if m > 0 else 0.5)
    return lo + span * jnp.asarray(np.array(cs, np.float32))


@jax.jit
def _dq_scale_jit(qs: list, scale_vec: jax.Array) -> list:
    # multiplies only — no add in this jaxpr, so no FMA contraction
    return [q.astype(jnp.float32) * scale_vec[i] for i, q in enumerate(qs)]


@functools.partial(jax.jit, static_argnames="specs")
def _dq_slice_scale_jit(buffers: dict, scale_vec: jax.Array,
                        specs: tuple) -> list:
    # slice + convert + multiply only — again no add in the jaxpr.
    # Slicing the accumulators INSIDE the executable matters: an eager
    # host-side slice of a freshly-ingested buffer blocks the host on
    # the in-flight plane OR, which is precisely the stall the
    # double-buffered upgrade path exists to avoid.
    out = []
    for i, (dt, off, size, shape) in enumerate(specs):
        q = jax.lax.slice(buffers[dt], (off,), (off + size,))
        out.append(q.reshape(shape).astype(jnp.float32) * scale_vec[i])
    return out


@functools.partial(jax.jit, static_argnames="dtypes")
def _dq_shift_jit(prods: list, offset_vec: jax.Array, dtypes: tuple) -> list:
    # adds + output casts only — no multiply in this jaxpr
    return [(p + offset_vec[i]).astype(jnp.dtype(dt))
            for i, (p, dt) in enumerate(zip(prods, dtypes))]


def dequantize_batch(qts: Sequence[QuantizedTensor],
                     received: Sequence[int | None] | None = None, *,
                     constants: tuple[jax.Array, jax.Array, jax.Array] | None = None
                     ) -> list[jax.Array]:
    """Eq. (5) for many tensors at once, bit-identical per tensor to
    :func:`dequantize` (tests assert byte equality) but O(1) host
    dispatches for the whole batch. ``constants`` accepts a cached
    :func:`dequant_constants` result (lo/hi/bits never change after
    quantization, so stores cache it across upgrades)."""
    if not qts:
        return []
    if received is None:
        received = [None] * len(qts)
    bits_seq = [qt.bits for qt in qts]
    if constants is None:
        constants = dequant_constants([qt.lo for qt in qts],
                                      [qt.hi for qt in qts], bits_seq)
    offs = dequant_offsets(constants, bits_seq, received)
    prods = _dq_scale_jit([qt.q for qt in qts], constants[2])
    dtypes = tuple(np.dtype(qt.orig_dtype).name for qt in qts)
    return _dq_shift_jit(prods, offs, dtypes)


def dequantize_buffers(buffers: Mapping[str, jax.Array],
                       specs: Sequence[tuple[str, int, int, tuple]],
                       bits_seq: Sequence[int],
                       received: Sequence[int | None],
                       dtypes: Sequence[str], *,
                       constants: tuple[jax.Array, jax.Array, jax.Array]
                       ) -> list[jax.Array]:
    """:func:`dequantize_batch` when the quantized values live as flat
    spans of shared container buffers (the PlaneStore layout): each
    ``specs`` entry is ``(container_dtype_name, offset, size, shape)``
    and the slicing happens inside the jitted executable, so the host
    never touches — and never blocks on — a buffer whose plane OR is
    still in flight. Output values are byte-identical to slicing
    eagerly and calling :func:`dequantize` per tensor."""
    if not specs:
        return []
    offs = dequant_offsets(constants, bits_seq, received)
    prods = _dq_slice_scale_jit(dict(buffers), constants[2], tuple(specs))
    return _dq_shift_jit(prods, offs, tuple(dtypes))


def quantization_error_bound(qt: QuantizedTensor, received_bits: int | None = None) -> jax.Array:
    """Worst-case |x - dequantize(quantize(x))| = half an LSB at m bits."""
    m = qt.bits if received_bits is None else received_bits
    span = qt.hi - qt.lo + _range_eps(qt.lo, qt.hi)
    # Half an LSB at m bits, plus slack for fp32 rounding in the
    # (x - lo) / span forward computation (can move a value across one
    # grid boundary near the top of the range).
    fp32_slack = span * (0.5**m) * 2.0**-7 + jnp.maximum(jnp.abs(qt.lo), jnp.abs(qt.hi)) * 2.0**-22
    return span * (0.5**m) * 0.5 + fp32_slack + _EPS_ABS


def truncate(qt: QuantizedTensor, m: int) -> QuantizedTensor:
    """Keep only the m most-significant bits (what a receiver holds after
    the first planes totalling m bits). Useful as an oracle: receiving
    planes [b_1..b_j] must equal ``truncate(q, sum(b[:j]))`` shifted."""
    if not (0 <= m <= qt.bits):
        raise ValueError(f"m={m} outside [0, {qt.bits}]")
    shift = qt.bits - m
    q = (qt.q >> shift) << shift
    return dataclasses.replace(qt, q=q)
