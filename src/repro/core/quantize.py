"""Floor-quantization and dequantization (paper eqs. 2 and 5).

The paper quantizes every floating-point tensor of a model to a k-bit
unsigned integer with a *flooring* quantizer (eq. 2); flooring — rather
than rounding — is what makes bit-plane prefixes exact (Jin et al.,
AdaBits): the first m planes of a floor-quantized value are themselves
the floor-quantization of that value at Σ_{i<=m} b_i bits.

Dequantization (eq. 5) adds the half-LSB revision factor ``1/2^{k+1}``
that re-centres the floor error, so the expected reconstruction error is
zero and the worst case is half an LSB of the *received* precision.

All functions are jit-able and operate on single arrays; pytree plumbing
lives in :mod:`repro.core.progressive`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# Container dtype for quantized values. k <= 16 everywhere in the paper;
# we keep the container at uint16 for k <= 16 and uint32 above.
MAX_BITS = 16


def container_dtype(k: int) -> jnp.dtype:
    if k <= 8:
        return jnp.uint8
    if k <= 16:
        return jnp.uint16
    if k <= 32:
        return jnp.uint32
    raise ValueError(f"k={k} exceeds 32-bit container")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A k-bit floor-quantized tensor plus its dequantization range.

    ``q`` holds unsigned integers in [0, 2^k); ``lo``/``hi`` are the
    original per-tensor min/max (scalar float32 arrays), ``bits`` the
    quantization width k (static).
    """

    q: jax.Array
    lo: jax.Array
    hi: jax.Array
    bits: int
    orig_dtype: Any = jnp.float32

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.lo, self.hi), (self.bits, self.orig_dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, lo, hi = children
        bits, orig_dtype = aux
        return cls(q=q, lo=lo, hi=hi, bits=bits, orig_dtype=orig_dtype)

    @property
    def shape(self):
        return self.q.shape

    @property
    def nbytes_payload(self) -> int:
        """Payload bytes if packed densely at ``bits`` bits per element."""
        import math

        return math.ceil(self.q.size * self.bits / 8)


# ε of eq. (2): keeps the scaled value strictly below 2^k so floor lands
# in [0, 2^k). Relative so it behaves across magnitudes.
_EPS_REL = 1e-6
_EPS_ABS = 1e-12


def _range_eps(lo: jax.Array, hi: jax.Array) -> jax.Array:
    span = hi - lo
    return span * _EPS_REL + _EPS_ABS


def quantize(x: jax.Array, bits: int) -> QuantizedTensor:
    """Eq. (2): q<k> = floor(2^k * (x - min) / (max - min + eps))."""
    if not (1 <= bits <= 32):
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf)
    hi = jnp.max(xf)
    span = hi - lo + _range_eps(lo, hi)
    scaled = (xf - lo) / span
    q = jnp.floor(jnp.ldexp(scaled, bits))
    # Guard: numerical edge can land exactly on 2^k; clamp into range.
    q = jnp.clip(q, 0, 2.0**bits - 1)
    return QuantizedTensor(
        q=q.astype(container_dtype(bits)),
        lo=lo,
        hi=hi,
        bits=bits,
        orig_dtype=x.dtype,
    )


def dequantize(qt: QuantizedTensor, received_bits: int | None = None) -> jax.Array:
    """Eq. (5): M' = (max-min) * q'/2^k + min + 1/2^{k+1} * (max-min).

    The paper writes the revision factor as ``1/2^{k+1}``; dimensional
    consistency (and the reference implementation) put it in the *value*
    domain, i.e. scaled by the range — half an LSB of the received
    precision. ``received_bits`` is the effective precision m = Σ b_i of
    the planes OR-ed in so far; the revision factor must be half *that*
    LSB, which is what makes truncated models unbiased.
    """
    k = qt.bits
    m = k if received_bits is None else received_bits
    if not (0 <= m <= k):
        raise ValueError(f"received_bits={m} outside [0, {k}]")
    # Use the same effective span as eq. (2) (incl. ε) so dequantization
    # exactly inverts the quantizer grid; the deviation from the paper's
    # literal (max - min) is 1e-6 relative and makes the half-LSB error
    # bound hold exactly.
    span = qt.hi - qt.lo + _range_eps(qt.lo, qt.hi)
    val = span * (qt.q.astype(jnp.float32) / (2.0**k)) + qt.lo
    if m > 0:
        val = val + span * (0.5 ** (m + 1))
    else:
        # Nothing received: centre of the whole range.
        val = qt.lo + span * 0.5 + jnp.zeros_like(val)
    return val.astype(qt.orig_dtype)


def quantization_error_bound(qt: QuantizedTensor, received_bits: int | None = None) -> jax.Array:
    """Worst-case |x - dequantize(quantize(x))| = half an LSB at m bits."""
    m = qt.bits if received_bits is None else received_bits
    span = qt.hi - qt.lo + _range_eps(qt.lo, qt.hi)
    # Half an LSB at m bits, plus slack for fp32 rounding in the
    # (x - lo) / span forward computation (can move a value across one
    # grid boundary near the top of the range).
    fp32_slack = span * (0.5**m) * 2.0**-7 + jnp.maximum(jnp.abs(qt.lo), jnp.abs(qt.hi)) * 2.0**-22
    return span * (0.5**m) * 0.5 + fp32_slack + _EPS_ABS


def truncate(qt: QuantizedTensor, m: int) -> QuantizedTensor:
    """Keep only the m most-significant bits (what a receiver holds after
    the first planes totalling m bits). Useful as an oracle: receiving
    planes [b_1..b_j] must equal ``truncate(q, sum(b[:j]))`` shifted."""
    if not (0 <= m <= qt.bits):
        raise ValueError(f"m={m} outside [0, {qt.bits}]")
    shift = qt.bits - m
    q = (qt.q >> shift) << shift
    return dataclasses.replace(qt, q=q)
