"""Wire format for progressive model transmission.

Layout (all little-endian):

    [HEADER]   json (length-prefixed): per-tensor path/shape/dtype/lo/hi,
               plane schedule, stage order. Shipped before stage 1.
    [STAGE 1]  concat of dense bit-packed planes, in policy priority order
    [STAGE 2]  ...
    ...
    [STAGE n]

``total wire bytes == header + singleton quantized payload`` — the
paper's "no size increase" claim, verified by tests. Stages can be cut at
arbitrary byte offsets by the transport; the client state machine in
``transmission/client.py`` resumes mid-plane.
"""
from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np
import jax.numpy as jnp

from repro.core import bitplanes
from repro.core.progressive import ProgressiveModel

MAGIC = b"PGNJ"
VERSION = 1


def _path_key(path: tuple) -> str:
    return path_str(path)


def path_str(path: tuple) -> str:
    """Render a jax tree path as 'a/b/0/c' regardless of key kind."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def encode_header(model: ProgressiveModel) -> bytes:
    meta = {
        "version": VERSION,
        "n_stages": model.n_stages,
        "tensors": [
            {
                "path": _path_key(t.path),
                "shape": list(t.shape),
                "dtype": np.dtype(t.orig_dtype).name,
                "lo": float(t.lo),
                "hi": float(t.hi),
                "bits": t.plan.schedule.bits,
                "widths": list(t.plan.schedule.widths),
                "priority": t.plan.priority,
                "slice_axis": t.slice_axis,
                "slice_idx": t.slice_idx,
                "n_slices": t.n_slices,
            }
            for t in model.tensors
        ],
    }
    body = json.dumps(meta).encode()
    return MAGIC + struct.pack("<II", VERSION, len(body)) + body


def decode_header(buf: bytes):
    if buf[:4] != MAGIC:
        raise ValueError("bad magic")
    version, n = struct.unpack("<II", buf[4:12])
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    meta = json.loads(buf[12 : 12 + n].decode())
    return meta, 12 + n


def encode_stage(model: ProgressiveModel, s: int) -> bytes:
    """Dense bit-packed payload of one stage (no per-plane framing needed:
    sizes are derivable from the header)."""
    chunks = []
    for idx, plane in model.stage(s):
        t = model.tensors[idx]
        w = t.plan.schedule.widths[s - 1]
        packed = bitplanes.pack_bits(jnp.asarray(plane), w)
        chunks.append(np.asarray(packed).tobytes())
    return b"".join(chunks)


def encode(model: ProgressiveModel) -> bytes:
    return encode_header(model) + b"".join(
        encode_stage(model, s) for s in range(1, model.n_stages + 1)
    )


@dataclasses.dataclass
class StageLayout:
    """Byte layout derived purely from the header — what a client needs
    to slice an incoming byte stream into (tensor, plane) payloads."""

    header_bytes: int
    # per stage: list of (tensor_idx, width, payload_bytes, n_elements)
    stages: list[list[tuple[int, int, int, int]]]

    @property
    def stage_bytes(self) -> list[int]:
        return [sum(e[2] for e in st) for st in self.stages]

    @property
    def total_bytes(self) -> int:
        return self.header_bytes + sum(self.stage_bytes)


def layout_from_header(meta: dict, header_bytes: int) -> StageLayout:
    n_stages = meta["n_stages"]
    order = sorted(
        range(len(meta["tensors"])),
        key=lambda i: (meta["tensors"][i]["priority"], i),
    )
    stages = []
    for s in range(1, n_stages + 1):
        entries = []
        for i in order:
            t = meta["tensors"][i]
            if s <= len(t["widths"]):
                w = t["widths"][s - 1]
                n_el = int(np.prod(t["shape"])) if t["shape"] else 1
                nbytes = -(-n_el * w // 8)
                entries.append((i, w, nbytes, n_el))
        stages.append(entries)
    return StageLayout(header_bytes=header_bytes, stages=stages)


def decode_plane(payload: bytes, width: int, n_elements: int) -> np.ndarray:
    packed = jnp.asarray(np.frombuffer(payload, dtype=np.uint8))
    return np.asarray(bitplanes.unpack_bits(packed, width, n_elements))
