"""Wire format for progressive model transmission.

v1 layout (all little-endian):

    [HEADER]   json (length-prefixed): per-tensor path/shape/dtype/lo/hi,
               plane schedule, stage order. Shipped before stage 1.
    [STAGE 1]  concat of dense bit-packed planes, in policy priority order
    [STAGE 2]  ...
    ...
    [STAGE n]

``total wire bytes == header + singleton quantized payload`` — the
paper's "no size increase" claim, verified by tests. Stages can be cut at
arbitrary byte offsets by the transport; the client state machine in
``transmission/client.py`` resumes mid-plane.

v2 layout (``encode(model, schedule=..., entropy_coded=...)``) keeps the
12-byte prefix — the first byte after MAGIC is the explicit version —
but replaces the fixed stage-major plane order with an explicit
(tensor, plane) *unit* list carried in the header:

    [HEADER]   v1 meta + "units" [[t,p],...] + "checkpoints" (prefix
               unit counts standing in for stage ends) + "unit_bytes"
               (on-wire size of each unit incl. frame) + "entropy" flag
    [UNIT 0]   <mode u8><reserved u8> + payload
    [UNIT 1]   ...

Units are MSB-first *within* each tensor (the eq.-(5) contiguous-prefix
invariant ``PlaneStore.ingest`` enforces) but interleave freely *across*
tensors — see :mod:`repro.core.calibrate`. Each unit body is either the
raw packed plane (``MODE_RAW``) or its entropy-coded form
(:mod:`repro.core.entropy`), chosen per-plane so a coded unit is never
larger than raw + the 2-byte frame. ``decode_plane`` undoes the framing
before ``unpack_bits``, so everything downstream of the client —
PlaneStore ingest, OR-reassembly, the eq.-(5) affine — is untouched and
the fully-received model is bit-identical to the v1 stream's.

v3 layout (``encode(model, integrity=True)``) is the fault-tolerant
wire: the same unit stream as v2, but every unit is preceded by an
8-byte integrity frame ``<seq u32><crc u32>`` (seq = unit index in the
schedule; crc = CRC32 over seq+mode+reserved+payload) and the header
carries a trailing whole-header CRC32. Lengths still come exclusively
from the header (``unit_bytes``), so framing is length-safe: a flipped
bit anywhere in a unit is caught by the unit CRC, a flipped bit in the
header by the header CRC, and the client can quarantine + re-request
individual units without losing stream sync. Framing overhead is
bounded and reported (:func:`framing_overhead`): 4 header bytes +
``FRAME_BYTES_V3`` per unit.

``encode(model)`` with no schedule still emits byte-identical v1
streams; ``decode_header`` accepts all three versions.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib

import numpy as np
import jax.numpy as jnp

from repro.core import bitplanes, entropy
from repro.core.progressive import ProgressiveModel

MAGIC = b"PGNJ"
VERSION = 1            # legacy stage-major stream (the default)
VERSION_SCHEDULED = 2  # scheduled/entropy-coded unit stream
VERSION_INTEGRITY = 3  # integrity-framed unit stream (CRC + seq)
SUPPORTED_VERSIONS = (VERSION, VERSION_SCHEDULED, VERSION_INTEGRITY)
FRAME_BYTES = 2        # v2 per-unit frame: <mode u8><reserved u8>
HEADER_CRC_BYTES = 4   # v3: CRC32 of the full header, appended to it
FRAME_BYTES_V3 = 10    # v3 per-unit frame: <seq u32><crc u32><mode u8><u8>
# Plausibility cap on the header's declared JSON length: a corrupted
# length field must not make a client wait forever for bytes that will
# never come. Real headers are a few KB per thousand tensors.
MAX_HEADER_BYTES = 1 << 28


class WireFormatError(ValueError):
    """Malformed wire bytes (truncation, garbage, bad lengths). Raised
    with offset context instead of letting struct/json/index errors
    escape. Subclasses ValueError so legacy callers keep working."""


class WireIntegrityError(WireFormatError):
    """v3 integrity violation: CRC mismatch or unexpected sequence
    number. Distinct from plain format errors so receivers can route it
    to quarantine/re-request instead of treating the stream as
    unparseable."""


def _path_key(path: tuple) -> str:
    return path_str(path)


def path_str(path: tuple) -> str:
    """Render a jax tree path as 'a/b/0/c' regardless of key kind."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _tensor_meta(model: ProgressiveModel) -> list[dict]:
    return [
        {
            "path": _path_key(t.path),
            "shape": list(t.shape),
            "dtype": np.dtype(t.orig_dtype).name,
            "lo": float(t.lo),
            "hi": float(t.hi),
            "bits": t.plan.schedule.bits,
            "widths": list(t.plan.schedule.widths),
            "priority": t.plan.priority,
            "slice_axis": t.slice_axis,
            "slice_idx": t.slice_idx,
            "n_slices": t.n_slices,
        }
        for t in model.tensors
    ]


def encode_header(model: ProgressiveModel) -> bytes:
    meta = {
        "version": VERSION,
        "n_stages": model.n_stages,
        "tensors": _tensor_meta(model),
    }
    body = json.dumps(meta).encode()
    return MAGIC + struct.pack("<II", VERSION, len(body)) + body


def decode_header(buf: bytes):
    """Parse the stream header. Returns ``(meta, header_bytes)``.

    Malformed input raises :class:`WireFormatError` with offset
    context (never a bare struct/json/index error); a v3 header whose
    trailing CRC32 does not cover its bytes raises
    :class:`WireIntegrityError`."""
    if len(buf) < 12:
        raise WireFormatError(
            f"truncated header: need 12 prefix bytes, have {len(buf)}")
    if buf[:4] != MAGIC:
        raise WireFormatError(
            f"bad magic at offset 0: {bytes(buf[:4])!r} != {MAGIC!r}")
    version, n = struct.unpack("<II", buf[4:12])
    if version not in SUPPORTED_VERSIONS:
        raise WireFormatError(f"unsupported version {version} at offset 4")
    if n > MAX_HEADER_BYTES:
        raise WireFormatError(
            f"header declares {n} body bytes at offset 8 "
            f"(cap {MAX_HEADER_BYTES}) — length field is corrupt")
    end = 12 + n
    if len(buf) < end:
        raise WireFormatError(
            f"truncated header: body ends at offset {end}, have {len(buf)}")
    if version == VERSION_INTEGRITY:
        if len(buf) < end + HEADER_CRC_BYTES:
            raise WireFormatError(
                f"truncated header: v3 CRC ends at offset "
                f"{end + HEADER_CRC_BYTES}, have {len(buf)}")
        (crc,) = struct.unpack("<I", buf[end:end + HEADER_CRC_BYTES])
        got = zlib.crc32(bytes(buf[:end])) & 0xFFFFFFFF
        if got != crc:
            raise WireIntegrityError(
                f"header CRC mismatch over [0, {end}): "
                f"computed {got:#010x}, stored {crc:#010x}")
        end += HEADER_CRC_BYTES
    try:
        meta = json.loads(bytes(buf[12:12 + n]).decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(
            f"unparseable header body at offsets [12, {12 + n}): {e}"
        ) from None
    if not isinstance(meta, dict) or "tensors" not in meta:
        raise WireFormatError(
            f"header body at offsets [12, {12 + n}) is valid JSON but "
            f"not a wire header (missing 'tensors')")
    if meta.get("version", version) != version:
        # the prefix version is outside the v3 CRC's reach by necessity
        # (it selects whether a CRC exists at all) — cross-checking it
        # against the JSON body closes the gap where a flipped prefix
        # byte demotes a v3 stream to an unchecked v2 parse
        raise WireFormatError(
            f"version mismatch: prefix says {version} at offset 4, "
            f"header body says {meta['version']}")
    return meta, end


def encode_stage(model: ProgressiveModel, s: int) -> bytes:
    """Dense bit-packed payload of one stage (no per-plane framing needed:
    sizes are derivable from the header)."""
    chunks = []
    for idx, plane in model.stage(s):
        t = model.tensors[idx]
        w = t.plan.schedule.widths[s - 1]
        packed = bitplanes.pack_bits(jnp.asarray(plane), w)
        chunks.append(np.asarray(packed).tobytes())
    return b"".join(chunks)


def encode_unit(model: ProgressiveModel, t_idx: int, p: int,
                *, entropy_coded: bool = False) -> bytes:
    """One v2 shipment unit: 2-byte frame + (raw | entropy-coded) packed
    plane ``p`` of tensor ``t_idx``. Coded only when it wins, so the
    unit is never larger than the raw packed plane + FRAME_BYTES."""
    t = model.tensors[t_idx]
    w = t.plan.schedule.widths[p]
    packed = np.asarray(
        bitplanes.pack_bits(jnp.asarray(t.planes[p]), w)).tobytes()
    if entropy_coded:
        mode, body = entropy.encode(packed)
    else:
        mode, body = entropy.MODE_RAW, packed
    return struct.pack("<BB", mode, 0) + body


def encode_v2(model: ProgressiveModel, schedule=None,
              *, entropy_coded: bool = True) -> bytes:
    """Scheduled/entropy-coded stream. ``schedule`` is a
    :class:`~repro.core.calibrate.TransmissionSchedule` (anything with
    ``units``/``checkpoints``); ``None`` falls back to the v1
    stage-major order (entropy coding alone still applies). Unit sizes
    are data-dependent, so payloads are encoded first and their on-wire
    sizes recorded in the header."""
    if schedule is None:
        from repro.core.calibrate import uniform_schedule
        schedule = uniform_schedule(model)
    payloads = [encode_unit(model, t, p, entropy_coded=entropy_coded)
                for t, p in schedule.units]
    meta = {
        "version": VERSION_SCHEDULED,
        "n_stages": len(schedule.checkpoints),
        "tensors": _tensor_meta(model),
        "units": [[int(t), int(p)] for t, p in schedule.units],
        "checkpoints": [int(c) for c in schedule.checkpoints],
        "unit_bytes": [len(u) for u in payloads],
        "entropy": bool(entropy_coded),
    }
    body = json.dumps(meta).encode()
    header = MAGIC + struct.pack("<II", VERSION_SCHEDULED, len(body)) + body
    return header + b"".join(payloads)


def frame_unit(seq: int, unit: bytes) -> bytes:
    """Wrap a v2-framed unit body (``<mode u8><reserved u8>`` +
    payload) in the v3 integrity frame. The CRC covers the sequence
    number AND the body, so any flipped bit in the on-wire unit —
    including its seq — fails verification."""
    seq_b = struct.pack("<I", seq)
    crc = zlib.crc32(seq_b + unit) & 0xFFFFFFFF
    return seq_b + struct.pack("<I", crc) + unit


def verify_unit(payload: bytes) -> tuple[int, bytes]:
    """Check a v3 unit's integrity frame. Returns ``(seq, body)`` where
    ``body`` is the v2-framed unit (feed it to ``decode_plane(...,
    framed=True)``). Raises :class:`WireIntegrityError` on CRC mismatch
    and :class:`WireFormatError` on truncation."""
    if len(payload) < FRAME_BYTES_V3:
        raise WireFormatError(
            f"v3 unit shorter than its {FRAME_BYTES_V3}-byte frame: "
            f"{len(payload)} bytes")
    seq, crc = struct.unpack("<II", payload[:8])
    body = payload[8:]
    got = zlib.crc32(payload[:4] + body) & 0xFFFFFFFF
    if got != crc:
        raise WireIntegrityError(
            f"unit CRC mismatch (frame claims seq {seq}): "
            f"computed {got:#010x}, stored {crc:#010x}")
    return seq, body


def encode_v3(model: ProgressiveModel, schedule=None,
              *, entropy_coded: bool = False) -> bytes:
    """Integrity-framed stream: v2's unit layout with a per-unit
    ``<seq u32><crc u32>`` frame and a whole-header CRC32. The payload
    bytes inside each frame are exactly the v2 unit encoding, so a
    fully-received v3 stream reconstructs bit-identically to the v1/v2
    streams of the same model."""
    if schedule is None:
        from repro.core.calibrate import uniform_schedule
        schedule = uniform_schedule(model)
    payloads = [
        frame_unit(seq, encode_unit(model, t, p, entropy_coded=entropy_coded))
        for seq, (t, p) in enumerate(schedule.units)
    ]
    meta = {
        "version": VERSION_INTEGRITY,
        "n_stages": len(schedule.checkpoints),
        "tensors": _tensor_meta(model),
        "units": [[int(t), int(p)] for t, p in schedule.units],
        "checkpoints": [int(c) for c in schedule.checkpoints],
        "unit_bytes": [len(u) for u in payloads],
        "entropy": bool(entropy_coded),
    }
    body = json.dumps(meta).encode()
    header = MAGIC + struct.pack("<II", VERSION_INTEGRITY, len(body)) + body
    header += struct.pack("<I", zlib.crc32(header) & 0xFFFFFFFF)
    return header + b"".join(payloads)


def framing_overhead(meta: dict) -> dict:
    """v3 integrity-framing overhead, from a decoded header: absolute
    bytes and the fraction of the total stream they cost. Zero for
    v1/v2. The bound is structural — HEADER_CRC_BYTES plus
    FRAME_BYTES_V3 - FRAME_BYTES per unit — so it is derivable (and
    asserted) without ever shipping the stream."""
    version = meta.get("version", VERSION)
    if version != VERSION_INTEGRITY:
        return {"version": version, "overhead_bytes": 0, "overhead_frac": 0.0}
    n_units = len(meta["units"])
    overhead = HEADER_CRC_BYTES + n_units * (FRAME_BYTES_V3 - FRAME_BYTES)
    total = sum(meta["unit_bytes"])
    return {
        "version": version,
        "n_units": n_units,
        "overhead_bytes": overhead,
        "overhead_frac": overhead / max(total, 1),
        "per_unit_bytes": FRAME_BYTES_V3 - FRAME_BYTES,
    }


def encode(model: ProgressiveModel, *, schedule=None,
           entropy_coded: bool = False, integrity: bool = False) -> bytes:
    """Default call emits byte-identical v1 streams; requesting a
    schedule and/or entropy coding switches to v2; ``integrity=True``
    selects the fault-tolerant v3 framing (composable with both)."""
    if integrity:
        return encode_v3(model, schedule, entropy_coded=entropy_coded)
    if schedule is None and not entropy_coded:
        return encode_header(model) + b"".join(
            encode_stage(model, s) for s in range(1, model.n_stages + 1)
        )
    return encode_v2(model, schedule, entropy_coded=entropy_coded)


@dataclasses.dataclass
class StageLayout:
    """Byte layout derived purely from the header — what a client needs
    to slice an incoming byte stream into (tensor, plane) payloads.

    v1: one stage per plane rank, entries dense-packed. v2
    (``framed=True``): "stages" are checkpoint groups of schedule
    units; each entry's ``payload_bytes`` INCLUDES the 2-byte frame,
    and payloads must pass through :func:`decode_plane` with
    ``framed=True`` to strip the frame / undo entropy coding."""

    header_bytes: int
    # per stage: list of (tensor_idx, width, payload_bytes, n_elements)
    stages: list[list[tuple[int, int, int, int]]]
    framed: bool = False
    # v3: payloads additionally carry the <seq u32><crc u32> integrity
    # frame and MUST pass wire.verify_unit before decode_plane
    integrity: bool = False

    def unit_offsets(self) -> list[int]:
        """Absolute wire offset of each unit's first byte, flattened
        across stages (what a resume cursor / re-request indexes)."""
        offs, off = [], self.header_bytes
        for st in self.stages:
            for e in st:
                offs.append(off)
                off += e[2]
        return offs

    @property
    def stage_bytes(self) -> list[int]:
        return [sum(e[2] for e in st) for st in self.stages]

    @property
    def total_bytes(self) -> int:
        return self.header_bytes + sum(self.stage_bytes)


def layout_from_header(meta: dict, header_bytes: int) -> StageLayout:
    version = meta.get("version", VERSION)
    if version in (VERSION_SCHEDULED, VERSION_INTEGRITY):
        return _layout_v2(meta, header_bytes,
                          integrity=version == VERSION_INTEGRITY)
    n_stages = meta["n_stages"]
    order = sorted(
        range(len(meta["tensors"])),
        key=lambda i: (meta["tensors"][i]["priority"], i),
    )
    stages = []
    for s in range(1, n_stages + 1):
        entries = []
        for i in order:
            t = meta["tensors"][i]
            if s <= len(t["widths"]):
                w = t["widths"][s - 1]
                n_el = int(np.prod(t["shape"])) if t["shape"] else 1
                nbytes = -(-n_el * w // 8)
                entries.append((i, w, nbytes, n_el))
        stages.append(entries)
    return StageLayout(header_bytes=header_bytes, stages=stages)


def _layout_v2(meta: dict, header_bytes: int,
               *, integrity: bool = False) -> StageLayout:
    units = meta["units"]
    unit_bytes = meta["unit_bytes"]
    if len(unit_bytes) != len(units):
        raise ValueError("unit_bytes length mismatch")
    entries = []
    for (t_idx, p), nbytes in zip(units, unit_bytes):
        t = meta["tensors"][t_idx]
        w = t["widths"][p]
        n_el = int(np.prod(t["shape"])) if t["shape"] else 1
        entries.append((int(t_idx), int(w), int(nbytes), n_el))
    stages, lo = [], 0
    for cp in meta["checkpoints"]:
        stages.append(entries[lo:cp])
        lo = cp
    if lo != len(entries):
        raise ValueError("checkpoints do not cover all units")
    return StageLayout(header_bytes=header_bytes, stages=stages,
                       framed=True, integrity=integrity)


def decode_plane(payload: bytes, width: int, n_elements: int,
                 *, framed: bool = False) -> np.ndarray:
    """Unpack one plane payload. ``framed=True`` (v2/v3 body) strips
    the 2-byte mode frame and undoes entropy coding first; the
    recovered packed bytes are identical to the raw path, so
    reconstruction downstream is bit-exact either way. Malformed input
    raises :class:`WireFormatError` with length context. v3 callers
    strip/verify the integrity frame via :func:`verify_unit` first."""
    raw_len = -(-n_elements * width // 8)
    if framed:
        if len(payload) < FRAME_BYTES:
            raise WireFormatError(
                f"framed payload shorter than its {FRAME_BYTES}-byte "
                f"frame: {len(payload)} bytes")
        mode = payload[0]
        try:
            payload = entropy.decode(mode, payload[FRAME_BYTES:], raw_len)
        except Exception as e:
            raise WireFormatError(
                f"undecodable unit body (mode {mode}, "
                f"{len(payload) - FRAME_BYTES} coded bytes for "
                f"{raw_len} raw): {e}") from None
    if len(payload) != raw_len:
        raise WireFormatError(
            f"plane payload is {len(payload)} bytes, expected {raw_len} "
            f"({n_elements} elements x {width} bits)")
    packed = jnp.asarray(np.frombuffer(payload, dtype=np.uint8))
    return np.asarray(bitplanes.unpack_bits(packed, width, n_elements))
