"""Wire format for progressive model transmission.

v1 layout (all little-endian):

    [HEADER]   json (length-prefixed): per-tensor path/shape/dtype/lo/hi,
               plane schedule, stage order. Shipped before stage 1.
    [STAGE 1]  concat of dense bit-packed planes, in policy priority order
    [STAGE 2]  ...
    ...
    [STAGE n]

``total wire bytes == header + singleton quantized payload`` — the
paper's "no size increase" claim, verified by tests. Stages can be cut at
arbitrary byte offsets by the transport; the client state machine in
``transmission/client.py`` resumes mid-plane.

v2 layout (``encode(model, schedule=..., entropy_coded=...)``) keeps the
12-byte prefix — the first byte after MAGIC is the explicit version —
but replaces the fixed stage-major plane order with an explicit
(tensor, plane) *unit* list carried in the header:

    [HEADER]   v1 meta + "units" [[t,p],...] + "checkpoints" (prefix
               unit counts standing in for stage ends) + "unit_bytes"
               (on-wire size of each unit incl. frame) + "entropy" flag
    [UNIT 0]   <mode u8><reserved u8> + payload
    [UNIT 1]   ...

Units are MSB-first *within* each tensor (the eq.-(5) contiguous-prefix
invariant ``PlaneStore.ingest`` enforces) but interleave freely *across*
tensors — see :mod:`repro.core.calibrate`. Each unit body is either the
raw packed plane (``MODE_RAW``) or its entropy-coded form
(:mod:`repro.core.entropy`), chosen per-plane so a coded unit is never
larger than raw + the 2-byte frame. ``decode_plane`` undoes the framing
before ``unpack_bits``, so everything downstream of the client —
PlaneStore ingest, OR-reassembly, the eq.-(5) affine — is untouched and
the fully-received model is bit-identical to the v1 stream's.

``encode(model)`` with no schedule still emits byte-identical v1
streams; ``decode_header`` accepts both versions.
"""
from __future__ import annotations

import dataclasses
import json
import struct

import numpy as np
import jax.numpy as jnp

from repro.core import bitplanes, entropy
from repro.core.progressive import ProgressiveModel

MAGIC = b"PGNJ"
VERSION = 1            # legacy stage-major stream (the default)
VERSION_SCHEDULED = 2  # scheduled/entropy-coded unit stream
SUPPORTED_VERSIONS = (VERSION, VERSION_SCHEDULED)
FRAME_BYTES = 2        # v2 per-unit frame: <mode u8><reserved u8>


def _path_key(path: tuple) -> str:
    return path_str(path)


def path_str(path: tuple) -> str:
    """Render a jax tree path as 'a/b/0/c' regardless of key kind."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _tensor_meta(model: ProgressiveModel) -> list[dict]:
    return [
        {
            "path": _path_key(t.path),
            "shape": list(t.shape),
            "dtype": np.dtype(t.orig_dtype).name,
            "lo": float(t.lo),
            "hi": float(t.hi),
            "bits": t.plan.schedule.bits,
            "widths": list(t.plan.schedule.widths),
            "priority": t.plan.priority,
            "slice_axis": t.slice_axis,
            "slice_idx": t.slice_idx,
            "n_slices": t.n_slices,
        }
        for t in model.tensors
    ]


def encode_header(model: ProgressiveModel) -> bytes:
    meta = {
        "version": VERSION,
        "n_stages": model.n_stages,
        "tensors": _tensor_meta(model),
    }
    body = json.dumps(meta).encode()
    return MAGIC + struct.pack("<II", VERSION, len(body)) + body


def decode_header(buf: bytes):
    if buf[:4] != MAGIC:
        raise ValueError("bad magic")
    version, n = struct.unpack("<II", buf[4:12])
    if version not in SUPPORTED_VERSIONS:
        raise ValueError(f"unsupported version {version}")
    meta = json.loads(buf[12 : 12 + n].decode())
    return meta, 12 + n


def encode_stage(model: ProgressiveModel, s: int) -> bytes:
    """Dense bit-packed payload of one stage (no per-plane framing needed:
    sizes are derivable from the header)."""
    chunks = []
    for idx, plane in model.stage(s):
        t = model.tensors[idx]
        w = t.plan.schedule.widths[s - 1]
        packed = bitplanes.pack_bits(jnp.asarray(plane), w)
        chunks.append(np.asarray(packed).tobytes())
    return b"".join(chunks)


def encode_unit(model: ProgressiveModel, t_idx: int, p: int,
                *, entropy_coded: bool = False) -> bytes:
    """One v2 shipment unit: 2-byte frame + (raw | entropy-coded) packed
    plane ``p`` of tensor ``t_idx``. Coded only when it wins, so the
    unit is never larger than the raw packed plane + FRAME_BYTES."""
    t = model.tensors[t_idx]
    w = t.plan.schedule.widths[p]
    packed = np.asarray(
        bitplanes.pack_bits(jnp.asarray(t.planes[p]), w)).tobytes()
    if entropy_coded:
        mode, body = entropy.encode(packed)
    else:
        mode, body = entropy.MODE_RAW, packed
    return struct.pack("<BB", mode, 0) + body


def encode_v2(model: ProgressiveModel, schedule=None,
              *, entropy_coded: bool = True) -> bytes:
    """Scheduled/entropy-coded stream. ``schedule`` is a
    :class:`~repro.core.calibrate.TransmissionSchedule` (anything with
    ``units``/``checkpoints``); ``None`` falls back to the v1
    stage-major order (entropy coding alone still applies). Unit sizes
    are data-dependent, so payloads are encoded first and their on-wire
    sizes recorded in the header."""
    if schedule is None:
        from repro.core.calibrate import uniform_schedule
        schedule = uniform_schedule(model)
    payloads = [encode_unit(model, t, p, entropy_coded=entropy_coded)
                for t, p in schedule.units]
    meta = {
        "version": VERSION_SCHEDULED,
        "n_stages": len(schedule.checkpoints),
        "tensors": _tensor_meta(model),
        "units": [[int(t), int(p)] for t, p in schedule.units],
        "checkpoints": [int(c) for c in schedule.checkpoints],
        "unit_bytes": [len(u) for u in payloads],
        "entropy": bool(entropy_coded),
    }
    body = json.dumps(meta).encode()
    header = MAGIC + struct.pack("<II", VERSION_SCHEDULED, len(body)) + body
    return header + b"".join(payloads)


def encode(model: ProgressiveModel, *, schedule=None,
           entropy_coded: bool = False) -> bytes:
    """Default call emits byte-identical v1 streams; requesting a
    schedule and/or entropy coding switches to v2."""
    if schedule is None and not entropy_coded:
        return encode_header(model) + b"".join(
            encode_stage(model, s) for s in range(1, model.n_stages + 1)
        )
    return encode_v2(model, schedule, entropy_coded=entropy_coded)


@dataclasses.dataclass
class StageLayout:
    """Byte layout derived purely from the header — what a client needs
    to slice an incoming byte stream into (tensor, plane) payloads.

    v1: one stage per plane rank, entries dense-packed. v2
    (``framed=True``): "stages" are checkpoint groups of schedule
    units; each entry's ``payload_bytes`` INCLUDES the 2-byte frame,
    and payloads must pass through :func:`decode_plane` with
    ``framed=True`` to strip the frame / undo entropy coding."""

    header_bytes: int
    # per stage: list of (tensor_idx, width, payload_bytes, n_elements)
    stages: list[list[tuple[int, int, int, int]]]
    framed: bool = False

    @property
    def stage_bytes(self) -> list[int]:
        return [sum(e[2] for e in st) for st in self.stages]

    @property
    def total_bytes(self) -> int:
        return self.header_bytes + sum(self.stage_bytes)


def layout_from_header(meta: dict, header_bytes: int) -> StageLayout:
    version = meta.get("version", VERSION)
    if version == VERSION_SCHEDULED:
        return _layout_v2(meta, header_bytes)
    n_stages = meta["n_stages"]
    order = sorted(
        range(len(meta["tensors"])),
        key=lambda i: (meta["tensors"][i]["priority"], i),
    )
    stages = []
    for s in range(1, n_stages + 1):
        entries = []
        for i in order:
            t = meta["tensors"][i]
            if s <= len(t["widths"]):
                w = t["widths"][s - 1]
                n_el = int(np.prod(t["shape"])) if t["shape"] else 1
                nbytes = -(-n_el * w // 8)
                entries.append((i, w, nbytes, n_el))
        stages.append(entries)
    return StageLayout(header_bytes=header_bytes, stages=stages)


def _layout_v2(meta: dict, header_bytes: int) -> StageLayout:
    units = meta["units"]
    unit_bytes = meta["unit_bytes"]
    if len(unit_bytes) != len(units):
        raise ValueError("unit_bytes length mismatch")
    entries = []
    for (t_idx, p), nbytes in zip(units, unit_bytes):
        t = meta["tensors"][t_idx]
        w = t["widths"][p]
        n_el = int(np.prod(t["shape"])) if t["shape"] else 1
        entries.append((int(t_idx), int(w), int(nbytes), n_el))
    stages, lo = [], 0
    for cp in meta["checkpoints"]:
        stages.append(entries[lo:cp])
        lo = cp
    if lo != len(entries):
        raise ValueError("checkpoints do not cover all units")
    return StageLayout(header_bytes=header_bytes, stages=stages,
                       framed=True)


def decode_plane(payload: bytes, width: int, n_elements: int,
                 *, framed: bool = False) -> np.ndarray:
    """Unpack one plane payload. ``framed=True`` (v2) strips the 2-byte
    mode frame and undoes entropy coding first; the recovered packed
    bytes are identical to the raw path, so reconstruction downstream
    is bit-exact either way."""
    if framed:
        if len(payload) < FRAME_BYTES:
            raise ValueError("framed payload shorter than frame")
        mode = payload[0]
        raw_len = -(-n_elements * width // 8)
        payload = entropy.decode(mode, payload[FRAME_BYTES:], raw_len)
    packed = jnp.asarray(np.frombuffer(payload, dtype=np.uint8))
    return np.asarray(bitplanes.unpack_bits(packed, width, n_elements))
