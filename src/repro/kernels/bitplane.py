"""Bit-plane accumulate (eq. 4) as a Pallas TPU kernel.

A precision upgrade on a serving pod is, per weight shard:

    acc <- acc | (plane << shift)

pure integer VPU work, elementwise, embarrassingly tiled. On a real pod
the plane shard arrives over ICI/DCN into HBM and this kernel streams
(acc, plane) HBM->VMEM, ORs, and writes back — memory-bound at
~3 bytes/element moved, i.e. a 27B-param upgrade costs ~`3*27e9/819e9`
≈ 100 ms of HBM time per chip. The serving engine calls this between
decode steps; it never blocks the MXU for long.

The same kernel also implements eq. (3) extraction (split) via shift
masks, so divide/concat are one code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _or_kernel(acc_ref, plane_ref, o_ref, *, shift: int):
    a = acc_ref[...].astype(jnp.uint32)
    p = plane_ref[...].astype(jnp.uint32)
    o_ref[...] = (a | (p << shift)).astype(o_ref.dtype)


def _or_segments_kernel(shift_ref, acc_ref, plane_ref, o_ref):
    # shift_ref is the scalar-prefetch table (SMEM): one shift per block.
    sh = shift_ref[pl.program_id(0)].astype(jnp.uint32)
    a = acc_ref[...].astype(jnp.uint32)
    p = plane_ref[...].astype(jnp.uint32)
    o_ref[...] = (a | (p << sh)).astype(o_ref.dtype)


def _extract_kernel(q_ref, o_ref, *, bits: int, before: int, width: int):
    q = q_ref[...].astype(jnp.uint32)
    mask = jnp.uint32(2 ** bits - 1)
    o_ref[...] = (((q << before) & mask) >> (bits - width)).astype(o_ref.dtype)


def _tile_1d(n: int, block: int) -> tuple[int, int]:
    pad = (-n) % block
    return n + pad, pad


@functools.partial(jax.jit, static_argnames=("shift", "block", "interpret"))
def plane_or(acc: jax.Array, plane: jax.Array, *, shift: int,
             block: int = 1024, interpret: bool = False) -> jax.Array:
    """acc | (plane << shift), elementwise over arbitrary-shape arrays."""
    shape = acc.shape
    a = acc.ravel()
    p = plane.ravel()
    n = a.shape[0]
    block = min(block, max(n, 8))
    npad, pad = _tile_1d(n, block)
    if pad:
        a = jnp.pad(a, (0, pad))
        p = jnp.pad(p, (0, pad))
    # 2-D tiles: TPU vregs want (8, 128); flatten into rows of `block`.
    a2 = a.reshape(-1, block)
    p2 = p.reshape(-1, block)
    rows = a2.shape[0]
    brows = min(rows, 8)
    rpad = (-rows) % brows
    if rpad:
        a2 = jnp.pad(a2, ((0, rpad), (0, 0)))
        p2 = jnp.pad(p2, ((0, rpad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_or_kernel, shift=shift),
        grid=(a2.shape[0] // brows,),
        in_specs=[
            pl.BlockSpec((brows, block), lambda i: (i, 0)),
            pl.BlockSpec((brows, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((brows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, acc.dtype),
        interpret=interpret,
    )(a2, p2)
    return out.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def plane_or_segments(acc: jax.Array, plane: jax.Array, shifts: jax.Array, *,
                      block: int = 1024, interpret: bool = False) -> jax.Array:
    """Batched eq. (4) over a *flat concatenated* accumulator buffer.

    One launch upgrades every tensor of a model at once: ``acc`` and
    ``plane`` are 1-D buffers in which each tensor occupies a
    block-aligned segment (see ``core/plane_store.py``), and ``shifts``
    is an int32 ``(n_blocks,)`` table giving the left shift of the block
    each grid step processes. The table rides in as a scalar-prefetch
    operand (SMEM), so the per-block shift is known before the block's
    DMA issues — the grid stays a single dense 1-D sweep and the whole
    upgrade is ONE ``pallas_call`` instead of one per tensor.

    Blocks with nothing arriving carry a zero plane segment: OR with 0
    is the identity at any shift, so no masking is needed.

    ``block`` must be a multiple of 128 (lane width); both buffers must
    be a multiple of ``block`` long. On a real pod the table is one int
    per 1024 elements — for very large shards raise ``block`` to keep
    the table comfortably in SMEM.
    """
    if acc.ndim != 1 or plane.ndim != 1:
        raise ValueError("plane_or_segments operates on flat 1-D buffers")
    if block % 128:
        raise ValueError(f"block must be a multiple of 128, got {block}")
    n = acc.shape[0]
    if n % block:
        raise ValueError(f"buffer length {n} not a multiple of block {block}")
    if plane.shape[0] != n:
        raise ValueError(
            f"plane length {plane.shape[0]} != acc length {n}")
    if shifts.shape[0] != n // block:
        raise ValueError(
            f"shift table has {shifts.shape[0]} entries, expected "
            f"{n // block} (one per block)")
    rows = block // 128
    a2 = acc.reshape(-1, 128)
    p2 = plane.reshape(-1, 128)
    n_blocks = n // block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rows, 128), lambda i, s: (i, 0)),
            pl.BlockSpec((rows, 128), lambda i, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, 128), lambda i, s: (i, 0)),
    )
    out = pl.pallas_call(
        _or_segments_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a2.shape, acc.dtype),
        interpret=interpret,
    )(shifts.astype(jnp.int32), a2, p2)
    return out.reshape(-1)


@functools.partial(
    jax.jit, static_argnames=("bits", "before", "width", "block", "interpret")
)
def plane_extract(q: jax.Array, *, bits: int, before: int, width: int,
                  block: int = 1024, interpret: bool = False) -> jax.Array:
    """Eq. (3): extract the plane at cumulative offset ``before`` of
    ``width`` bits from k-bit values (server-side divide)."""
    shape = q.shape
    a = q.ravel()
    n = a.shape[0]
    block = min(block, max(n, 8))
    npad, pad = _tile_1d(n, block)
    if pad:
        a = jnp.pad(a, (0, pad))
    a2 = a.reshape(-1, block)
    rows = a2.shape[0]
    brows = min(rows, 8)
    rpad = (-rows) % brows
    if rpad:
        a2 = jnp.pad(a2, ((0, rpad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_extract_kernel, bits=bits, before=before, width=width),
        grid=(a2.shape[0] // brows,),
        in_specs=[pl.BlockSpec((brows, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((brows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, q.dtype),
        interpret=interpret,
    )(a2)
    return out.reshape(-1)[:n].reshape(shape)
