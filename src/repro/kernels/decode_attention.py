"""Ragged batched flash decode-attention Pallas TPU kernel (one new
token per slot vs a long KV cache).

Decode at 32k–500k context is memory-bound: the whole KV cache crosses
HBM once per token while the MXU does a rank-1 sliver of work. The
kernel therefore optimizes for exactly one pass over K and V:

  grid = (B, Kh, S/bs); for each slot, KV-head and cache chunk, compute
  the (G, bs) score tile (G = query heads per KV head, padded to the
  8-row sublane), run the online-softmax update against VMEM scratch
  carries (m, l, acc), and emit the normalized (G, hd) output on the
  last chunk.

The batch is *ragged*: every slot carries its own query position
(``q_pos`` is ``(B,)``) and its own per-slot cache position vector
(``k_pos`` is ``(B, S)``; ring buffers pass their slot positions,
negative marks an empty/unwritten slot, and a fully negative row marks
a free slot of a continuous-batching pool). Full caches,
partially-filled caches, sliding-window ring caches and empty pool
slots all use the same kernel — which is what lets a slot-pool serving
engine run requests at wildly different positions in ONE launch.

K and V arrive in the kernel's native ``(B, Kh, S, hd)`` layout — the
same layout the model's KV caches are stored in — so the wrapper
performs no transpose and, for any reasonably-sized cache, no
sequence-axis padding: the hot decode loop touches each cache byte
exactly once. (When S doesn't divide by the block size the block
shrinks to a divisor; only a divisor-hostile S — prime-ish lengths —
falls back to padding the tail block with masked keys. Keep cache
lengths multiples of the block size — 512 by default — for peak TPU
efficiency.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pick_block(S: int, bs: int) -> int:
    """Choose a sequence block size for a cache of length S: S itself
    when it fits in one block, else the largest *sublane-aligned*
    (multiple-of-8) divisor of S that is <= bs. Returns 0 when no
    aligned divisor of useful size exists (caller pads instead)."""
    if S <= bs:
        return S
    for d in range(bs - bs % 8, 7, -8):
        if S % d == 0:
            return d if d >= bs // 2 else 0
    return 0


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_s: int, window: int, softcap: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd), pre-scaled
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bs, hd)
    kpos = pos_ref[...]                          # (1, bs) int32, this slot
    qpos = qpos_ref[0, 0]                        # scalar, this slot

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kpos >= 0) & (kpos <= qpos)
    if window:
        valid = valid & (kpos > qpos - window)
    s = jnp.where(valid, s, NEG_INF)          # broadcast (1,bs) over (G,bs)

    m_prev = m_ref[...]                        # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "bs", "interpret")
)
def flash_decode(
    q: jax.Array,        # (B, H, hd) one new token's queries per slot
    k: jax.Array,        # (B, Kh, S, hd) cache, native layout
    v: jax.Array,        # (B, Kh, S, hd)
    k_pos: jax.Array,    # (B, S) int32; negative = empty slot
    q_pos: jax.Array,    # (B,) int32; negative = free pool slot
    *,
    window: int = 0,
    softcap: float = 0.0,
    bs: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    Kh, S = k.shape[1], k.shape[2]
    G = H // Kh

    # prefer shrinking the block to a sublane-aligned divisor of S (no
    # padding, no copies); if S is divisor-hostile (prime-ish, or only
    # misaligned/tiny divisors) fall back to padding the tail block —
    # keys padded with k_pos = -1 are masked exactly like empty slots
    d = _pick_block(S, bs)
    if d:
        bs = d
    else:
        pad_s = (-S) % bs
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_s)), constant_values=-1)
        S = S + pad_s
    n_s = S // bs

    # pad G to the 8-row sublane so the score tile is vreg-aligned
    Gp = max(8, G)
    qg = q.reshape(B, Kh, G, hd) * (hd ** -0.5)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    pos2 = k_pos.reshape(B, S).astype(jnp.int32)
    qpos2 = q_pos.reshape(B, 1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, n_s=n_s, window=window, softcap=softcap),
        grid=(B, Kh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
            pl.BlockSpec((1, 1, Gp, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, Gp, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kh, Gp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qpos2, qg, k, v, pos2)
    return out[:, :, :G, :].reshape(B, H, hd)
