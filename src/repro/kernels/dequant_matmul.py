"""Fused dequantize-matmul Pallas TPU kernel.

The TPU-native form of the paper's steps 3+4: weights stay in HBM as
k-bit unsigned integers (the receiver's plane accumulator), and eq. (5)
is applied *in VMEM, per tile, on the way into the MXU*:

    y = x @ (span * q / 2^k + lo + span / 2^{m+1})
      = x @ (scale * q + offset)

So the model is never materialized in floating point in HBM: resident
weight bytes are ``k/16``x smaller than bf16 and a precision upgrade
(another plane OR-ed into ``q``) changes *values only* — same buffer,
same executable. ``scale``/``offset`` are *traced* (1, 1) operands
(computed outside by :func:`repro.core.quantize.dequant_affine` from
(lo, hi, bits, received_bits)); nothing about the received precision is
baked into the executable, so a consumer jitted around this call keeps
exactly one compilation across every precision upgrade.

Tiling: grid (M/bm, N/bn, K/bk) with K innermost; a fp32 accumulator
tile lives in VMEM scratch across the K sweep. Block shapes default to
MXU-aligned (128, 128) tiles (512 in K for bandwidth); the uint16 weight
tile (bk x bn) is dequantized in-register (VPU) then fed to the MXU.

Sharding: the kernel itself is single-device; multi-device serving
shards ``q`` on N only (never K — the fp32 accumulation order across
the K sweep is part of the bit-exactness contract, and a sharded K
would turn it into partial sums + an all-reduce). Per-shard launches go
through :func:`repro.kernels.ops.sharded_dequant_matmul` (shard_map,
one launch per shard on its own (K, N/n) columns) or the engines'
jit-with-shardings path; each shard's call is exactly this kernel on
its local columns, so per-stage outputs match single-device bit for
bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, q_ref, scale_ref, off_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; K swept by the innermost grid dim."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    scale = scale_ref[0, 0]
    off = off_ref[0, 0]
    # eq. (5) on the weight tile, in-register: uint -> fp32 affine.
    w = q_ref[...].astype(jnp.float32) * scale + off
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"),
)
def dequant_matmul(
    x: jax.Array,            # (M, K) float
    q: jax.Array,            # (K, N) uint8/uint16/uint32
    scale: jax.Array,        # traced eq.-(5) slope; scalar or (1, 1) f32
    offset: jax.Array,       # traced eq.-(5) intercept; scalar or (1, 1) f32
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """y = x @ (scale * q + offset) without materializing the fp weight.

    ``scale``/``offset`` come from
    :func:`repro.core.quantize.dequant_affine` — they are plain traced
    operands, NOT static arguments, so a precision upgrade (new
    received_bits -> new affine values) re-runs the same executable.
    """
    M, K = x.shape
    K2, N = q.shape
    assert K == K2, (x.shape, q.shape)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    off = jnp.asarray(offset, jnp.float32).reshape(1, 1)

    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    # pad to tile multiples (host-side; cheap relative to the matmul)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        q = jnp.pad(q, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    n_k = Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        # fp32 accumulator tile persists across the K sweep
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale, off)
    return out[:M, :N]
