"""Public jit'd wrappers for the Pallas kernels.

On this CPU container everything runs with ``interpret=True`` (the
kernel body executes in Python, bit-exact with the TPU lowering's
semantics); on a real TPU the same calls compile to Mosaic. The switch
is automatic via the default backend — callers never pass ``interpret``.

``LAUNCH_COUNTS`` tallies kernel dispatches at the *call site* (outside
jit), which is what the upgrade-latency benchmark uses to prove a
full-model stage upgrade issues O(1) launches through the PlaneStore
instead of O(n_tensors) through the old per-tensor loop.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.kernels import dequant_matmul as _dqm
from repro.kernels import bitplane as _bp
from repro.kernels import decode_attention as _da
from repro.kernels import verify_attention as _va

# Dispatch counts per public kernel entry point. Reset freely; purely
# diagnostic (benchmarks, tests) — never read on a hot path.
LAUNCH_COUNTS: collections.Counter = collections.Counter()


def reset_launch_counts() -> None:
    LAUNCH_COUNTS.clear()


def _count(name: str) -> None:
    """Tally one dispatch: the legacy ``LAUNCH_COUNTS`` view plus the
    telemetry registry (``kernel_launches_total{kernel=...}``) when it
    is enabled — one source of truth, two readers."""
    LAUNCH_COUNTS[name] += 1
    from repro import obs as _obs
    if _obs.enabled():
        _obs.get_registry().counter(
            "kernel_launches_total",
            "Pallas kernel dispatches by entry point").inc(kernel=name)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def dequant_matmul(x, q, scale, offset, **kw):
    """y = x @ (scale * q + offset); the eq.-(5) affine rides in as
    traced (1, 1) operands (see ``repro.core.quantize.dequant_affine``),
    so precision upgrades never recompile a jitted consumer."""
    _count("dequant_matmul")
    kw.setdefault("interpret", _interpret_default())
    return _dqm.dequant_matmul(x, q, scale, offset, **kw)


@functools.lru_cache(maxsize=None)
def _sharded_dqm(mesh, axis: str, interpret: bool):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    # check_rep=False is required: pallas_call has no replication rule,
    # and the kernel computes no cross-shard reductions anyway (K stays
    # whole per shard).
    return jax.jit(shard_map(
        functools.partial(_dqm.dequant_matmul, interpret=interpret),
        mesh=mesh,
        in_specs=(P(), P(None, axis), P(), P()),
        out_specs=P(None, axis),
        check_rep=False))


def sharded_dequant_matmul(x, q, scale, offset, *, mesh, axis: str = "model"):
    """Explicit tensor-parallel dequant-matmul: ``q`` (K, N) sharded on
    N over ``mesh``'s ``axis``; x/scale/offset replicated. One kernel
    launch *per shard* under ``shard_map`` — each shard dequantizes and
    multiplies its own (K, N/n) accumulator columns, and the output
    comes back (M, N) sharded on N (XLA overlaps any consumer-driven
    gather against the other shards' dequant work). Bit-identical to
    the single-device kernel: the K contraction is never sharded, so no
    partial-sum all-reduce ever reorders float adds. This is the
    shard_map half of the sharded serving story; the engines' model
    path uses jit-with-shardings (``models.common.serving_mesh``)
    instead, which XLA partitions from the same specs."""
    _count("sharded_dequant_matmul")
    return _sharded_dqm(mesh, axis, _interpret_default())(
        x, q, scale, offset)


def plane_or(acc, plane, *, shift, **kw):
    _count("plane_or")
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_or(acc, plane, shift=shift, **kw)


def plane_or_segments(acc, plane, shifts, **kw):
    _count("plane_or_segments")
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_or_segments(acc, plane, shifts, **kw)


def plane_extract(q, *, bits, before, width, **kw):
    _count("plane_extract")
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_extract(q, bits=bits, before=before, width=width, **kw)


def flash_decode(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0, **kw):
    """Ragged batched decode attention: q (B, H, hd); k/v in the native
    (B, Kh, S, hd) cache layout; k_pos (B, S); q_pos (B,)."""
    _count("flash_decode")
    kw.setdefault("interpret", _interpret_default())
    return _da.flash_decode(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap, **kw
    )


def decode_attention(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0):
    """The model's per-step decode-attention entry point (same ragged
    operands as :func:`flash_decode`). On TPU this is the Pallas flash
    kernel; elsewhere it is the vectorized jnp oracle — interpret-mode
    Pallas unrolls the (B, Kh, S/bs) grid into the jaxpr, which turns a
    batched decode step into O(B) staged kernel bodies and defeats the
    whole point of continuous batching on CPU CI. Both consume the
    native (B, Kh, S, hd) cache layout with no transpose; parity is
    pinned by tests/test_kernels.py. (No pass-through kwargs: kernel
    tuning knobs like ``bs`` belong to :func:`flash_decode` callers,
    and the two backends must accept identical calls.)"""
    _count("decode_attention")
    if jax.default_backend() == "tpu":
        return _da.flash_decode(
            q, k, v, k_pos, q_pos, window=window, softcap=softcap,
            interpret=False
        )
    from repro.kernels import ref as _ref

    return _ref.flash_decode_ref(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap
    ).astype(q.dtype)


def flash_verify(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0, **kw):
    """Ragged draft-block verify attention: q (B, T, H, hd); k/v in the
    native (B, Kh, S, hd) cache layout; k_pos (B, S); q_pos (B, T)
    per-token positions (negative = masked row)."""
    _count("flash_verify")
    kw.setdefault("interpret", _interpret_default())
    return _va.flash_verify(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap, **kw
    )


def verify_attention(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0):
    """The model's verify-step attention entry point: T = k+1 draft
    queries per slot against the same native cache, one pass. On TPU
    this is the Pallas flash_verify kernel; elsewhere it is the jnp
    oracle, whose per-row computation is *exactly* a decode step's (see
    ``kernels/ref.flash_verify_ref``) — the bit-identity that makes
    lossless speculative decoding token-identical to plain greedy on
    this backend. Same no-pass-through-kwargs rule as
    :func:`decode_attention`."""
    _count("verify_attention")
    if jax.default_backend() == "tpu":
        return _va.flash_verify(
            q, k, v, k_pos, q_pos, window=window, softcap=softcap,
            interpret=False
        )
    from repro.kernels import ref as _ref

    return _ref.flash_verify_ref(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap
    ).astype(q.dtype)


def prefill_attention(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0):
    """The model's chunked-prefill attention entry point: a (B, chunk)
    block of ragged prompt queries per slot against the same native
    (B, Kh, S, hd) cache, one pass. Operand-wise this is
    :func:`verify_attention` — q (B, T, H, hd), k_pos (B, S), q_pos
    (B, T) per-token positions with negative = masked row — the
    difference is what the rows MEAN: q_pos rows carry per-slot chunk
    offsets (slot b's row t is prompt position off_b + t), so slots at
    different prompt depths prefill in the same launch while free and
    decoding slots ride fully masked. On TPU this is the Pallas
    flash_verify kernel (multi-query-position causal attention is the
    same program either way); elsewhere the jnp oracle
    ``kernels/ref.flash_prefill_ref``. Same no-pass-through-kwargs rule
    as :func:`decode_attention`."""
    _count("prefill_attention")
    if jax.default_backend() == "tpu":
        return _va.flash_verify(
            q, k, v, k_pos, q_pos, window=window, softcap=softcap,
            interpret=False
        )
    from repro.kernels import ref as _ref

    return _ref.flash_prefill_ref(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap
    ).astype(q.dtype)


# The old pytree-level ``receiver_or`` convenience (one plane_or per
# leaf) is gone: shipments now flow through the PlaneStore
# (``repro/core/plane_store.py``), which batches a whole shipment into
# one plane_or_segments launch per container dtype.
