"""Public jit'd wrappers for the Pallas kernels.

On this CPU container everything runs with ``interpret=True`` (the
kernel body executes in Python, bit-exact with the TPU lowering's
semantics); on a real TPU the same calls compile to Mosaic. The switch
is automatic via the default backend — callers never pass ``interpret``.

``LAUNCH_COUNTS`` tallies kernel dispatches at the *call site* (outside
jit), which is what the upgrade-latency benchmark uses to prove a
full-model stage upgrade issues O(1) launches through the PlaneStore
instead of O(n_tensors) through the old per-tensor loop.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.kernels import dequant_matmul as _dqm
from repro.kernels import bitplane as _bp
from repro.kernels import decode_attention as _da

# Dispatch counts per public kernel entry point. Reset freely; purely
# diagnostic (benchmarks, tests) — never read on a hot path.
LAUNCH_COUNTS: collections.Counter = collections.Counter()


def reset_launch_counts() -> None:
    LAUNCH_COUNTS.clear()


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def dequant_matmul(x, q, scale, offset, **kw):
    """y = x @ (scale * q + offset); the eq.-(5) affine rides in as
    traced (1, 1) operands (see ``repro.core.quantize.dequant_affine``),
    so precision upgrades never recompile a jitted consumer."""
    LAUNCH_COUNTS["dequant_matmul"] += 1
    kw.setdefault("interpret", _interpret_default())
    return _dqm.dequant_matmul(x, q, scale, offset, **kw)


def plane_or(acc, plane, *, shift, **kw):
    LAUNCH_COUNTS["plane_or"] += 1
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_or(acc, plane, shift=shift, **kw)


def plane_or_segments(acc, plane, shifts, **kw):
    LAUNCH_COUNTS["plane_or_segments"] += 1
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_or_segments(acc, plane, shifts, **kw)


def plane_extract(q, *, bits, before, width, **kw):
    LAUNCH_COUNTS["plane_extract"] += 1
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_extract(q, bits=bits, before=before, width=width, **kw)


def flash_decode(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0, **kw):
    LAUNCH_COUNTS["flash_decode"] += 1
    kw.setdefault("interpret", _interpret_default())
    return _da.flash_decode(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap, **kw
    )


# The old pytree-level ``receiver_or`` convenience (one plane_or per
# leaf) is gone: shipments now flow through the PlaneStore
# (``repro/core/plane_store.py``), which batches a whole shipment into
# one plane_or_segments launch per container dtype.
