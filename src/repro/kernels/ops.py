"""Public jit'd wrappers for the Pallas kernels.

On this CPU container everything runs with ``interpret=True`` (the
kernel body executes in Python, bit-exact with the TPU lowering's
semantics); on a real TPU the same calls compile to Mosaic. The switch
is automatic via the default backend — callers never pass ``interpret``.

Also hosts the pytree-level conveniences used by the serving engine:
``receiver_or`` (eq. 4 across a whole plane shipment) and
``progressive_matmul`` (consume quantized weights without an fp copy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dequant_matmul as _dqm
from repro.kernels import bitplane as _bp
from repro.kernels import decode_attention as _da


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def dequant_matmul(x, q, lo, hi, *, bits, received_bits=None, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _dqm.dequant_matmul(
        x, q, lo, hi, bits=bits, received_bits=received_bits, **kw
    )


def plane_or(acc, plane, *, shift, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_or(acc, plane, shift=shift, **kw)


def plane_extract(q, *, bits, before, width, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _bp.plane_extract(q, bits=bits, before=before, width=width, **kw)


def flash_decode(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _da.flash_decode(
        q, k, v, k_pos, q_pos, window=window, softcap=softcap, **kw
    )


# ---------------------------------------------------------------------------
# Pytree-level conveniences
# ---------------------------------------------------------------------------

def receiver_or(acc_tree, plane_tree, shifts: dict):
    """Apply eq. (4) across a shipment of planes. ``shifts`` maps the
    flat index of each leaf to its shift; leaves absent from
    ``plane_tree`` pass through."""
    out = {}
    for key, acc in acc_tree.items():
        if key in plane_tree:
            out[key] = plane_or(acc, plane_tree[key], shift=shifts[key])
        else:
            out[key] = acc
    return out
