"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition of its kernel, written with
plain jnp ops (no pallas imports). Kernel tests sweep shapes/dtypes and
assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import dequant_affine

NEG_INF = -1e30


def dequant_ref(q: jax.Array, lo: jax.Array, hi: jax.Array, bits: int,
                received_bits: int | None = None) -> jax.Array:
    """Eq. (5) via the one shared affine helper — the ε-widened span is
    defined in ``repro.core.quantize.dequant_affine`` and nowhere else,
    so kernel, oracle and materialization cannot drift."""
    scale, offset = dequant_affine(lo, hi, bits, received_bits)
    return q.astype(jnp.float32) * scale + offset


def dequant_matmul_ref(x: jax.Array, q: jax.Array, scale: jax.Array,
                       offset: jax.Array) -> jax.Array:
    """y = x @ (scale * q + offset).  x: (M, K) float; q: (K, N) uint.
    Mirrors the kernel's operands: the affine comes precomputed (from
    ``dequant_affine``), exactly like the traced (1, 1) kernel inputs."""
    w = q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32) \
        + jnp.asarray(offset, jnp.float32)
    return x.astype(jnp.float32) @ w


def plane_or_ref(acc: jax.Array, plane: jax.Array, shift: int) -> jax.Array:
    """Eq. (4) single-plane accumulate: acc | (plane << shift)."""
    return (acc.astype(jnp.uint32) | (plane.astype(jnp.uint32) << shift)).astype(acc.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_pos: jax.Array, q_pos: jax.Array,
                     *, window: int = 0, softcap: float = 0.0) -> jax.Array:
    """Ragged batched single-token GQA decode attention.

    q: (B, H, hd); k/v: (B, Kh, S, hd) native cache layout;
    k_pos: (B, S) int32 per-slot cache positions (negative = empty
    slot); q_pos: (B,) int32 per-slot query position (negative = free
    pool slot: every key is masked and the output row is meaningless).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    Kh, S = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.reshape(B, Kh, G, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_pos.reshape(B, 1)
    valid = (k_pos >= 0) & (k_pos <= qp)          # (B, S)
    if window:
        valid = valid & (k_pos > qp - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd)


def flash_verify_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_pos: jax.Array, q_pos: jax.Array,
                     *, window: int = 0, softcap: float = 0.0) -> jax.Array:
    """Ragged batched draft-block verify attention (T = k+1 queries per
    slot against one native-layout cache).

    q: (B, T, H, hd); k/v: (B, Kh, S, hd); k_pos: (B, S) per-slot cache
    positions; q_pos: (B, T) int32 *per-token* query positions (negative
    = masked row — draft padding or a free pool slot). Returns
    (B, T, H, hd).

    Implemented as a sequential ``lax.map`` of :func:`flash_decode_ref`
    over the T draft rows ON PURPOSE: each row then runs the *exact*
    computation a plain decode step would, so the verify pass is
    bit-identical to sequential decode on this backend — which is what
    makes lossless speculative token-identity testable at equality
    rather than tolerance. T is small (k+1), so the sequential map costs
    nothing here; the TPU kernel amortizes the cache pass instead.
    """
    qt = jnp.swapaxes(q, 0, 1)        # (T, B, H, hd)
    qpt = jnp.swapaxes(q_pos, 0, 1)   # (T, B)

    def row(args):
        qr, qp = args
        return flash_decode_ref(qr, k, v, k_pos, qp,
                                window=window, softcap=softcap)

    out = jax.lax.map(row, (qt, qpt))  # (T, B, H, hd)
    return jnp.swapaxes(out, 0, 1)


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      k_pos: jax.Array, q_pos: jax.Array,
                      *, window: int = 0, softcap: float = 0.0) -> jax.Array:
    """Ragged chunked-prefill attention oracle: a (B, chunk) block of
    prompt queries per slot against one native-layout cache.

    Operand contract is :func:`flash_verify_ref`'s — q: (B, T, H, hd);
    k/v: (B, Kh, S, hd); k_pos: (B, S); q_pos: (B, T) per-token
    positions, negative = masked row — but the rows carry per-slot
    CHUNK OFFSETS (slot b's row t is prompt position off_b + t, with -1
    padding past a short final chunk and for slots that are free or
    decoding). The computation is identical, and deliberately shared:
    each chunk row runs the exact computation a decode step at that
    position would, so chunked prefill is bit-identical per row to
    sequential decode of the prompt — the property the parity suite
    pins. Kept as a separate entry point so call sites (and
    LAUNCH_COUNTS) distinguish prefill chunks from verify blocks, and
    so a TPU prefill kernel can diverge from the verify kernel without
    touching callers.
    """
    return flash_verify_ref(q, k, v, k_pos, q_pos,
                            window=window, softcap=softcap)
