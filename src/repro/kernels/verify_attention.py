"""Ragged batched flash verify-attention Pallas TPU kernel: a block of
T = k+1 draft tokens per slot against a long KV cache, in ONE pass.

Speculative decoding's verify step scores a whole draft block — the
last accepted token plus k drafted continuations — through the target
model at once. Attention-wise that is the flash-decode problem with a
(T, ...) query *block* per slot instead of a single token: the whole KV
cache still crosses HBM exactly once, but it is amortized over T
queries, which is where the verify step's throughput multiplier comes
from on a memory-bound decode.

  grid = (B, Kh, S/bs); for each slot, KV-head and cache chunk the
  kernel computes the (T*G, bs) score tile (T draft rows x G query
  heads per KV head, padded to the 8-row sublane), runs the online
  softmax against VMEM scratch carries (m, l, acc), and emits the
  normalized (T*G, hd) output on the last chunk.

Raggedness is *per query row*: ``q_pos`` is ``(B, T)`` — every draft
token carries its own position, so one launch serves slots whose drafts
start at wildly different depths (a continuous-batching pool
mid-speculation), slots whose draft is shorter than T (padding rows are
marked ``q_pos = -1`` and fully masked), and free slots (whole row
negative). ``k_pos`` is the same ``(B, S)`` per-slot cache position
vector flash-decode uses — full caches, partially filled caches and
sliding-window ring caches (where ring slots beyond the attention
window are excluded by the window mask, not by layout) all work
unchanged. Masked rows produce finite garbage (uniform attention over
nothing is avoided by the same NEG_INF + 1e-30 guard as flash_decode)
and are discarded host-side.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# one block-size policy for both decode-family kernels: a tuning change
# there must not desynchronize the verify kernel's padding behavior
from repro.kernels.decode_attention import NEG_INF, _pick_block


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_s: int, window: int, softcap: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (R, hd), pre-scaled
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bs, hd)
    kpos = pos_ref[...]                          # (1, bs) int32, this slot
    qpos = qpos_ref[...]                         # (1, R) int32 per-row pos

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (R, bs)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    # per-row causality: row r is the query at position qpos[r]; a
    # negative qpos (draft padding / free slot) masks the entire row
    qp = qpos.reshape(-1, 1)                     # (R, 1)
    valid = (kpos >= 0) & (kpos <= qp) & (qp >= 0)
    if window:
        valid = valid & (kpos > qp - window)
    s = jnp.where(valid, s, NEG_INF)             # (R, bs)

    m_prev = m_ref[...]                          # (R, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(si == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "bs", "interpret")
)
def flash_verify(
    q: jax.Array,        # (B, T, H, hd) draft-block queries per slot
    k: jax.Array,        # (B, Kh, S, hd) cache, native layout
    v: jax.Array,        # (B, Kh, S, hd)
    k_pos: jax.Array,    # (B, S) int32; negative = empty slot
    q_pos: jax.Array,    # (B, T) int32 per-token; negative = masked row
    *,
    window: int = 0,
    softcap: float = 0.0,
    bs: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, hd = q.shape
    Kh, S = k.shape[1], k.shape[2]
    G = H // Kh

    d = _pick_block(S, bs)
    if d:
        bs = d
    else:
        pad_s = (-S) % bs
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_s)), constant_values=-1)
        S = S + pad_s
    n_s = S // bs

    # rows: draft-token-major, query-group-minor — (t, g) -> t * G + g;
    # padded to the 8-row sublane, padding rows masked via q_pos = -1
    R = T * G
    Rp = -(-max(R, 8) // 8) * 8
    qg = (q.reshape(B, T, Kh, G, hd).transpose(0, 2, 1, 3, 4)
          .reshape(B, Kh, R, hd)) * (hd ** -0.5)
    qpos_rows = jnp.repeat(q_pos.astype(jnp.int32), G, axis=1)  # (B, R)
    if Rp != R:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Rp - R), (0, 0)))
        qpos_rows = jnp.pad(qpos_rows, ((0, 0), (0, Rp - R)),
                            constant_values=-1)
    pos2 = k_pos.reshape(B, S).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, n_s=n_s, window=window, softcap=softcap),
        grid=(B, Kh, n_s),
        in_specs=[
            pl.BlockSpec((1, Rp), lambda b, h, s: (b, 0)),
            pl.BlockSpec((1, 1, Rp, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, Rp, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kh, Rp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Rp, 1), jnp.float32),
            pltpu.VMEM((Rp, 1), jnp.float32),
            pltpu.VMEM((Rp, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_rows, qg, k, v, pos2)
    out = out[:, :, :R, :].reshape(B, Kh, T, G, hd)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, T, H, hd)
