import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init, and the production-mesh dry-run needs 512 placeholder devices.
"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, prove the sharding config is coherent, and dump the
roofline source terms.

Usage:
    python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
    python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all              # every combo, resumable

Each run writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis / cost_analysis / parsed collective schedule; the
EXPERIMENTS.md tables are generated from these files by
benchmarks/roofline.py.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import get_config, all_configs
from repro.launch import hlo_analysis, sharding
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch.steps import (
    SHAPES,
    WorkloadShape,
    long_context_supported,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    step_input_specs,
)
from repro.models.model import build_model
from repro.train import optimizer as opt


def _shardings_for(cfg, shape: WorkloadShape, mesh, specs, strategy="greedy"):
    """in_shardings tuple matching step_input_specs order."""
    if shape.mode == "train":
        params_sds, opt_sds, batch_sds = specs
        psh = sharding.param_shardings(params_sds, mesh, strategy)
        osh = {"mu": psh, "nu": psh, "step": sharding.replicated(mesh)}
        bsh = sharding.batch_shardings(batch_sds, mesh)
        return (psh, osh, bsh)
    if shape.mode == "prefill":
        params_sds, batch_sds = specs
        psh = sharding.param_shardings(params_sds, mesh, strategy)
        bsh = sharding.batch_shardings(batch_sds, mesh)
        return (psh, bsh)
    params_sds, caches_sds, tokens_sds, pos_sds = specs
    psh = sharding.param_shardings(params_sds, mesh, strategy)
    csh = sharding.cache_shardings(caches_sds, mesh, batch=shape.global_batch)
    tsh = sharding.batch_shardings(tokens_sds, mesh)
    return (psh, csh, tsh, sharding.replicated(mesh))


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            strategy: str = "greedy", param_dtype: str = "f32",
            microbatches: int = 1) -> dict:
    cfg = get_config(arch)
    if param_dtype == "bf16":
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"

    if shape_name == "long_500k" and not long_context_supported(cfg):
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped",
            "reason": "pure full-attention arch; long_500k requires "
                      "sub-quadratic attention (DESIGN.md §4)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for s in mesh.shape.values():
        n_chips *= s

    def _lower_compile(use_cfg):
        model = build_model(use_cfg)
        specs = step_input_specs(use_cfg, shape)
        in_sh = _shardings_for(use_cfg, shape, mesh, specs, strategy)
        if shape.mode == "train":
            step = make_train_step(model, opt.OptConfig(),
                                   microbatches=microbatches)
            donate = (0, 1)
        elif shape.mode == "prefill":
            step = make_prefill_step(model)
            donate = ()
        else:
            step = make_serve_step(model)
            donate = (1,)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(
                step, in_shardings=in_sh, donate_argnums=donate
            ).lower(*specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        return specs, compiled, t_lower, t_compile

    # 1) production (scanned) program: sharding/compile proof + memory
    specs, compiled, t_lower, t_compile = _lower_compile(cfg)
    ma = compiled.memory_analysis()
    mf = hlo_analysis.model_flops(cfg, specs[0], shape, mode=shape.mode)
    rl_scanned = hlo_analysis.roofline_from_compiled(
        compiled, n_chips=n_chips, model_flops_global=mf
    )
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mode": shape.mode,
        "strategy": strategy,
        "param_dtype": param_dtype,
        "microbatches": microbatches,
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        },
        # counts from the *scanned* HLO undercount loop bodies (trip
        # counts are not multiplied by XLA cost analysis); kept for
        # reference only. §Roofline uses `roofline` below.
        "roofline_scanned_reference": rl_scanned.to_dict(),
        "n_params": hlo_analysis.param_count(specs[0]),
        "n_params_active": hlo_analysis.active_param_count(cfg, specs[0]),
    }
    del compiled

    # 2) costing (unrolled) programs: faithful per-device FLOPs / bytes /
    #    collective schedule for the roofline table (single-pod only; the
    #    roofline table is single-pod per the brief).
    #
    #    Every stack is cycle-homogeneous (same block pattern each cycle),
    #    so counts are affine in the cycle count R: total(R) = outside +
    #    R * per_cycle. We compile two small *unrolled* probes (R=1, R=2)
    #    and extrapolate to the full R — exact for homogeneous stacks and
    #    two orders of magnitude cheaper to compile than the full unroll
    #    (validated against a full 16-cycle unroll in tests/test_dryrun).
    if not multi_pod:
        t0 = time.time()
        R = cfg.n_cycles
        if R <= 2:
            _, compiled_c, _, _ = _lower_compile(cfg.for_costing())
            counts = hlo_analysis.raw_counts(compiled_c)
        else:
            _, comp1, _, _ = _lower_compile(_probe_cfg(cfg, 1))
            _, comp2, _, _ = _lower_compile(_probe_cfg(cfg, 2))
            c1 = hlo_analysis.raw_counts(comp1)
            c2 = hlo_analysis.raw_counts(comp2)
            counts = hlo_analysis.extrapolate_counts(c1, c2, R)
        supp = hlo_analysis.recurrence_supplement(cfg, shape)
        rl = hlo_analysis.roofline_from_counts(
            counts,
            n_chips=n_chips,
            model_flops_global=mf,
            extra_flops_per_dev=supp["flops"] / n_chips,
            extra_hbm_per_dev=supp["hbm_bytes"] / n_chips,
        )
        out["roofline"] = rl.to_dict()
        out["costing_compile_s"] = round(time.time() - t0, 2)
        out["recurrence_supplement_global"] = supp
    return out


def _probe_cfg(cfg, k: int):
    """Unrolled costing probe with k cycles (tail preserved)."""
    n_layers = k * len(cfg.cycle) + len(cfg.tail)
    return dataclasses.replace(cfg.for_costing(), n_layers=n_layers)


def _out_path(outdir: str, arch: str, shape: str, multi_pod: bool,
              strategy: str = "greedy", param_dtype: str = "f32",
              microbatches: int = 1) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    tag = "" if strategy == "greedy" else f"__{strategy}"
    if param_dtype != "f32":
        tag += f"__{param_dtype}"
    if microbatches != 1:
        tag += f"__mb{microbatches}"
    return os.path.join(outdir, f"{arch}__{shape}__{mesh}{tag}.json")


def _drive_subprocesses(combos, args) -> None:
    """One subprocess per combo: isolates compiler memory and enforces a
    wall-clock limit (a hung compile records an error entry instead of
    starving the rest of the table)."""
    import subprocess
    import sys

    for arch, shape, mp in combos:
        path = _out_path(args.out, arch, shape, mp, args.strategy,
                         args.param_dtype)
        if os.path.exists(path) and not args.force:
            print(f"skip (exists): {path}", flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape,
               "--strategy", args.strategy, "--param-dtype", args.param_dtype,
               "--out", args.out]
        if mp:
            cmd.append("--multi-pod")
        if args.force:
            cmd.append("--force")
        print(f"== [driver] {arch} x {shape} {'2x16x16' if mp else '16x16'} ==",
              flush=True)
        try:
            r = subprocess.run(cmd, timeout=args.timeout,
                               capture_output=True, text=True)
            tail = (r.stdout or "").strip().splitlines()
            print("   " + (tail[-1] if tail else f"rc={r.returncode}"), flush=True)
            if r.returncode != 0 and not os.path.exists(path):
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "error",
                               "error": (r.stderr or "")[-2000:]}, f, indent=2)
        except subprocess.TimeoutExpired:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": f"compile timeout > {args.timeout}s "
                                    "(XLA-CPU pathological case; see "
                                    "EXPERIMENTS.md §Dry-run notes)"},
                          f, indent=2)
        print("", flush=True, end="")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every combo, both meshes")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--strategy", default="greedy",
                    choices=["greedy", "megatron"],
                    help="param sharding strategy (megatron = §Perf variant)")
    ap.add_argument("--param-dtype", default="f32", choices=["f32", "bf16"],
                    help="parameter storage dtype (bf16 = §Perf variant)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation slices (§Perf variant)")
    ap.add_argument("--timeout", type=int, default=2400,
                    help="per-combo wall-clock limit under --all (seconds)")
    ap.add_argument("--no-subprocess", action="store_true",
                    help="run --all combos in-process (no isolation)")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        # canonical hyphenated arch ids (cfg.name), single-pod first.
        # zamba2 (heaviest XLA-CPU compile: SSD chunk einsums) goes last
        # so one slow arch never starves the table.
        arch_ids = [c.name for c in all_configs().values()]
        arch_ids.sort(key=lambda a: a == "zamba2-7b")
        combos = [
            (a, s, mp)
            for mp in (False, True)
            for a in arch_ids
            for s in SHAPES
        ]
        if not args.no_subprocess:
            _drive_subprocesses(combos, args)
            return
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in combos:
        path = _out_path(args.out, arch, shape, mp, args.strategy,
                         args.param_dtype, args.microbatches)
        if os.path.exists(path) and not args.force:
            print(f"skip (exists): {path}")
            continue
        print(f"== dry-run {arch} x {shape} on {'2x16x16' if mp else '16x16'} ==",
              flush=True)
        try:
            result = run_one(arch, shape, multi_pod=mp, strategy=args.strategy,
                             param_dtype=args.param_dtype,
                             microbatches=args.microbatches)
        except Exception as e:  # a failure here is a bug in our sharding
            result = {
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc()[-4000:],
            }
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        status = result["status"]
        extra = ""
        if status == "ok":
            r = result.get("roofline")
            if r:
                extra = (f" dominant={r['dominant']} compute={r['compute_s']:.2e}s "
                         f"memory={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
                         f"compile={result['compile_s']:.0f}s")
            else:
                extra = f" compile={result['compile_s']:.0f}s (sharding proof)"
        print(f"   -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
