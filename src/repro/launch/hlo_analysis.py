"""Roofline-term extraction from a compiled dry-run artifact.

Sources (all per-device, because the compiled module is the SPMD
partition):

* ``compiled.cost_analysis()``  -> HLO FLOPs + HBM bytes accessed
* ``compiled.memory_analysis()``-> argument/temp/output bytes (fits-check)
* ``compiled.as_text()``        -> collective ops; we parse every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute and convert result shapes to wire bytes per device
  using standard ring-algorithm costs.

Hardware constants are TPU v5e (mesh.py). The three roofline terms are
seconds-if-that-resource-were-the-only-bottleneck; the max identifies
the dominant term.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch import mesh as meshmod

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?((?:bf16|f16|f32|f64|s\d+|u\d+|pred|f8e4m3fn|f8e5m2|c64|c128)\[[^\]]*\])?"
    r"[^=]*?(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s\d+|u\d+|pred|f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_result_bytes(line: str) -> int:
    """Sum result-tuple bytes on an HLO instruction line (left of '=')."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Per-device wire bytes by collective kind (ring-algorithm model)."""

    counts: dict
    result_bytes: dict
    wire_bytes: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    result_bytes: dict[str, float] = {}
    wire_bytes: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if f"{kind}-done" in line:
            continue
        rb = _line_result_bytes(line)
        g = _group_size(line)
        if kind == "collective-permute":
            wire = float(rb)  # point-to-point; no replica groups
        elif g <= 1:
            wire = 0.0
        elif kind == "all-reduce":
            wire = 2.0 * rb * (g - 1) / g          # reduce-scatter + all-gather
        elif kind == "all-gather":
            wire = rb * (g - 1) / g                # result is the gathered buf
        elif kind == "reduce-scatter":
            wire = rb * (g - 1)                    # operand = result * g
        else:  # all-to-all
            wire = rb * (g - 1) / g
        counts[kind] = counts.get(kind, 0) + 1
        result_bytes[kind] = result_bytes.get(kind, 0.0) + rb
        wire_bytes[kind] = wire_bytes.get(kind, 0.0) + wire
    return CollectiveStats(counts=counts, result_bytes=result_bytes, wire_bytes=wire_bytes)


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    hbm_bytes: float          # per device
    wire_bytes: float         # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    arg_bytes: int            # per device (params+inputs residency)
    temp_bytes: int
    fits: bool
    collective_detail: dict
    model_flops: float = 0.0  # 6*N*D useful flops, global
    useful_ratio: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def normalize_cost_analysis(ca) -> dict:
    """``Compiled.cost_analysis()`` returns a one-element list of dicts
    on jax<=0.4.x and a plain dict on newer releases; accept both."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def raw_counts(compiled) -> dict:
    """Additive per-device counters from one compiled module."""
    ca = normalize_cost_analysis(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    stats = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_counts": dict(stats.counts),
        "coll_result_bytes": dict(stats.result_bytes),
        "coll_wire_bytes": dict(stats.wire_bytes),
        "arg_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
    }


def _affine(v1, v2, r):
    """outside + r*per_cycle given values at r=1 and r=2."""
    per = v2 - v1
    return v1 + (r - 1) * per


def extrapolate_counts(c1: dict, c2: dict, r: int) -> dict:
    """Counts for the R-cycle stack from the 1- and 2-cycle probes.

    Exact for cycle-homogeneous stacks: every additive counter is affine
    in the cycle count. Memory-analysis numbers are NOT extrapolated
    (residency is taken from the production scanned compile instead).
    """
    out = {"flops": _affine(c1["flops"], c2["flops"], r),
           "hbm_bytes": _affine(c1["hbm_bytes"], c2["hbm_bytes"], r)}
    for key in ("coll_counts", "coll_result_bytes", "coll_wire_bytes"):
        kinds = set(c1[key]) | set(c2[key])
        out[key] = {
            k: max(0.0, _affine(c1[key].get(k, 0.0), c2[key].get(k, 0.0), r))
            for k in kinds
        }
    for key in ("arg_bytes", "temp_bytes", "output_bytes", "alias_bytes"):
        out[key] = c2[key]  # probe-local; unused downstream
    return out


def roofline_from_counts(counts: dict, *, n_chips: int,
                         model_flops_global: float = 0.0,
                         ici_links: int = 4,
                         extra_flops_per_dev: float = 0.0,
                         extra_hbm_per_dev: float = 0.0,
                         memory_analysis=None) -> Roofline:
    # clamp: affine extrapolation of near-zero probe deltas can produce
    # tiny negatives for very small models
    flops = max(counts["flops"] + extra_flops_per_dev, 0.0)
    hbm = max(counts["hbm_bytes"] + extra_hbm_per_dev, 0.0)
    wire = max(sum(counts["coll_wire_bytes"].values()), 0.0)

    compute_s = flops / meshmod.PEAK_FLOPS_BF16
    memory_s = hbm / meshmod.HBM_BW
    collective_s = wire / (meshmod.ICI_BW * ici_links)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    if memory_analysis is not None:
        arg_b = int(memory_analysis.argument_size_in_bytes)
        tmp_b = int(memory_analysis.temp_size_in_bytes)
        out_b = int(memory_analysis.output_size_in_bytes)
        alias_b = int(memory_analysis.alias_size_in_bytes)
    else:
        arg_b = counts.get("arg_bytes", 0)
        tmp_b = counts.get("temp_bytes", 0)
        out_b = counts.get("output_bytes", 0)
        alias_b = counts.get("alias_bytes", 0)
    fits = (arg_b + tmp_b + out_b - alias_b) < meshmod.HBM_PER_CHIP

    useful = (
        model_flops_global / (n_chips * flops)
        if flops > 0 and model_flops_global > 0
        else 0.0
    )
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=wire,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        arg_bytes=arg_b,
        temp_bytes=tmp_b,
        fits=fits,
        collective_detail={
            "counts": counts["coll_counts"],
            "result_bytes": counts["coll_result_bytes"],
            "wire_bytes": counts["coll_wire_bytes"],
        },
        model_flops=model_flops_global,
        useful_ratio=useful,
    )


def roofline_from_compiled(compiled, *, n_chips: int, model_flops_global: float = 0.0,
                           ici_links: int = 4,
                           extra_flops_per_dev: float = 0.0,
                           extra_hbm_per_dev: float = 0.0) -> Roofline:
    return roofline_from_counts(
        raw_counts(compiled),
        n_chips=n_chips,
        model_flops_global=model_flops_global,
        ici_links=ici_links,
        extra_flops_per_dev=extra_flops_per_dev,
        extra_hbm_per_dev=extra_hbm_per_dev,
        memory_analysis=compiled.memory_analysis(),
    )


# -- per-token recurrence supplements ---------------------------------------------
#
# The costing variant unrolls every *chunked* scan, but per-token
# recurrences (xLSTM's mLSTM/sLSTM cells) cannot be unrolled at T up to
# 512k. Their loop bodies are counted once by cost_analysis; we add the
# missing (T - 1) trips analytically from the cell's arithmetic. Only
# xlstm-125m has such blocks.

def recurrence_supplement(cfg, shape) -> dict:
    """Global extra (flops, hbm_bytes) for per-token scan bodies."""
    kinds = list(cfg.cycle) * cfg.n_cycles + list(cfg.tail)
    n_mlstm = kinds.count("mlstm")
    n_slstm = kinds.count("slstm")
    if not (n_mlstm or n_slstm):
        return {"flops": 0.0, "hbm_bytes": 0.0}
    B = shape.global_batch
    T = shape.seq_len if shape.mode in ("train", "prefill") else 1
    extra_trips = max(T - 1, 0)
    bwd = 2.0 if shape.mode == "train" else 0.0  # bwd scan ~2x fwd cell cost

    d_in_m = int(cfg.lstm_proj_factor * cfg.d_model)
    hd_m = d_in_m // cfg.n_heads
    # mLSTM cell: C update (4 flops/elem) + h=Cq (2) => ~6*H*hd^2; carries
    # C read+write dominate bytes: 2*4B*H*hd^2
    ml_flops = 6.0 * B * cfg.n_heads * hd_m * hd_m
    ml_bytes = 8.0 * B * cfg.n_heads * hd_m * hd_m
    # sLSTM cell: block-diag recurrent matmul 8*d*hd + ~20*d elementwise
    hd_s = cfg.d_model // cfg.n_heads
    sl_flops = B * (8.0 * cfg.d_model * hd_s + 20.0 * cfg.d_model)
    sl_bytes = 16.0 * B * cfg.d_model
    f = extra_trips * (1.0 + bwd) * (n_mlstm * ml_flops + n_slstm * sl_flops)
    by = extra_trips * (1.0 + bwd) * (n_mlstm * ml_bytes + n_slstm * sl_bytes)
    return {"flops": f, "hbm_bytes": by}


# -- model FLOPs (the "useful work" numerator) -----------------------------------

def param_count(params_sds) -> int:
    import numpy as np
    import jax

    total = 0
    for leaf in jax.tree.leaves(params_sds):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def active_param_count(cfg, params_sds) -> int:
    """MoE: count only top_k/E of each expert bank."""
    import jax

    total = 0
    leaves = jax.tree_util.tree_flatten_with_path(params_sds)[0]
    from repro.core.wire import path_str

    for path, leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        p = path_str(path)
        if cfg.n_experts and re.search(r"we_(gate|up|down)", p):
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def model_flops(cfg, params_sds, shape, *, mode: str) -> float:
    """6*N_active*D for training; 2*N_active*D for a forward-only step
    (prefill processes D=B*S tokens; decode processes D=B tokens)."""
    n_active = active_param_count(cfg, params_sds)
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens
