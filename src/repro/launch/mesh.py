"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces
512 host devices via XLA_FLAGS before any jax import).

Target hardware (roofline constants): TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI. One pod = 16x16 = 256 chips;
multi-pod = 2 pods = 512 chips with a slower inter-pod axis.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
DCN_BW = 6.25e9               # bytes/s per host inter-pod (25 GbE-ish x2)
HBM_PER_CHIP = 16 * 2**30     # 16 GiB


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CI tests (run under forced host-device count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_serving_mesh(n_model: int, *, n_data: int = 1):
    """Mesh for the sharded serving stack (PlaneStore shards + sharded
    decode): tensor/expert parallelism over ``model``, optional replica
    rows over ``data``. Same axes as the debug/production meshes so
    :func:`repro.launch.sharding.serving_spec_for_param` applies
    unchanged. Call only under an adequate device count (e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch/FSDP dimension (pod joins data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
