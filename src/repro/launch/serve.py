"""Progressive serving launcher: cold-start a server from bit-plane
stages arriving over a simulated link and decode while precision climbs.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --bandwidth-mbps 1.0 --decode-steps 64

Timeline: stage arrival times come from the bandwidth simulator over the
*real* serialized plane sizes; the server upgrades in place between
decode steps exactly when the link would have delivered each stage
(paper Fig. 4 made operational).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import ProgressiveServer
from repro.transmission.simulator import Link, simulate_transfer
from repro.core import wire


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bandwidth-mbps", type=float, default=1.0)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prog = divide(params)

    # real stage byte sizes -> arrival times on the link
    stage_bytes = [len(wire.encode_stage(prog, s)) for s in range(1, prog.n_stages + 1)]
    hdr = len(wire.encode_header(prog))
    link = Link(bandwidth_bytes_per_s=args.bandwidth_mbps * 1e6)
    events = simulate_transfer(
        [("hdr", hdr)] + [(f"s{t}", b) for t, b in enumerate(stage_bytes, 1)], link
    )
    arrivals = [e.end_s for e in events[1:]]
    print(f"model bytes={hdr + sum(stage_bytes)}  stages={prog.n_stages}  "
          f"arrivals={[round(a, 2) for a in arrivals]}s @ {args.bandwidth_mbps} MB/s")

    max_len = args.prompt_len + args.decode_steps
    server = ProgressiveServer(model, prog, max_len=max_len)
    server.receive_stage()  # stage 1 = cold start
    B = args.batch
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)}
    if cfg.enc_layers:
        batch["enc_input"] = jnp.zeros(
            (B, max(1, args.prompt_len // cfg.enc_seq_divisor), cfg.d_model), cfg.dtype
        )
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_tokens, cfg.d_vision), cfg.dtype
        )
    server.start(batch)

    # decode clock: assume a fixed per-step budget so upgrades interleave
    step_s = max(arrivals[-1] / max(args.decode_steps, 1), 1e-6)

    def stage_arrival(i: int) -> bool:
        now = (i + 1) * step_s + arrivals[0]
        return server.stage < len(arrivals) and now >= arrivals[server.stage]

    result = server.decode(args.decode_steps, stage_arrival=stage_arrival)
    print("upgrades (decode step -> stage):", result.upgrades)
    print("stage per step:", result.stage_at_step)
    print("tokens[0]:", [int(t) for t in result.tokens[0][:16]], "...")
    print(f"served {args.decode_steps} steps across {server.stage} precision stages; "
          f"mean step {1e3 * sum(result.per_step_s) / len(result.per_step_s):.1f} ms")


if __name__ == "__main__":
    main()
