"""Progressive serving launcher: cold-start a server from bit-plane
stages arriving over a simulated link and decode while precision climbs.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --bandwidth-mbps 1.0 --decode-steps 64
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --scenario browser-lte-handoff --seed 1 --event-log artifacts/serve.jsonl

The whole run is a co-simulation :class:`Session`: real ``wire`` bytes
stream through the bandwidth trace in transport chunks into the real
``ProgressiveClient``/PlaneStore, and the ``ProgressiveServer`` decodes
from that same store, upgrading in place between decode steps exactly
when the link delivered each stage (paper Fig. 4 made operational —
one code path with the Table-I/III benchmarks).
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.transmission import Session, get_scenario, list_scenarios
from repro.transmission.simulator import BandwidthTrace


def _write_event_log(result, event_log: str | None) -> None:
    if not event_log:
        return
    path = Path(event_log)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(result.to_jsonl())
    print(f"event log -> {path}")


def _write_metrics(metrics: str | None) -> None:
    """Dump the telemetry registry: Prometheus text at ``metrics``,
    the structured summary (with spans) as JSON at ``metrics + '.json'``."""
    if not metrics:
        return
    import json

    from repro import obs
    from repro.obs.exporters import to_prometheus, to_summary

    path = Path(metrics)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(to_prometheus(obs.get_registry()))
    summary_path = path.with_name(path.name + ".json")
    summary_path.write_text(json.dumps(
        to_summary(obs.get_registry(), obs.get_tracer()),
        indent=2, sort_keys=True) + "\n")
    print(f"metrics -> {path} (+ {summary_path.name})")


def _verify_fault_recovery(result, blob, model, prog, batch,
                           *, decode_steps: int = 8) -> None:
    """The lossy run's acceptance check: after the transport converged,
    the client's store must be BIT-identical to a clean stream's, and a
    fresh final-stage decode must emit the same tokens. Raises
    SystemExit on divergence — CI treats this as the smoke's assert."""
    import numpy as np

    from repro.serving.engine import ProgressiveServer, WireStoreReceiver
    from repro.transmission import ProgressiveClient

    t = result.transport
    print(f"transport: injected={t['injected']} "
          f"quarantined={t['quarantined']} repaired={t['repaired_units']} "
          f"reconnects={t['reconnects']} duplicates={t['duplicate_units']}")
    if result.client.nacks or not result.client.complete:
        raise SystemExit(
            f"FAIL: transport did not converge (stages "
            f"{result.client.stages_complete}, nacks {result.client.nacks})")
    clean = ProgressiveClient()
    clean.feed(blob)
    clean.materialize()
    result.client.materialize()
    fp_clean = clean.store.fingerprint()
    fp_lossy = result.client.store.fingerprint()
    if fp_clean != fp_lossy:
        raise SystemExit(
            f"FAIL: store diverged from the clean stream: "
            f"{fp_lossy} != {fp_clean}")

    def final_tokens(client):
        srv = ProgressiveServer(
            model, prog,
            max_len=int(batch["tokens"].shape[1]) + decode_steps,
            receiver=WireStoreReceiver(client, prog))
        while srv.stage < client.stages_complete:
            srv.receive_stage()
        srv.start(batch)
        return np.asarray(srv.decode(decode_steps).tokens)

    a, b = final_tokens(clean), final_tokens(result.client)
    if not np.array_equal(a, b):
        raise SystemExit(f"FAIL: final-stage tokens diverged:\n{a}\n{b}")
    print(f"fault recovery verified: store bit-identical to clean stream, "
          f"final-stage tokens identical over {decode_steps} steps")


def build_batch(cfg, batch: int, prompt_len: int, seed: int) -> dict:
    out = {"tokens": jax.random.randint(
        jax.random.PRNGKey(seed), (batch, prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)}
    if cfg.enc_layers:
        out["enc_input"] = jnp.zeros(
            (batch, max(1, prompt_len // cfg.enc_seq_divisor), cfg.d_model),
            cfg.dtype)
    if cfg.vision_tokens:
        out["vision_embeds"] = jnp.zeros(
            (batch, cfg.vision_tokens, cfg.d_vision), cfg.dtype)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", default=None, choices=list_scenarios(),
                    help="named network scenario (overrides --bandwidth-mbps)")
    ap.add_argument("--trace-csv", default=None,
                    help="bandwidth trace CSV (see benchmarks/traces/)")
    ap.add_argument("--bandwidth-mbps", type=float, default=1.0)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resident", default="fp", choices=["fp", "quantized"],
                    help="weight residency: 'fp' re-materializes float "
                         "weights per upgrade; 'quantized' decodes straight "
                         "from the uint plane accumulators (no fp weight "
                         "copy in HBM, recompile-free upgrades)")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decoding: a truncated-bits view "
                         "of the same accumulators drafts, the full view "
                         "verifies whole draft blocks in one pass — token-"
                         "identical to plain greedy, zero extra weight "
                         "bytes (implies quantized residency)")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="draft view precision for --speculative")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="fixed draft length for --speculative "
                         "(default: adaptive from the acceptance rate)")
    ap.add_argument("--pool-clients", type=int, default=0,
                    help="> 0: continuous-batching mode — this many "
                         "clients join mid-download (flash crowd) and are "
                         "served by one slot pool instead of a single "
                         "lock-stepped stream")
    ap.add_argument("--chunked-prefill", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="force chunked admission on/off for the pool "
                         "(default: auto — on for every arch without "
                         "cross-attention)")
    ap.add_argument("--pool-slots", type=int, default=4,
                    help="slot-pool size for --pool-clients")
    ap.add_argument("--crowd-span-s", type=float, default=1.0,
                    help="window after cold start over which the crowd "
                         "arrives")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="> 1: shard the serving stack over this many "
                         "devices on the mesh's model axis — the plane "
                         "accumulators shard with the params they back "
                         "(shard-local ingest) and decode runs through "
                         "sharded dispatch, token-identical to single-"
                         "device at every precision stage (CI runs this "
                         "under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--event-log", default=None,
                    help="write the session's audit log (JSONL) here")
    ap.add_argument("--metrics", default=None,
                    help="enable the telemetry registry for this run and "
                         "write its Prometheus text export here (plus the "
                         "structured summary at <path>.json); analyze "
                         "event logs with repro-telemetry")
    ap.add_argument("--faults", action="store_true",
                    help="lossy-channel mode: encode the stream on the v3 "
                         "integrity wire and inject seeded channel faults "
                         "(corruption/truncation/duplication/reorder/"
                         "disconnect). Lossy scenarios (browser-3g-lossy, "
                         "edge-flaky) supply their own fault profile; other "
                         "links get a default ~1%% corruption profile. After "
                         "the run the launcher PROVES recovery: the final "
                         "store must be bit-identical to a clean stream's "
                         "and the final-stage tokens identical to a clean "
                         "run's")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed for the fault profile and retry jitter "
                         "(default: --seed)")
    args = ap.parse_args()

    if args.metrics:
        from repro import obs

        obs.configure(True)

    mesh = None
    if args.mesh_shards > 1:
        from repro.launch.mesh import make_serving_mesh

        if jax.device_count() < args.mesh_shards:
            raise SystemExit(
                f"--mesh-shards {args.mesh_shards} needs that many devices, "
                f"have {jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before launch)")
        mesh = make_serving_mesh(args.mesh_shards)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prog = divide(params)
    # lossy mode needs the v3 integrity wire so damage is detectable
    blob = wire.encode(prog, integrity=args.faults)

    scenario = get_scenario(args.scenario) if args.scenario else None
    if scenario is not None:
        session = Session.from_scenario(blob, scenario, seed=args.seed)
        link_desc = f"scenario {args.scenario} (seed {args.seed})"
    elif args.trace_csv:
        session = Session(blob, BandwidthTrace.from_csv(args.trace_csv))
        link_desc = f"trace {args.trace_csv}"
    else:
        session = Session(
            blob, BandwidthTrace.constant(args.bandwidth_mbps * 1e6))
        link_desc = f"{args.bandwidth_mbps} MB/s"

    faults = fault_policy = None
    if args.faults:
        from repro.transmission import FaultPolicy, FaultTrace

        fseed = args.seed if args.fault_seed is None else args.fault_seed
        if scenario is not None and scenario.lossy:
            faults = scenario.make_faults(fseed)
        else:
            faults = FaultTrace(seed=fseed, p_corrupt=0.01,
                                p_disconnect=0.002)
        fault_policy = FaultPolicy(seed=fseed)
        print(f"lossy channel: {faults}  "
              f"(v3 framing overhead "
              f"{wire.framing_overhead(session.meta)['overhead_frac']:.2%})")

    arrivals = session.stage_arrival_times()
    print(f"model bytes={len(blob)}  stages={prog.n_stages}  "
          f"arrivals={[round(a, 2) for a in arrivals]}s over {link_desc}")

    if args.pool_clients > 0:
        from repro.transmission import flash_crowd_arrivals

        pool_spec = None
        if args.speculative:
            from repro.serving.speculative import SpecConfig

            pool_spec = SpecConfig(draft_bits=args.draft_bits,
                                   k=args.draft_k)
        prompts = [jax.random.randint(
            jax.random.PRNGKey(1000 + i), (args.prompt_len,), 0, cfg.vocab
        ).astype(jnp.int32) for i in range(args.pool_clients)]
        offs = flash_crowd_arrivals(args.seed, args.pool_clients,
                                    span_s=args.crowd_span_s)
        result = session.run_serving_pool(
            model, prog, prompts=prompts, arrival_offsets_s=offs,
            max_new_tokens=args.decode_steps, n_slots=args.pool_slots,
            resident=None if pool_spec else args.resident,
            speculative=pool_spec,
            chunked_prefill=args.chunked_prefill, mesh=mesh,
            faults=faults, fault_policy=fault_policy)
        pool = result.server
        print(f"flash crowd: {args.pool_clients} clients over "
              f"{args.crowd_span_s}s into {args.pool_slots} slots; "
              f"admissions at "
              f"{[round(t, 2) for t, _ in result.admissions]}s")
        if args.speculative:
            s = result.speculation_summary()
            print(f"speculative pool: {s['rounds']} rounds, "
                  f"{s['accepted']}/{s['drafted']} drafts accepted; extra "
                  f"resident draft bytes: "
                  f"{pool.resident_report()['extra_draft_bytes']}")
        print(f"upgrades (batched step -> stage): {result.upgrades}")
        for rid in sorted(result.tokens):
            print(f"client {rid}: tokens {result.tokens[rid]}")
        print(f"served {sum(len(v) for v in result.tokens.values())} tokens "
              f"across {pool.stage} precision stages with "
              f"{pool.decode_cache_size()} decode executable(s); "
              f"{len(result.events)} audited session events")
        if args.faults:
            from repro.transmission import ProgressiveClient

            clean = ProgressiveClient()
            clean.feed(blob)
            clean.materialize()
            result.client.materialize()
            if clean.store.fingerprint() != result.client.store.fingerprint():
                raise SystemExit(
                    "FAIL: pool store diverged from the clean stream")
            t = result.transport
            print(f"fault recovery verified (pool): store bit-identical; "
                  f"injected={t['injected']} "
                  f"quarantined={t['quarantined']}")
        _write_event_log(result, args.event_log)
        _write_metrics(args.metrics)
        return

    batch = build_batch(cfg, args.batch, args.prompt_len, seed=1)
    speculative = None
    max_len = args.prompt_len + args.decode_steps
    if args.speculative:
        from repro.serving.speculative import SpecConfig

        speculative = SpecConfig(draft_bits=args.draft_bits, k=args.draft_k)
        # headroom for the final verify block to write past the last
        # emitted token
        max_len += speculative.k_max + 1
    result = session.run_serving(
        model, prog, decode_steps=args.decode_steps, batch=batch,
        max_len=max_len, resident=None if speculative else args.resident,
        speculative=speculative, mesh=mesh,
        faults=faults, fault_policy=fault_policy)
    server = result.server
    if args.faults:
        _verify_fault_recovery(result, blob, model, prog, batch)
    if args.speculative:
        s = result.speculation_summary()
        rep = server.resident_report()
        print(f"speculative: {s['rounds']} rounds, draft {args.draft_bits} "
              f"bits, acceptance {s['accepted']}/{s['drafted']} "
              f"({s['rate']:.0%} of drafted)" if s["drafted"] else
              f"speculative: {s['rounds']} rounds (no precision gap yet)")
        print(f"zero-copy draft: extra resident draft bytes = "
              f"{rep['extra_draft_bytes']} ({rep['aliased_leaves']} aliased "
              f"leaves); decode executables: {server.decode_cache_size()}")
    elif args.resident == "quantized":
        rep = server.resident_report()
        print(f"quantized-resident: {rep['quantized_leaves']} weight leaves "
              f"on {rep['quantized_bytes']} uint bytes, "
              f"{rep['fp_bytes']} fp bytes (non-matmul remainder); "
              f"decode executables compiled: {server.decode_cache_size()}")
    print("upgrades (decode step -> stage):", result.upgrades)
    print("stage per step:", result.stage_at_step)
    print("tokens[0]:", [int(t) for t in result.tokens[0][:16]], "...")
    print(f"served {args.decode_steps} steps across {server.stage} precision "
          f"stages; {len(result.events)} audited session events")
    _write_event_log(result, args.event_log)
    _write_metrics(args.metrics)


if __name__ == "__main__":
    main()
