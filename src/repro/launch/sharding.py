"""Sharding rules: param/optimizer/cache/input PartitionSpecs.

Strategy (baseline — §Perf iterates from here):

* every weight matrix is 2-D sharded: one dim over the ``model`` axis
  (tensor parallel), another over the FSDP axes (``data``, plus ``pod``
  in multi-pod) — chosen greedily by size with divisibility checks, with
  semantic overrides for embeddings and expert banks;
* optimizer state shards exactly like its param;
* batch dims shard over (pod, data); decode KV caches shard batch over
  data and heads over model when head-count divides, else the sequence
  dim takes the model axis; long_500k (batch=1) puts sequence on data.

Everything returns NamedSharding so it can be handed to jax.jit
in_shardings/out_shardings directly.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, model_axis
from repro.core.wire import path_str

# tensors smaller than this stay replicated (no FSDP benefit)
_FSDP_MIN_ELEMENTS = 65_536


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


# Megatron-style directional rules (§Perf hillclimb): column-parallel
# producers shard their OUTPUT dim on the model axis, row-parallel
# consumers shard their INPUT (contraction) dim — attention heads and
# d_ff then stay model-sharded through the whole block with exactly one
# all-reduce per block output, instead of the greedy rule's
# shard-the-largest-dim which can put the model axis on a contraction
# and leave the downstream attention replicated 16-way.
_COL_PARALLEL = re.compile(r"(wq|wk|wv|wi_gate|wi_up|up_proj|in_proj|w_in|w_if)$")
_ROW_PARALLEL = re.compile(r"(wo|down_proj|out_proj)$")


def spec_for_param(path: str, shape: tuple, mesh: Mesh,
                   strategy: str = "greedy") -> P:
    """Choose a PartitionSpec for one parameter tensor.

    strategy: "greedy" (baseline — largest divisible dim takes the model
    axis) or "megatron" (directional column/row-parallel overrides,
    falling back to greedy where no rule matches).
    """
    fsdp = data_axes(mesh)
    tp = model_axis(mesh)
    tp_size = _axis_size(mesh, tp)
    fsdp_size = _axis_size(mesh, fsdp)

    if len(shape) <= 1:
        return P()

    # stacked cycle params carry a leading n_cycles dim -> never shard it
    start = 1 if "cycles/" in path else 0
    dims = list(range(start, len(shape)))
    spec: list[Any] = [None] * len(shape)

    if strategy == "megatron" and len(shape) - start == 2:
        leaf = path.rsplit("/", 1)[-1]
        tp_dim = None
        if _COL_PARALLEL.search(leaf):
            tp_dim = start + 1  # output dim
        elif _ROW_PARALLEL.search(leaf):
            tp_dim = start      # input (contraction) dim
        if tp_dim is not None and _divides(shape[tp_dim], tp_size):
            spec[tp_dim] = tp
            other = start + 1 if tp_dim == start else start
            size = 1
            for s in shape:
                size *= s
            if size >= _FSDP_MIN_ELEMENTS and _divides(shape[other], fsdp_size):
                spec[other] = fsdp
            return P(*spec)

    # semantic override (both strategies): expert banks (E, d, f) —
    # prefer expert dim for the model axis when it divides
    if re.search(r"we_(gate|up|down)", path):
        e_dim = start  # (R?, E, d, f)
        if _divides(shape[e_dim], tp_size):
            spec[e_dim] = tp
            dims.remove(e_dim)
        remaining = sorted(dims, key=lambda d: -shape[d])
        for d in remaining:
            if spec[e_dim] is None and _divides(shape[d], tp_size):
                spec[d] = tp
                dims.remove(d)
                break
        for d in sorted(dims, key=lambda d: -shape[d]):
            if spec[d] is None and _divides(shape[d], fsdp_size):
                spec[d] = fsdp
                break
        return P(*spec)

    # generic: largest divisible dim -> model axis; next -> fsdp
    order = sorted(dims, key=lambda d: -shape[d])
    tp_dim = next((d for d in order if _divides(shape[d], tp_size)), None)
    if tp_dim is not None:
        spec[tp_dim] = tp
    size = 1
    for s in shape:
        size *= s
    if size >= _FSDP_MIN_ELEMENTS:
        fsdp_dim = next(
            (d for d in order if d != tp_dim and _divides(shape[d], fsdp_size)), None
        )
        if fsdp_dim is not None:
            spec[fsdp_dim] = fsdp
    return P(*spec)


def serving_spec_for_param(path: str, shape: tuple, mesh: Mesh) -> P:
    """Reduction-order-safe PartitionSpec for a *serving* weight.

    The training rules above happily put the model axis on a contraction
    dim (row-parallel wo); under GSPMD that turns the matmul into
    per-shard partial sums + an all-reduce, which reorders float adds —
    fine for training, fatal for the serving exit criterion that a
    sharded server is *token-identical* to single-device at every
    precision stage. Serving therefore shards only dims that are never
    reduced over: the expert dim of MoE banks (indexed, not contracted)
    and otherwise the output (last) dim of each matmul weight — every
    resharding GSPMD inserts is then pure data movement (gathers), which
    is bit-exact. The data/fsdp axes never touch serving params (weights
    are replicated across data rows); 1-D and indivisible leaves
    replicate entirely. The :class:`~repro.core.plane_store.
    ShardedPlaneStore` routes plane ingest along the same axes, so the
    accumulator shard and the param shard it backs are the same bytes."""
    tp = model_axis(mesh)
    tp_size = _axis_size(mesh, tp)
    if tp_size <= 1 or len(shape) < 2:
        return P()
    # stacked cycle params carry a leading n_cycles dim -> never shard it
    start = 1 if "cycles/" in path else 0
    if len(shape) - start < 2:
        return P()
    spec: list[Any] = [None] * len(shape)
    if re.search(r"we_(gate|up|down)", path) and _divides(shape[start],
                                                          tp_size):
        spec[start] = tp  # expert dim: indexed per expert, never reduced
        return P(*spec)
    if _divides(shape[-1], tp_size):
        spec[-1] = tp     # output dim: concatenated, never reduced
        return P(*spec)
    return P()


def param_shardings(params_shape_tree, mesh: Mesh, strategy: str = "greedy"):
    def one(path, leaf):
        return NamedSharding(
            mesh, spec_for_param(path_str(path), leaf.shape, mesh, strategy)
        )

    return jax.tree_util.tree_map_with_path(one, params_shape_tree)


def opt_state_shardings(opt_shape_tree, params_shardings, mesh: Mesh):
    """mu/nu mirror params; scalars replicated."""
    return {
        "mu": params_shardings,
        "nu": params_shardings,
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(batch_shape_tree, mesh: Mesh):
    """Input batches: dim 0 over (pod, data)."""
    fsdp = data_axes(mesh)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and _divides(leaf.shape[0], _axis_size(mesh, fsdp)):
            spec[0] = fsdp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape_tree)


def cache_shardings(cache_shape_tree, mesh: Mesh, *, batch: int):
    """Decode caches. Layout per leaf kind:

    stacked KV:   (R, B, K, S, hd)   (native decode-kernel layout)
    tail KV:      (B, K, S, hd)
    mamba state:  (R?, B, H, N, hd)
    mlstm C:      (R?, B, H, hd, hd);  n: (R?, B, H, hd);  m: (R?, B, H)
    slstm states: (R?, B, d_inner) / (R?, B, H)

    Batch dim -> data when divisible; else (long_500k, B=1) sequence/head
    dims absorb data. Head/seq dims -> model when divisible.
    """
    fsdp = data_axes(mesh)
    tp = model_axis(mesh)
    fsdp_size = _axis_size(mesh, fsdp)
    tp_size = _axis_size(mesh, tp)

    def one(path, leaf):
        shape = leaf.shape
        p = path_str(path)
        spec: list[Any] = [None] * len(shape)
        # locate batch dim: first dim equal to `batch`, skipping a
        # leading stacked dim when present
        start = 1 if ("cycles/" in p and len(shape) >= 2) else 0
        bdim = None
        for d in range(start, len(shape)):
            if shape[d] == batch:
                bdim = d
                break
        batch_on_data = bdim is not None and _divides(batch, fsdp_size)
        if batch_on_data:
            spec[bdim] = fsdp
        # model axis: largest remaining divisible dim (prefers seq/heads)
        rest = [d for d in range(start, len(shape)) if d != bdim]
        order = sorted(rest, key=lambda d: -shape[d])
        tp_dim = next((d for d in order if _divides(shape[d], tp_size)), None)
        if tp_dim is not None:
            spec[tp_dim] = tp
        # batch=1 long-context: give `data` to another divisible dim
        if not batch_on_data:
            d_dim = next(
                (d for d in order if d != tp_dim and _divides(shape[d], fsdp_size)),
                None,
            )
            if d_dim is not None:
                spec[d_dim] = fsdp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
