"""Step builders shared by the dry-run, the trainer, and the server.

These are the exact functions that get pjit'd onto the production mesh:

    train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
    prefill_step(params, batch)                 -> (last_logits, caches)
    serve_step(params, caches, tokens, pos)     -> (logits, caches)

plus the input-spec helpers that produce ShapeDtypeStruct stand-ins for
every argument (the dry-run lowers against these; nothing allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.model import Model, build_model
from repro.train import optimizer as opt


# -- workload shapes (assigned) ------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": WorkloadShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": WorkloadShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": WorkloadShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": WorkloadShape("long_500k", 524_288, 1, "decode"),
}

# long_500k runs only where prefill/decoding is sub-quadratic or
# sliding-window-dominated (DESIGN.md §4); pure full-attention archs skip.
LONG_CONTEXT_OK = {
    "gemma3-27b",      # 5:1 SWA-1024 : global
    "xlstm-125m",      # recurrent, O(1) state
    "zamba2-7b",       # Mamba2-dominated hybrid
    "mixtral-8x22b",   # SWA-4096 everywhere
}


def long_context_supported(cfg: ArchConfig) -> bool:
    return cfg.name in LONG_CONTEXT_OK


# -- step builders ---------------------------------------------------------------

def make_train_step(model: Model, ocfg: opt.OptConfig, *, microbatches: int = 1):
    """microbatches > 1 = gradient accumulation: the global batch is
    split along dim 0 and swept under lax.scan, shrinking peak activation
    memory by ~the microbatch factor at the cost of re-running the
    (already scanned) layer stack per slice. §Perf iterates on this."""

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc, l_acc, m_acc = acc
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = {k: m_acc[k] + metrics[k] for k in m_acc}
                return (g_acc, l_acc + loss, m_acc), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            metrics0 = {"ce": jnp.float32(0), "balance_loss": jnp.float32(0),
                        "dropped_frac": jnp.float32(0)}
            if model.cfg.costing:
                # unrolled so cost_analysis counts every microbatch
                carry = (zeros, jnp.float32(0), metrics0)
                for i in range(microbatches):
                    carry, _ = body(carry, jax.tree.map(lambda x: x[i], micro))
                grads, loss, metrics = carry
            else:
                (grads, loss, metrics), _ = jax.lax.scan(
                    body, (zeros, jnp.float32(0), metrics0), micro
                )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {k: v / microbatches for k, v in metrics.items()}
        params, opt_state, opt_metrics = opt.update(ocfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    return serve_step


# -- dry-run input specs ------------------------------------------------------------

def step_input_specs(cfg: ArchConfig, shape: WorkloadShape):
    """ShapeDtypeStructs for every argument of the step for this shape.

    Returns (step_fn_builder_name, specs_tuple) where specs_tuple matches
    the positional signature of the corresponding step function.
    """
    model = build_model(cfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    B, S = shape.global_batch, shape.seq_len

    if shape.mode == "train":
        batch = model.input_specs(batch=B, seq_len=S, mode="train")
        opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
        return (params_sds, opt_sds, batch)

    if shape.mode == "prefill":
        batch = model.input_specs(batch=B, seq_len=S, mode="prefill")
        return (params_sds, batch)

    # decode: one token against a seq_len-deep cache
    caches_sds = jax.eval_shape(lambda: model.init_caches(B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (params_sds, caches_sds, tokens, pos)
