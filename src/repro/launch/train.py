"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --batch 8 --seq 128

Full-size configs on the production mesh run on a real cluster with the
same code path (the mesh context + shardings are identical to the
dry-run); on this CPU box use ``--reduced`` for a runnable scale. The
loop saves *progressive* checkpoints (header + bit-plane stages), which
is the paper's artifact: a checkpoint you can cold-start from at 2 bits.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.models.model import build_model
from repro.train import optimizer as opt
from repro.train.data import DataConfig
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)

    def extra(batch):
        import jax.numpy as jnp

        B, S = batch["tokens"].shape
        if cfg.enc_layers:
            batch["enc_input"] = jnp.zeros(
                (B, max(1, S // cfg.enc_seq_divisor), cfg.d_model), cfg.dtype
            )
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (B, cfg.vision_tokens, cfg.d_vision), cfg.dtype
            )
        return batch

    result = train(
        model,
        steps=args.steps,
        data_cfg=data_cfg,
        opt_cfg=opt.OptConfig(lr=args.lr, total_steps=args.steps),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=args.log_every,
        seed=args.seed,
        extra_batch=extra,
    )
    for h in result.history:
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in h.items()}))
    first, last = result.history[0]["loss"], result.history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
