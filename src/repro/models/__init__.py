from repro.models.common import ArchConfig
from repro.models.model import Model, build_model

__all__ = ["ArchConfig", "Model", "build_model"]
