"""Attention: RoPE, chunked online-softmax (flash-style) attention, and
the attention-family block (full / sliding-window / cross / enc-dec).

The chunked attention is the load-bearing piece for prefill/training:
it scans over KV chunks with a running (max, denominator, accumulator)
triple, so neither the 32k-prefill compile nor the 500k-decode compile
ever materializes a (Tq, Tk) score matrix.

The per-token decode path is different: KV caches are stored in the
flash kernel's **native** ``(B, Kh, S, hd)`` layout from prefill
onwards, each decode step writes one token per slot at its own
position (``pos`` may be a ``(B,)`` vector — ragged continuous
batching), and attention runs through
:func:`repro.kernels.ops.decode_attention` (the Pallas flash kernel on
TPU, its vectorized jnp oracle elsewhere). No transpose and no
sequence-axis padding of the cache ever happens inside the hot loop —
each cache byte crosses HBM exactly once per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.models.common import (ArchConfig, apply_norm, norm_init,
                                 activation, dense, dense_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); pos: (T,) shared or (B, T) per-slot int32
    positions (ragged decode batches rotate every slot at its own
    position)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos.astype(jnp.float32)[..., None] * freqs  # (..., T, half)
    if angles.ndim == 2:
        angles = angles[None]                            # (1|B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, K, hd)
    v: jax.Array,  # (B, Tk, K, hd)
    q_pos: jax.Array,  # (Tq,) int32
    k_pos: jax.Array,  # (Tk,) int32; negative = invalid slot
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    softcap: float = 0.0,
    unroll: bool = False,
) -> jax.Array:
    B, Tq, H, hd = q.shape
    Tk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd**-0.5

    chunk = min(chunk, Tk) if Tk else 1
    if unroll:
        # costing mode: cap the unrolled trip count at 16 by enlarging the
        # chunk (FLOPs/bytes are chunk-size-invariant; only tiling changes)
        chunk = max(chunk, -(-Tk // 16))
    pad = (-Tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    n_chunks = k.shape[1] // chunk

    qg = q.reshape(B, Tq, K, G, hd).astype(jnp.float32) * scale
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, K, hd), 1, 0)
    pc = k_pos.reshape(n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry  # (B,K,G,Tq), (B,K,G,Tq), (B,K,G,Tq,hd)
        kk, vv, pp = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kk.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        valid = pp[None, :] >= 0  # (1, chunk)
        if causal:
            valid = valid & (pp[None, :] <= q_pos[:, None])
        if window:
            valid = valid & (pp[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vv.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Tq, hd), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for c in range(n_chunks):
            carry, _ = body(carry, (kc[c], vc[c], pc[c]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,Tq,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Tq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache helpers (native (B, K, S, hd) layout)
# ---------------------------------------------------------------------------

def make_ring_cache(k: jax.Array, v: jax.Array, window: int):
    """Prefill -> ring cache holding the last `window` positions at slot
    p % window. k/v: (B, S, K, hd) in; caches come out in the native
    (B, K, window, hd) layout. Speculative decoding over-allocates the
    ring AFTER prefill (see :func:`grow_ring_cache`) so speculative
    writes past the head never clobber entries still inside a live
    window."""
    B, S, K, hd = k.shape
    W = min(window, S)
    slots = jnp.arange(S - W, S) % window
    kn = jnp.swapaxes(k, 1, 2)  # one transpose at prefill, never per step
    vn = jnp.swapaxes(v, 1, 2)
    ring_k = jnp.zeros((B, K, window, hd), k.dtype).at[:, :, slots].set(kn[:, :, S - W :])
    ring_v = jnp.zeros((B, K, window, hd), v.dtype).at[:, :, slots].set(vn[:, :, S - W :])
    return ring_k, ring_v


def grow_ring_cache(cache: dict, new_size: int, pos: int) -> dict:
    """Repack a prefill-produced ring cache (ring size = its S axis)
    into a larger ring, preserving every stored position. ``pos`` is the
    next write position (= tokens consumed so far) — a concrete host
    int, so this is plain indexing, done once per request at admission.

    Why: a ring of size W is only safe when positions are written in
    strict sequence (writing p clobbers p - W exactly when no future
    query can attend p - W). A speculative round writes k + 1 positions
    ahead and may then *rewind* to the first rejection, after which
    still-live window entries would have been clobbered. Over-allocating
    the ring to W + k + 1 restores the invariant: the attention window
    mask is still ``window`` (positions), only the slot layout widens.
    """
    R = cache["k"].shape[-2]  # slot axis is -2 (stacked or not)
    if new_size <= R:
        return cache
    import numpy as np

    held = np.asarray(ring_positions(R, pos - 1)) if pos > 0 else \
        np.full((R,), -1, np.int64)
    src = np.nonzero(held >= 0)[0]
    dst = held[src] % new_size

    def regrow(a):
        shp = a.shape[:-2] + (new_size,) + a.shape[-1:]
        out = jnp.zeros(shp, a.dtype)
        return out.at[..., dst, :].set(a[..., src, :])

    return {"k": regrow(cache["k"]), "v": regrow(cache["v"])}


def ring_positions(window: int, pos: jax.Array) -> jax.Array:
    """Position stored at each ring slot after a write at ``pos``;
    negative for not-yet-filled slots. ``pos`` scalar -> (window,);
    ``pos`` (B,) -> (B, window) per-slot position maps."""
    i = jnp.arange(window)
    p = jnp.asarray(pos)[..., None]   # () -> (1,); (B,) -> (B, 1)
    return p - ((p - i) % window)     # (window,) or (B, window)


def write_kv_slot(cache: jax.Array, new: jax.Array, pos: jax.Array,
                  active: jax.Array | None = None) -> jax.Array:
    """Write a token block's K or V into the native cache at each slot's
    own position. cache: (B, K, S, hd); new: (B, K, T, hd) — T = 1 for a
    decode step, T = k+1 contiguous rows for a verify block; pos: (B,)
    int32 (clamped into range, so a free slot's ``-1`` writes harmlessly
    at 0 — its row is fully masked anyway). ``active`` (B,) bool makes
    the write a per-slot no-op instead (the verify path uses it so a
    masked slot's cache row stays byte-identical, which is what lets the
    rollback invariant be tested at equality)."""
    def upd(c, u, p, a=None):
        u = u.astype(c.dtype)
        if a is not None:
            old = lax.dynamic_slice(c, (0, p, 0), u.shape)
            u = jnp.where(a, u, old)
        return lax.dynamic_update_slice(c, u, (0, p, 0))

    if active is None:
        return jax.vmap(upd)(cache, new, pos)
    return jax.vmap(upd)(cache, new, pos, active)


# ---------------------------------------------------------------------------
# Attention-family blocks
# ---------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, cfg.d_model, cfg.d_ff),
        "wi_up": dense_init(k2, cfg.d_model, cfg.d_ff),
        "wo": dense_init(k3, cfg.d_ff, cfg.d_model),
    }


def mlp_apply(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    dt = cfg.dtype
    h = activation(cfg, dense(x, p["wi_gate"], dtype=dt)) \
        * dense(x, p["wi_up"], dtype=dt)
    return dense(h, p["wo"], dtype=dt)


def attn_init(cfg: ArchConfig, key, *, cross: bool = False):
    ks = jax.random.split(key, 5)
    kv_in = cfg.d_model  # enc states are projected to d_model upstream
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.hd),
        "wk": dense_init(ks[1], kv_in, cfg.n_kv * cfg.hd),
        "wv": dense_init(ks[2], kv_in, cfg.n_kv * cfg.hd),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.hd, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _qk_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * scale).astype(x.dtype)


def project_qkv(cfg: ArchConfig, p, x: jax.Array, kv_src: jax.Array):
    dt = cfg.dtype
    B, Tq, _ = x.shape
    Tk = kv_src.shape[1]
    q = dense(x, p["wq"], dtype=dt).reshape(B, Tq, cfg.n_heads, cfg.hd)
    k = dense(kv_src, p["wk"], dtype=dt).reshape(B, Tk, cfg.n_kv, cfg.hd)
    v = dense(kv_src, p["wv"], dtype=dt).reshape(B, Tk, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    return q, k, v


def decode_pos_vector(pos, batch: int) -> jax.Array:
    """Normalize a decode position argument — scalar (lock-stepped
    stream) or (B,) vector (ragged slot pool) — to a (B,) int32."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(p, (batch,)) if p.ndim == 0 else p


def self_attention(
    cfg: ArchConfig,
    p,
    x: jax.Array,
    *,
    mode: str,  # full | prefill | prefill_chunk | verify | decode
    window: int,
    cache,  # {"k","v"} native (B, K, S|W, hd) or None
    pos,  # decode/verify: scalar or (B,) int32 per-slot positions;
          # prefill_chunk: (B, T) per-token positions (negative = masked);
          # prefill: optional (B,) valid lengths for bucket-padded prompts
    rope_theta: float | None = None,
):
    """Returns (attn_out, new_cache)."""
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    B, Tq, _ = x.shape
    if mode in ("full", "prefill"):
        q, k, v = project_qkv(cfg, p, x, x)
        q_pos = jnp.arange(Tq, dtype=jnp.int32)
        if pos is not None:
            # bucket-padded prefill: positions at/after the valid length
            # are masked out (-1). All batch rows share one valid length
            # (the pool prefills at batch 1); keys at masked positions
            # are invisible to every query, and their garbage cache rows
            # sit beyond the prompt, overwritten by decode before any
            # query can attend them.
            if window:
                raise NotImplementedError(
                    "bucketed prefill is not supported for sliding-window "
                    "attention (the ring layout has no masked slots)")
            nv = jnp.asarray(pos, jnp.int32).reshape(-1)[0]
            q_pos = jnp.where(q_pos < nv, q_pos, jnp.int32(-1))
        q = rope(q, q_pos, theta)
        k = rope(k, q_pos, theta)
        out = chunked_attention(
            q, k, v, q_pos, q_pos, causal=True, window=window,
            chunk=cfg.attn_chunk, unroll=cfg.costing,
        )
        new_cache = None
        if mode == "prefill":
            if window:
                rk, rv = make_ring_cache(k, v, window)
                new_cache = {"k": rk, "v": rv}
            else:
                # one transpose at prefill; decode never transposes
                new_cache = {"k": jnp.swapaxes(k, 1, 2),
                             "v": jnp.swapaxes(v, 1, 2)}
    elif mode in ("verify", "prefill_chunk"):
        # One multi-row pass per slot: T = k+1 draft tokens (verify) or
        # a (B, chunk) block of ragged prompt positions (chunked
        # prefill, writing prompt KV straight into the pooled cache).
        q, k_new, v_new = project_qkv(cfg, p, x, x)
        if mode == "prefill_chunk":
            # per-token positions arrive precomputed: row t of slot b
            # holds prompt position off_b + t, or -1 for masked rows
            # (free/decoding slots riding the batched launch, ragged
            # padding past a short final chunk)
            tok_pos = jnp.asarray(pos, jnp.int32)              # (B, T)
        else:
            pos_vec = decode_pos_vector(pos, B)                # (B,) base
            # per-token positions; a negative base (free pool slot)
            # keeps every row masked instead of walking into valid range
            tok_pos = jnp.where(pos_vec[:, None] >= 0,
                                pos_vec[:, None]
                                + jnp.arange(Tq, dtype=jnp.int32)[None, :],
                                jnp.int32(-1))                 # (B, T)
        q = rope(q, tok_pos, theta)
        k_new = rope(k_new, tok_pos, theta)
        kn = jnp.swapaxes(k_new, 1, 2)                         # (B, K, T, hd)
        vn = jnp.swapaxes(v_new, 1, 2)
        # write the whole block FIRST, then attend: rejected verify rows
        # are never rolled back — the next round simply overwrites them,
        # and the per-row causal mask (k_pos <= q_pos) keeps any not-yet
        # -overwritten row invisible to every live query. Masked rows
        # (free/finished slots, ragged padding) write NOTHING — their
        # cache rows stay byte-identical.
        if window:
            ring = cache["k"].shape[2]
            if ring < window + Tq:
                raise ValueError(
                    f"multi-row writes over a ring cache need ring >= "
                    f"window + T ({window} + {Tq}), got {ring}: the block "
                    f"would clobber live window entries (grow the cache "
                    f"with ring_margin >= the block length)")
            k_cache, v_cache = cache["k"], cache["v"]
            for t in range(Tq):
                wp = jnp.maximum(tok_pos[:, t], 0) % ring
                live = tok_pos[:, t] >= 0
                k_cache = write_kv_slot(k_cache, kn[:, :, t:t + 1], wp, live)
                v_cache = write_kv_slot(v_cache, vn[:, :, t:t + 1], wp, live)
            # last written position per slot (-1 if fully masked): for a
            # verify block this is pos + T - 1; for a chunk, off + n - 1
            head = jnp.max(tok_pos, axis=1)
            k_pos = ring_positions(ring, head)                 # (B, ring)
            # ring_positions(-1) is all-negative, so a masked slot's
            # whole ring stays invisible
        elif mode == "prefill_chunk":
            # per-row masked writes: a short final chunk must NOT write
            # its padded tail — a T-wide block write starting at the
            # last prompt position would CLAMP near the cache end
            # (dynamic_update_slice shifts the start to S - T) and drag
            # garbage onto real prompt rows. T single-row writes never
            # clamp (every live row < max_len) and leave masked rows
            # byte-identical.
            k_cache, v_cache = cache["k"], cache["v"]
            for t in range(Tq):
                wp = jnp.maximum(tok_pos[:, t], 0)
                live = tok_pos[:, t] >= 0
                k_cache = write_kv_slot(k_cache, kn[:, :, t:t + 1], wp, live)
                v_cache = write_kv_slot(v_cache, vn[:, :, t:t + 1], wp, live)
            S = k_cache.shape[2]
            k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            # contiguous verify block: one vmapped T-wide update per
            # slot. The speculative pool's max_len headroom (budget
            # ceiling + k_max + 1) guarantees the block never reaches
            # the cache end, so the write cannot clamp.
            row0 = tok_pos[:, 0]
            k_cache = write_kv_slot(cache["k"], kn, jnp.maximum(row0, 0),
                                    row0 >= 0)
            v_cache = write_kv_slot(cache["v"], vn, jnp.maximum(row0, 0),
                                    row0 >= 0)
            S = k_cache.shape[2]
            k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        attend = (ops.prefill_attention if mode == "prefill_chunk"
                  else ops.verify_attention)
        out = attend(
            q, k_cache, v_cache, k_pos.astype(jnp.int32), tok_pos,
            window=window,
        )                                                      # (B, T, H, hd)
        new_cache = {"k": k_cache, "v": v_cache}
    else:  # decode: ragged, native-layout, one batched kernel call
        q, k_new, v_new = project_qkv(cfg, p, x, x)
        pos_vec = decode_pos_vector(pos, B)                    # (B,)
        q = rope(q, pos_vec[:, None], theta)
        k_new = rope(k_new, pos_vec[:, None], theta)
        kn = jnp.swapaxes(k_new, 1, 2)                         # (B, K, 1, hd)
        vn = jnp.swapaxes(v_new, 1, 2)
        # inactive slots (pos < 0) write NOTHING: a mid-prefill slot's
        # freshly-written prompt KV at position 0 must survive decode
        # steps dispatched while its remaining chunks are still queued
        live = pos_vec >= 0
        if window:
            # ring size comes from the cache (it may be over-allocated
            # beyond the attention window for speculative rounds); the
            # window mask itself is positional, never layout
            ring = cache["k"].shape[2]
            slot = jnp.maximum(pos_vec, 0) % ring
            k_cache = write_kv_slot(cache["k"], kn, slot, live)
            v_cache = write_kv_slot(cache["v"], vn, slot, live)
            k_pos = ring_positions(ring, pos_vec)              # (B, ring)
        else:
            k_cache = write_kv_slot(cache["k"], kn, jnp.maximum(pos_vec, 0),
                                    live)
            v_cache = write_kv_slot(cache["v"], vn, jnp.maximum(pos_vec, 0),
                                    live)
            S = k_cache.shape[2]
            # the kernel masks k_pos > q_pos per slot; stale entries
            # beyond each slot's position never contribute
            k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out = ops.decode_attention(
            q[:, 0], k_cache, v_cache, k_pos.astype(jnp.int32), pos_vec,
            window=window,
        )[:, None]                                             # (B, 1, H, hd)
        new_cache = {"k": k_cache, "v": v_cache}
    return dense(out.reshape(B, Tq, -1), p["wo"], dtype=cfg.dtype), new_cache


def cross_attention(cfg: ArchConfig, p, x: jax.Array, enc_kv, *,
                    native: bool = False):
    """enc_kv: precomputed {"k","v"} from the encoder or vision
    projector — computed once at prefill, static afterwards. With
    ``native=False`` (prefill/full) the memory is (B, Tv, K, hd) and
    attention runs chunked; with ``native=True`` (decode Tq == 1, or a
    verify block Tq == k+1) the memory is the cached native
    (B, K, Tv, hd) layout and attention runs through the ragged
    decode/verify kernel with every memory slot valid — no per-step
    transpose of the cross cache."""
    dt = cfg.dtype
    B, Tq, _ = x.shape
    q = dense(x, p["wq"], dtype=dt).reshape(B, Tq, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"])
    if native:
        Tv = enc_kv["k"].shape[2]
        k_pos = jnp.broadcast_to(jnp.arange(Tv, dtype=jnp.int32), (B, Tv))
        # non-causal: q_pos = Tv admits every memory slot for every slot
        if Tq == 1:
            q_pos = jnp.full((B,), Tv, jnp.int32)
            out = ops.decode_attention(
                q[:, 0], enc_kv["k"], enc_kv["v"], k_pos, q_pos, window=0,
            )[:, None]
        else:
            q_pos = jnp.full((B, Tq), Tv, jnp.int32)
            out = ops.verify_attention(
                q, enc_kv["k"], enc_kv["v"], k_pos, q_pos, window=0,
            )
    else:
        Tv = enc_kv["k"].shape[1]
        k_pos = jnp.arange(Tv, dtype=jnp.int32)
        q_pos = jnp.zeros((Tq,), jnp.int32)  # no causality vs. memory tokens
        out = chunked_attention(
            q, enc_kv["k"], enc_kv["v"], q_pos, k_pos, causal=False, window=0,
            chunk=cfg.attn_chunk, unroll=cfg.costing,
        )
    return dense(out.reshape(B, Tq, -1), p["wo"], dtype=dt)


def cross_kv(cfg: ArchConfig, p, enc_out: jax.Array):
    """Project encoder/vision states to this block's K/V once.
    Returns the sequence-major (B, Tv, K, hd) layout used by the
    chunked prefill path; cache it with :func:`to_native_kv`."""
    dt = cfg.dtype
    B, Tv, _ = enc_out.shape
    k = dense(enc_out, p["wk"], dtype=dt).reshape(B, Tv, cfg.n_kv, cfg.hd)
    v = dense(enc_out, p["wv"], dtype=dt).reshape(B, Tv, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        k = _qk_norm(k, p["k_norm"])
    return {"k": k, "v": v}


def to_native_kv(kv):
    """(B, Tv, K, hd) -> native (B, K, Tv, hd); one transpose at
    prefill so decode steps read the cache as-is."""
    return {"k": jnp.swapaxes(kv["k"], 1, 2), "v": jnp.swapaxes(kv["v"], 1, 2)}
