"""Attention: RoPE, chunked online-softmax (flash-style) attention, and
the attention-family block (full / sliding-window / cross / enc-dec).

The chunked attention is the load-bearing piece for this box: it scans
over KV chunks with a running (max, denominator, accumulator) triple, so
neither the 32k-prefill compile nor the 500k-decode compile ever
materializes a (Tq, Tk) score matrix. The same structure is what the
Pallas flash kernel implements on real TPUs (``kernels/decode_attention``);
this module is its jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (ArchConfig, apply_norm, norm_init,
                                 activation, dense, dense_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); pos: (T,) int32 positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, K, hd)
    v: jax.Array,  # (B, Tk, K, hd)
    q_pos: jax.Array,  # (Tq,) int32
    k_pos: jax.Array,  # (Tk,) int32; negative = invalid slot
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    softcap: float = 0.0,
    unroll: bool = False,
) -> jax.Array:
    B, Tq, H, hd = q.shape
    Tk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd**-0.5

    chunk = min(chunk, Tk) if Tk else 1
    if unroll:
        # costing mode: cap the unrolled trip count at 16 by enlarging the
        # chunk (FLOPs/bytes are chunk-size-invariant; only tiling changes)
        chunk = max(chunk, -(-Tk // 16))
    pad = (-Tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    n_chunks = k.shape[1] // chunk

    qg = q.reshape(B, Tq, K, G, hd).astype(jnp.float32) * scale
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, K, hd), 1, 0)
    pc = k_pos.reshape(n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry  # (B,K,G,Tq), (B,K,G,Tq), (B,K,G,Tq,hd)
        kk, vv, pp = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kk.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        valid = pp[None, :] >= 0  # (1, chunk)
        if causal:
            valid = valid & (pp[None, :] <= q_pos[:, None])
        if window:
            valid = valid & (pp[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vv.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Tq, hd), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for c in range(n_chunks):
            carry, _ = body(carry, (kc[c], vc[c], pc[c]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,Tq,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Tq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache helpers
# ---------------------------------------------------------------------------

def make_ring_cache(k: jax.Array, v: jax.Array, window: int):
    """Prefill -> ring cache holding the last `window` positions at slot
    p % window. k/v: (B, S, K, hd)."""
    B, S, K, hd = k.shape
    W = min(window, S)
    slots = jnp.arange(S - W, S) % window
    ring_k = jnp.zeros((B, window, K, hd), k.dtype).at[:, slots].set(k[:, S - W :])
    ring_v = jnp.zeros((B, window, K, hd), v.dtype).at[:, slots].set(v[:, S - W :])
    return ring_k, ring_v


def ring_positions(window: int, pos: jax.Array) -> jax.Array:
    """Position stored at each ring slot after a write at ``pos``;
    negative for not-yet-filled slots."""
    i = jnp.arange(window)
    return pos - ((pos - i) % window)


# ---------------------------------------------------------------------------
# Attention-family blocks
# ---------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, cfg.d_model, cfg.d_ff),
        "wi_up": dense_init(k2, cfg.d_model, cfg.d_ff),
        "wo": dense_init(k3, cfg.d_ff, cfg.d_model),
    }


def mlp_apply(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    dt = cfg.dtype
    h = activation(cfg, dense(x, p["wi_gate"], dtype=dt)) \
        * dense(x, p["wi_up"], dtype=dt)
    return dense(h, p["wo"], dtype=dt)


def attn_init(cfg: ArchConfig, key, *, cross: bool = False):
    ks = jax.random.split(key, 5)
    kv_in = cfg.d_model  # enc states are projected to d_model upstream
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.hd),
        "wk": dense_init(ks[1], kv_in, cfg.n_kv * cfg.hd),
        "wv": dense_init(ks[2], kv_in, cfg.n_kv * cfg.hd),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.hd, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _qk_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (y * scale).astype(x.dtype)


def project_qkv(cfg: ArchConfig, p, x: jax.Array, kv_src: jax.Array):
    dt = cfg.dtype
    B, Tq, _ = x.shape
    Tk = kv_src.shape[1]
    q = dense(x, p["wq"], dtype=dt).reshape(B, Tq, cfg.n_heads, cfg.hd)
    k = dense(kv_src, p["wk"], dtype=dt).reshape(B, Tk, cfg.n_kv, cfg.hd)
    v = dense(kv_src, p["wv"], dtype=dt).reshape(B, Tk, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    return q, k, v


def self_attention(
    cfg: ArchConfig,
    p,
    x: jax.Array,
    *,
    mode: str,  # full | prefill | decode
    window: int,
    cache,  # {"k","v"} or None
    pos,  # decode: scalar int32; else None
    rope_theta: float | None = None,
):
    """Returns (attn_out, new_cache)."""
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    B, Tq, _ = x.shape
    if mode in ("full", "prefill"):
        q, k, v = project_qkv(cfg, p, x, x)
        q_pos = jnp.arange(Tq, dtype=jnp.int32)
        q = rope(q, q_pos, theta)
        k = rope(k, q_pos, theta)
        out = chunked_attention(
            q, k, v, q_pos, q_pos, causal=True, window=window,
            chunk=cfg.attn_chunk, unroll=cfg.costing,
        )
        new_cache = None
        if mode == "prefill":
            if window:
                rk, rv = make_ring_cache(k, v, window)
                new_cache = {"k": rk, "v": rv}
            else:
                new_cache = {"k": k, "v": v}
    else:  # decode
        q, k_new, v_new = project_qkv(cfg, p, x, x)
        pos_arr = jnp.full((Tq,), pos, jnp.int32)
        q = rope(q, pos_arr, theta)
        k_new = rope(k_new, pos_arr, theta)
        if window:
            slot = pos % window
            k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
            v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
            k_pos = ring_positions(window, pos)
        else:
            k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
            v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
            S = k_cache.shape[1]
            k_pos = jnp.where(jnp.arange(S) <= pos, jnp.arange(S), -1)
        out = chunked_attention(
            q,
            k_cache,
            v_cache,
            pos_arr,
            k_pos.astype(jnp.int32),
            causal=True,
            window=window,
            chunk=cfg.attn_chunk,
            unroll=cfg.costing,
        )
        new_cache = {"k": k_cache, "v": v_cache}
    return dense(out.reshape(B, Tq, -1), p["wo"], dtype=cfg.dtype), new_cache


def cross_attention(cfg: ArchConfig, p, x: jax.Array, enc_kv):
    """enc_kv: precomputed {"k","v"} (B, Tv, K, hd) from the encoder or
    vision projector — computed once at prefill, static afterwards."""
    dt = cfg.dtype
    B, Tq, _ = x.shape
    q = dense(x, p["wq"], dtype=dt).reshape(B, Tq, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"])
    Tv = enc_kv["k"].shape[1]
    k_pos = jnp.arange(Tv, dtype=jnp.int32)
    q_pos = jnp.zeros((Tq,), jnp.int32)  # no causality vs. memory tokens
    out = chunked_attention(
        q, enc_kv["k"], enc_kv["v"], q_pos, k_pos, causal=False, window=0,
        chunk=cfg.attn_chunk, unroll=cfg.costing,
    )
    return dense(out.reshape(B, Tq, -1), p["wo"], dtype=dt)


def cross_kv(cfg: ArchConfig, p, enc_out: jax.Array):
    """Project encoder/vision states to this block's K/V once."""
    dt = cfg.dtype
    B, Tv, _ = enc_out.shape
    k = dense(enc_out, p["wk"], dtype=dt).reshape(B, Tv, cfg.n_kv, cfg.hd)
    v = dense(enc_out, p["wv"], dtype=dt).reshape(B, Tv, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        k = _qk_norm(k, p["k_norm"])
    return {"k": k, "v": v}
