"""Shared model plumbing: config dataclass, norms, activations, init —
and the ONE dense-apply dispatch point of quantized-resident serving
(:func:`dense` / :func:`expert_dense` / :func:`embed_lookup`)."""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedTensor
from repro.kernels import ops

# Trace-time serving-mesh stack (see :func:`serving_mesh`): non-empty
# top means the dispatch helpers below pin their outputs replicated.
_SERVING_MESH: list = [None]


@contextlib.contextmanager
def serving_mesh(mesh):
    """While active (at *trace* time), every dispatch-helper output is
    pinned replicated on ``mesh`` via ``with_sharding_constraint``.

    This is the whole trick that makes sharded serving token-identical
    to single-device: GSPMD only reorders float reductions when a
    *contraction* dim is sharded, and with every activation pinned
    replicated, each matmul sees a replicated input against a weight
    sharded on a non-contraction dim (see ``serving_spec_for_param``) —
    the only collectives are output all-gathers, pure data movement,
    bit-exact. The engines wrap their jitted model entry points in this
    context (``PrecisionManagedEngine._meshed``); with no mesh active
    the helpers are byte-for-byte the single-device code path."""
    _SERVING_MESH.append(mesh)
    try:
        yield
    finally:
        _SERVING_MESH.pop()


def _pin_replicated(y: jax.Array) -> jax.Array:
    mesh = _SERVING_MESH[-1]
    if mesh is None:
        return y
    return jax.lax.with_sharding_constraint(
        y, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture. ``cycle`` is the repeating block pattern; layers
    = len(cycle) * n_cycles + len(tail). Block kinds:

    attn        full-attention decoder block (GQA + GLU MLP)
    swa         sliding-window attention block (window=cfg.window)
    global      full attention (gemma3 naming, distinct rope_theta)
    moe         attention + MoE FFN (full attn)
    swa_moe     sliding-window attention + MoE FFN (mixtral)
    cross       cross-attention block (VLM image layers)
    selfcross   self-attn + cross-attn + MLP in one block (enc-dec decoder)
    mamba2      Mamba-2 SSD block
    slstm       xLSTM sLSTM block
    mlstm       xLSTM mLSTM block
    shared_attn Zamba2 shared transformer block (one weight set reused)
    enc_attn    bidirectional encoder block
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    cycle: tuple[str, ...] = ("attn",)
    head_dim: int | None = None
    # attention
    rope_theta: float = 10_000.0
    window: int = 0  # sliding window width for swa/local blocks
    qk_norm: bool = False
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"
    attn_chunk: int = 1024  # online-softmax KV chunk
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # xlstm
    lstm_proj_factor: float = 2.0
    # enc-dec (audio)
    enc_layers: int = 0
    enc_seq_divisor: int = 4  # encoder frames = seq // divisor
    # vlm
    vision_tokens: int = 0
    d_vision: int = 0
    # output
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    # compute dtype for activations
    dtype: Any = jnp.bfloat16
    # storage dtype for parameters. fp32 = training default (master
    # weights); bf16 halves the resident weight bytes + HBM traffic for
    # serving (§Perf iteration; quantized-resident serving
    # (ProgressiveServer(resident="quantized")) goes further, to k/16
    # of bf16, with no fp copy at all)
    param_dtype: Any = jnp.float32
    # rematerialize cycle bodies in the training forward (memory/compute
    # trade; §Perf iterates on this)
    remat: bool = True
    # costing mode: unroll every lax.scan (cycle stack, attention chunks,
    # SSD chunks, CE chunks) so compiled.cost_analysis() counts loop
    # bodies x trip_count. XLA's HLO cost analysis visits a while-loop
    # body ONCE (verified; see EXPERIMENTS.md §Dry-run), so the scanned
    # production model undercounts FLOPs/bytes/collectives by the trip
    # counts. The costing variant is mathematically identical (scan
    # unrolling does not change the computed function); only HLO size
    # and compile time differ. Never use for real training.
    costing: bool = False

    def for_costing(self) -> "ArchConfig":
        import dataclasses as _dc

        return _dc.replace(self, costing=True)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_cycles(self) -> int:
        return self.n_layers // len(self.cycle)

    @property
    def tail(self) -> tuple[str, ...]:
        """Remainder blocks after full cycles, continuing the pattern."""
        r = self.n_layers % len(self.cycle)
        return self.cycle[:r]

    @property
    def uses_cross(self) -> bool:
        return any(k in ("cross", "selfcross") for k in self.cycle)

    @property
    def is_subquadratic(self) -> bool:
        """True when no block does *unwindowed* attention over the full
        sequence during prefill (SSM/SWA mixes count; a minority of
        'global' layers is allowed for decode-only long-context shapes)."""
        quad = {"attn", "moe", "cross", "selfcross", "enc_attn"}
        return not any(k in quad for k in self.cycle)

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        small = dict(
            n_layers=max(2, len(self.cycle)),
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv=min(self.n_kv, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.head_dim else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free capacity (cf >= E/K) so prefill==decode exactly in
            # consistency tests; production configs keep the real cf.
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            vision_tokens=min(self.vision_tokens, 16) if self.vision_tokens else 0,
            d_vision=min(self.d_vision, 64) if self.d_vision else 0,
            window=min(self.window, 16) if self.window else 0,
            attn_chunk=16,
            ssm_chunk=8,
            dtype=jnp.float32,
        )
        # keep n_kv dividing n_heads
        if small["n_heads"] % max(small["n_kv"], 1):
            small["n_kv"] = 1
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, d: int):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparam_ln":  # OLMo: LN without affine params
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        y = y * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm_type == "layernorm":
            y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def activation(cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(cfg.act)


def dense_init(key, d_in: int, d_out: int) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return scale * jax.random.normal(key, (d_in, d_out), jnp.float32)


# ---------------------------------------------------------------------------
# Quantized-resident dispatch
#
# Every matmul in transformer.py / attention.py / moe.py / model.py goes
# through one of these three helpers. A parameter leaf is either a plain
# float array (materialized path: cast + matmul, exactly the old code)
# or a live QuantizedTensor riding the PlaneStore accumulator, in which
# case eq. (5) is fused into the MXU feed via ops.dequant_matmul — the
# fp weight never exists outside a VMEM tile. Call sites never branch;
# this is the single dispatch point.
# ---------------------------------------------------------------------------

# Leaf basenames that are consumed exclusively through the dispatch
# helpers below and may therefore stay quantized in HBM. (Norm/gate
# vectors, conv kernels and recurrence matrices keep the materialized
# path — they're tiny and not matmul-shaped.)
QUANTIZED_RESIDENT_LEAVES = frozenset({
    "wq", "wk", "wv", "wo", "wi_gate", "wi_up",          # attention + GLU MLP
    "router", "we_gate", "we_up", "we_down",             # MoE
    "embed", "lm_head", "vision_proj",                   # I/O surfaces
    "in_proj", "out_proj", "up_proj", "down_proj",       # SSM/xLSTM projections
    "w_in", "w_if",
})


def leaf_basename(key) -> str:
    """Last component of a PlaneStore leaf key — a jax tree path tuple
    (pull-mode stores) or a 'a/b/c' path string (wire-fed stores)."""
    if isinstance(key, str):
        return key.rsplit("/", 1)[-1]
    last = key[-1]
    for attr in ("key", "idx", "name"):
        if hasattr(last, attr):
            return str(getattr(last, attr))
    return str(last)


def quantized_resident_eligible(key) -> bool:
    """The default ``eligible`` predicate for
    :meth:`~repro.core.plane_store.PlaneStore.quantized_leaves`."""
    return leaf_basename(key) in QUANTIZED_RESIDENT_LEAVES


def masked_q(w: QuantizedTensor, q: jax.Array | None = None,
             keep: jax.Array | None = None) -> jax.Array:
    """Apply a truncated view's deferred plane mask: keep only the top
    ``keep_bits`` bits of the accumulator, on the fly. The full-view
    ``keep_bits is None`` case is a structural no-op (no masking ops in
    the jaxpr), so the plain quantized-resident path is untouched. The
    mask runs inside the consuming jit — the masked uint is a transient
    fusion input, never a resident buffer."""
    q = w.q if q is None else q
    keep = w.keep_bits if keep is None else keep
    if keep is None:
        return q
    shift = (jnp.int32(w.bits) - keep.astype(jnp.int32)).astype(q.dtype)
    return (q >> shift) << shift


def dense(x: jax.Array, w, *, dtype) -> jax.Array:
    """``x @ w`` with ``w`` either a float array (cast to ``dtype``,
    plain matmul) or a QuantizedTensor (fused dequant-matmul; f32
    accumulation, output cast to ``dtype``). x: (..., K); w: (K, N)."""
    if isinstance(w, QuantizedTensor):
        lead = x.shape[:-1]
        y = ops.dequant_matmul(x.reshape(-1, x.shape[-1]), masked_q(w),
                               w.scale, w.offset)
        return _pin_replicated(y.reshape(*lead, w.q.shape[-1])).astype(dtype)
    return _pin_replicated(x @ w.astype(dtype))


def expert_dense(x: jax.Array, w, *, dtype) -> jax.Array:
    """Per-expert matmul ``einsum('becd,edf->becf')``. Quantized path:
    one fused dequant-matmul per expert (E is static and small), each
    fed its own (1, 1) affine slice — expert banks sliced per expert by
    the division policy keep their per-slice quantization ranges."""
    if isinstance(w, QuantizedTensor):
        B, E, C, d = x.shape
        outs = []
        for e in range(E):
            qe = masked_q(w, w.q[e],
                          None if w.keep_bits is None else w.keep_bits[e])
            ye = ops.dequant_matmul(x[:, e].reshape(B * C, d), qe,
                                    w.scale[e], w.offset[e])
            outs.append(ye.reshape(B, C, -1))
        return _pin_replicated(jnp.stack(outs, axis=1)).astype(dtype)
    return _pin_replicated(jnp.einsum("becd,edf->becf", x, w.astype(dtype)))


def embed_lookup(w, tokens: jax.Array) -> jax.Array:
    """Embedding-row gather. Quantized path gathers the *uint* rows and
    applies the eq.-(5) affine to just those rows — the fp table never
    materializes. Returns float32 rows (callers cast)."""
    if isinstance(w, QuantizedTensor):
        rows = masked_q(w, w.q[tokens]).astype(jnp.float32)
        return _pin_replicated(rows * w.scale.reshape(())
                               + w.offset.reshape(()))
    return _pin_replicated(w[tokens].astype(jnp.float32))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x
