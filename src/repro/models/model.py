"""Public model API: build_model(cfg) -> Model.

Model exposes exactly the entry points the launcher/dry-run need:

    init(key)                      -> params
    forward(params, batch)         -> full logits (small-scale debugging)
    loss(params, batch)            -> (scalar, metrics); chunked CE
    prefill(params, batch)         -> (last_logits, caches)
    prefill_chunk(params, caches, tokens, tok_pos) -> (logits, caches)
                                      (ragged chunked prefill into the
                                       pooled caches, slot-pool path)
    decode_step(params, caches, tokens, pos) -> (logits, caches)
    init_caches(batch, max_len)    -> zeroed cache pytree (eval_shape-safe)
    grow_caches(caches, max_len)   -> pad prefill caches for decoding

`batch` is a dict: {"tokens": (B,S) i32, "labels": (B,S) i32} plus, per
family, "enc_input" (audio frames, (B,S_enc,d)) or "vision_embeds"
((B, vision_tokens, d_vision)). Modality frontends are stubs per the
brief: input_specs() hands the backbone precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (ArchConfig, apply_norm, dense, embed_lookup,
                                 norm_init, dense_init, softcap)
from repro.models import transformer as tfm


def _embed_init(key, vocab: int, d: int) -> jax.Array:
    return 0.02 * jax.random.normal(key, (vocab, d), jnp.float32)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_stack, k_enc, k_extra, k_head = jax.random.split(key, 5)
        params: dict[str, Any] = {
            "embed": _embed_init(k_embed, cfg.vocab, cfg.d_model),
            "decoder": tfm.stack_init(cfg, k_stack),
            "final_norm": norm_init(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab)
        if cfg.enc_layers:
            enc_cfg = dataclasses.replace(
                cfg, cycle=("enc_attn",), n_layers=cfg.enc_layers
            )
            params["encoder"] = {
                "stack": tfm.stack_init(enc_cfg, k_enc),
                "final_norm": norm_init(cfg, cfg.d_model),
            }
        if cfg.vision_tokens:
            params["vision_proj"] = dense_init(k_extra, cfg.d_vision, cfg.d_model)
        if cfg.param_dtype != jnp.float32:
            params = jax.tree.map(
                lambda a: a.astype(cfg.param_dtype)
                if jnp.issubdtype(a.dtype, jnp.floating) else a,
                params,
            )
        return params

    # -- shared pieces -------------------------------------------------------
    def _encode(self, params, batch):
        """Run the modality encoder (or vision projector); returns the
        memory the decoder cross-attends to, or None."""
        cfg = self.cfg
        if cfg.enc_layers:
            enc_in = batch["enc_input"].astype(cfg.dtype)
            enc_cfg = dataclasses.replace(cfg, cycle=("enc_attn",), n_layers=cfg.enc_layers)
            x, _, _ = tfm.run_stack(enc_cfg, params["encoder"]["stack"], enc_in, mode="full")
            return apply_norm(cfg, params["encoder"]["final_norm"], x)
        if cfg.vision_tokens:
            v = batch["vision_embeds"].astype(cfg.dtype)
            return dense(v, params["vision_proj"], dtype=cfg.dtype)
        return None

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens).astype(cfg.dtype)
        return x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)

    def _unembed(self, params, x):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = dense(x.astype(jnp.float32), w, dtype=jnp.float32)
        return softcap(logits, cfg.logit_softcap)

    # -- entry points --------------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self._encode(params, batch)
        x = self._embed(params, batch["tokens"])
        x, _, aux = tfm.run_stack(cfg, params["decoder"], x, mode="full", enc_out=enc_out)
        x = apply_norm(cfg, params["final_norm"], x)
        return self._unembed(params, x), aux

    def loss(self, params, batch, *, ce_chunk: int = 512):
        """Chunked cross-entropy: never materializes (B, S, V)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch)
        x = self._embed(params, batch["tokens"])
        x, _, aux = tfm.run_stack(cfg, params["decoder"], x, mode="full", enc_out=enc_out)
        x = apply_norm(cfg, params["final_norm"], x)

        B, S, _ = x.shape
        labels = batch["labels"]
        C = min(ce_chunk, S)
        if cfg.costing:
            C = max(C, -(-S // 16))  # cap unrolled CE trips
        pad = (-S) % C
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        n_chunks = x.shape[1] // C
        xc = jnp.moveaxis(x.reshape(B, n_chunks, C, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(B, n_chunks, C), 1, 0)

        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(
            jnp.float32
        )

        def ce_chunk_fn(carry, xs):
            xx, ll = xs
            logits = softcap(xx.astype(jnp.float32) @ w, cfg.logit_softcap)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(ll, 0)[..., None], axis=-1
            )[..., 0]
            valid = (ll >= 0).astype(jnp.float32)
            nll = (logz - gold) * valid
            return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

        if cfg.costing:
            carry = (jnp.float32(0), jnp.float32(0))
            for c in range(n_chunks):
                carry, _ = ce_chunk_fn(carry, (xc[c], lc[c]))
            nll_sum, n_valid = carry
        else:
            (nll_sum, n_valid), _ = lax.scan(
                ce_chunk_fn, (jnp.float32(0), jnp.float32(0)), (xc, lc)
            )
        ce = nll_sum / jnp.maximum(n_valid, 1.0)
        total = ce + 0.01 * aux["balance_loss"]
        metrics = {"ce": ce, **aux}
        return total, metrics

    def prefill(self, params, batch, n_valid=None):
        """``n_valid=None``: every token is real, the returned logits
        are the last row's. ``n_valid`` (B,) int32: the prompt is
        bucket-padded to its static length and only the first
        ``n_valid[b]`` positions are real — padded positions are masked
        out of attention and the logits are gathered at row
        ``n_valid - 1``. All batch rows must share one valid length
        (the slot pool prefills at batch 1). Only plain-attention
        stacks support masked padding: a sliding-window ring has no
        masked slots and a recurrent state would consume the padding."""
        cfg = self.cfg
        if n_valid is not None:
            kinds = set(cfg.cycle) | set(cfg.tail)
            if kinds & {"swa", "swa_moe", "mamba2", "mlstm", "slstm"}:
                raise NotImplementedError(
                    "bucket-padded prefill needs position masking, which "
                    "sliding-window rings and recurrent states don't "
                    "support — admit at the exact prompt length instead")
        enc_out = self._encode(params, batch)
        x = self._embed(params, batch["tokens"])
        x, caches, _ = tfm.run_stack(
            cfg, params["decoder"], x, mode="prefill", enc_out=enc_out,
            pos=None if n_valid is None else jnp.asarray(n_valid, jnp.int32),
        )
        if n_valid is None:
            xl = x[:, -1:, :]
        else:
            idx = jnp.clip(jnp.asarray(n_valid, jnp.int32) - 1, 0,
                           x.shape[1] - 1)
            xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        xl = apply_norm(cfg, params["final_norm"], xl)
        return self._unembed(params, xl)[:, 0, :], caches

    def prefill_chunk(self, params, caches, tokens, tok_pos):
        """Ragged chunked prefill: consume a (B, C) block of prompt
        tokens straight into the POOLED caches, each slot at its own
        depth. ``tok_pos`` (B, C) int32 gives token (b, t)'s prompt
        position (slot b's chunk offset + t); negative marks a masked
        row — free/decoding slots riding the batched launch, or ragged
        padding past a short final chunk. Masked rows write nothing and
        read nothing (their cache rows stay byte-identical). Returns
        ``(logits (B, C, V), caches)``; logits[:, t] is the next-token
        distribution after consuming prompt position tok_pos[:, t] —
        the chunk holding a slot's LAST prompt token yields its first
        generated token at that row. This replaces the batch-1 prefill
        + grow_caches + per-leaf slot write of legacy admission: no
        cache-sized copy ever happens on the admit path."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        x, caches, _ = tfm.run_stack(
            cfg, params["decoder"], x, mode="prefill_chunk", caches=caches,
            pos=jnp.asarray(tok_pos, jnp.int32),
        )
        x = apply_norm(cfg, params["final_norm"], x)
        return self._unembed(params, x), caches

    def decode_step(self, params, caches, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32 (lock-stepped write
        position) or (B,) int32 per-slot positions (ragged continuous
        batching — each slot decodes at its own depth; negative marks a
        free pool slot whose output is meaningless)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        x, caches, _ = tfm.run_stack(
            cfg, params["decoder"], x, mode="decode", caches=caches, pos=pos
        )
        x = apply_norm(cfg, params["final_norm"], x)
        return self._unembed(params, x)[:, 0, :], caches

    def verify_step(self, params, caches, tokens, pos):
        """Speculative verify: score a whole draft block in one pass.

        tokens: (B, T) int32 — the last accepted token followed by the
        k = T-1 drafted continuations, per slot; pos: (B,) int32 base
        positions (token t of slot b sits at ``pos[b] + t``; negative
        marks a free pool slot whose rows stay fully masked). Returns
        ``(logits (B, T, V), caches)``: logits[:, t] is the target
        model's next-token distribution after consuming tokens[:, :t+1],
        exactly what t+1 sequential decode_step calls would produce —
        K/V for all T positions are written into the caches (rejected
        rows are *left in place* and simply overwritten by later
        rounds; the per-row causal mask keeps them invisible)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        x, caches, _ = tfm.run_stack(
            cfg, params["decoder"], x, mode="verify", caches=caches, pos=pos
        )
        x = apply_norm(cfg, params["final_norm"], x)
        return self._unembed(params, x), caches

    # -- caches ----------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, *, ring_margin: int = 0):
        cfg = self.cfg
        enc_len = self.enc_len(max_len)
        return tfm.stack_init_caches(cfg, batch, max_len, enc_len,
                                     ring_margin)

    def enc_len(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.enc_layers:
            return max(1, seq_len // cfg.enc_seq_divisor)
        if cfg.vision_tokens:
            return cfg.vision_tokens
        return 0

    def grow_caches(self, caches, max_len: int, *, ring_margin: int = 0,
                    pos: int = 0):
        """Pad prefill-produced full-attention caches along the sequence
        axis so decode_step can write up to max_len. With
        ``ring_margin > 0`` sliding-window ring caches are additionally
        repacked to ``window + ring_margin`` slots (``pos`` = tokens
        consumed so far, i.e. the prompt length) so speculative verify
        blocks up to ``ring_margin`` tokens long never clobber live
        window entries."""
        cfg = self.cfg
        from repro.models.attention import grow_ring_cache

        def grow_slot(kind: str, c, stacked: bool):
            if c is None:
                return None
            if kind == "selfcross":
                return {"self": _pad_kv(c["self"], max_len, stacked), "cross": c["cross"]}
            if kind in ("attn", "global", "moe", "shared_attn"):
                return _pad_kv(c, max_len, stacked)
            if kind in ("swa", "swa_moe") and ring_margin and cfg.window:
                return grow_ring_cache(c, cfg.window + ring_margin, pos)
            return c  # swa ring / ssm states / cross are already final-size

        out = {"cycles": {}, "tail": {}}
        for j, kind in enumerate(cfg.cycle):
            slot = f"{j}_{kind}"
            if caches["cycles"] is not None and slot in caches["cycles"]:
                out["cycles"][slot] = grow_slot(kind, caches["cycles"][slot], True)
        for i, kind in enumerate(cfg.tail):
            slot = f"{i}_{kind}"
            if slot in caches["tail"]:
                out["tail"][slot] = grow_slot(kind, caches["tail"][slot], False)
        return out

    # -- dry-run inputs ----------------------------------------------------------
    def input_specs(self, *, batch: int, seq_len: int, mode: str):
        """ShapeDtypeStruct stand-ins for every model input (no
        allocation). mode: train | prefill | decode."""
        cfg = self.cfg
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if mode == "train":
            specs = {
                "tokens": sds((batch, seq_len), i32),
                "labels": sds((batch, seq_len), i32),
            }
        elif mode == "prefill":
            specs = {"tokens": sds((batch, seq_len), i32)}
        elif mode == "decode":
            specs = {"tokens": sds((batch, 1), i32)}
        else:
            raise ValueError(mode)
        if mode != "decode":
            if cfg.enc_layers:
                specs["enc_input"] = sds(
                    (batch, max(1, seq_len // cfg.enc_seq_divisor), cfg.d_model),
                    cfg.dtype,
                )
            if cfg.vision_tokens:
                specs["vision_embeds"] = sds(
                    (batch, cfg.vision_tokens, cfg.d_vision), cfg.dtype
                )
        return specs


def _pad_kv(c, max_len: int, stacked: bool):
    # native layout: (B, K, S, hd) / stacked (R, B, K, S, hd)
    ax = 3 if stacked else 2
    S = c["k"].shape[ax]
    if S >= max_len:
        return c
    pad = [(0, 0)] * c["k"].ndim
    pad[ax] = (0, max_len - S)
    return {"k": jnp.pad(c["k"], pad), "v": jnp.pad(c["v"], pad)}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
