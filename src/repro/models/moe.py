"""Mixture-of-Experts FFN: token-choice top-k routing with capacity.

Dispatch is the dense-dispatch formulation (one-hot dispatch/combine
tensors), the standard JAX MoE layout: expert compute is
(E, capacity) tokens, so HLO FLOPs reflect *active* expert compute
(≈ top_k/E of dense-all-experts), which is what the roofline needs.
Experts shard over the `model` mesh axis; the dispatch einsum then lowers
to an all-to-all over that axis in the compiled collective schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, activation, dense, dense_init,
                                 expert_dense)


def moe_init(cfg: ArchConfig, key):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in = (2.0 / (d + f)) ** 0.5
    s_out = (2.0 / (d + f)) ** 0.5
    return {
        "router": dense_init(k0, d, E),
        "we_gate": s_in * jax.random.normal(k1, (E, d, f), jnp.float32),
        "we_up": s_in * jax.random.normal(k2, (E, d, f), jnp.float32),
        "we_down": s_out * jax.random.normal(k3, (E, f, d), jnp.float32),
    }


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(cap, cfg.top_k)


def moe_apply(cfg: ArchConfig, p, x: jax.Array):
    """x: (B, T, d) -> (y, aux) where aux carries the load-balance loss
    terms (mean router entropy + switch-style balance loss)."""
    dt = cfg.dtype
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, T)

    logits = dense(x, p["router"], dtype=dt).astype(jnp.float32)  # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B,T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) in its expert's buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,T,K,E)
    flat = onehot.reshape(B, T * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (B,T*K,E)
    pos = (pos_in_expert * flat).sum(-1).reshape(B, T, K)  # (B,T,K)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch: (B, T, E, C) one-hot; combine: weighted
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=jnp.float32)[..., :C]
    dispatch = jnp.einsum("btke,btkc->btec", onehot, pos_oh)  # (B,T,E,C)
    combine = jnp.einsum("btke,btkc,btk->btec", onehot, pos_oh, gate_vals)

    xin = jnp.einsum("btec,btd->becd", dispatch.astype(dt), x)  # (B,E,C,d)
    h = activation(cfg, expert_dense(xin, p["we_gate"], dtype=dt))
    h = h * expert_dense(xin, p["we_up"], dtype=dt)
    out = expert_dense(h, p["we_down"], dtype=dt)
    y = jnp.einsum("btec,becd->btd", combine.astype(dt), out)

    # Switch-transformer load-balance loss
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    ce = onehot.sum(2).reshape(B * T, E).mean(0)  # fraction routed per expert
    balance_loss = E * jnp.sum(me * ce)
    dropped = 1.0 - keep.mean()
    return y, {"balance_loss": balance_loss, "dropped_frac": dropped}
