"""Recurrent blocks: Mamba-2 (SSD, chunked), xLSTM mLSTM and sLSTM.

TPU adaptation notes (see DESIGN.md §7):
* Mamba-2 runs in its chunked SSD form — intra-chunk work is a masked
  matmul (MXU-friendly), inter-chunk state passing is a `lax.scan` over
  chunk summaries. Mathematically identical to the step recurrence
  (property-tested against it).
* mLSTM/sLSTM run as `lax.scan` step recurrences (one HLO body regardless
  of sequence length). A chunked mLSTM is a recorded hillclimb candidate.
* Decode paths are single-step recurrences; state is the "KV cache" of
  these blocks and is O(1) in sequence length — which is why the SSM and
  hybrid architectures take the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import ArchConfig, dense, dense_init

# ---------------------------------------------------------------------------
# Mamba-2
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads if cfg.ssm_heads else max(1, d_inner // 64)
    hd = d_inner // H
    return d_inner, H, hd, cfg.ssm_state


def mamba2_init(cfg: ArchConfig, key):
    d_inner, H, hd, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # [z, x, B, C, dt] fused input projection
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * d_inner + 2 * N + H),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_width, d_inner), jnp.float32),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, cfg.d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, T, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _split_proj(cfg: ArchConfig, p, u: jax.Array):
    d_inner, H, hd, N = mamba2_dims(cfg)
    zxbcdt = dense(u, p["in_proj"], dtype=u.dtype)
    z, x, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, x, Bm, Cm, dt


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    out = gf * lax.rsqrt(jnp.mean(gf * gf, axis=-1, keepdims=True) + 1e-6) * scale
    return out.astype(y.dtype)


def mamba2_forward(cfg: ArchConfig, p, u: jax.Array, state=None, return_state=False):
    """Chunked SSD scan. u: (B, T, d_model) -> (B, T, d_model)."""
    d_inner, H, hd, N = mamba2_dims(cfg)
    B_, T, _ = u.shape
    dtype = u.dtype
    z, x, Bm, Cm, dt_raw = _split_proj(cfg, p, u)
    x = jax.nn.silu(_causal_conv(x, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype)))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a_log = -dt * jnp.exp(p["A_log"])  # log decay, (B,T,H)

    L = min(cfg.ssm_chunk, T)
    if cfg.costing:
        # unrolled below; cap trips at 16 (chunk size does not change FLOPs)
        L = max(L, -(-T // 16))
    pad = (-T) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))  # pad a=1? log a=0 -> pad ok
    Tp = T + pad
    nC = Tp // L

    xh = x.reshape(B_, nC, L, H, hd)
    Bc = Bm.reshape(B_, nC, L, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nC, L, N).astype(jnp.float32)
    dtc = dt.reshape(B_, nC, L, H)
    alc = a_log.reshape(B_, nC, L, H)

    def chunk_body(S, xs):
        xk, Bk, Ck, dtk, alk = xs  # (B,L,...)
        cum = jnp.cumsum(alk, axis=1)  # (B,L,H) inclusive
        # intra-chunk: masked decay matmul
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H)
        ii = jnp.arange(L)
        mask = ii[:, None] >= ii[None, :]
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bin,bjn->bij", Ck, Bk)
        W = CB[..., None] * decay * dtk[:, None, :, :]  # (B,i,j,H)
        y = jnp.einsum("bijh,bjhd->bihd", W, xk.astype(jnp.float32))
        # inter-chunk: contribution of incoming state
        y = y + jnp.einsum("bin,bih,bhnd->bihd", Ck, jnp.exp(cum), S)
        # state update
        rem = jnp.exp(cum[:, -1:, :] - cum)  # decay from j to chunk end
        S_new = jnp.exp(cum[:, -1])[:, :, None, None] * S + jnp.einsum(
            "bjh,bjn,bjhd->bhnd", dtk * rem, Bk, xk.astype(jnp.float32)
        )
        return S_new, y

    S0 = (
        jnp.zeros((B_, H, N, hd), jnp.float32)
        if state is None
        else state.astype(jnp.float32)
    )
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bc, Cc, dtc, alc))
    if cfg.costing:
        S_fin, ys_l = S0, []
        for c in range(nC):
            S_fin, y_c = chunk_body(S_fin, tuple(t[c] for t in xs))
            ys_l.append(y_c)
        ys = jnp.stack(ys_l)
    else:
        S_fin, ys = lax.scan(chunk_body, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, Tp, H, hd)[:, :T]
    y = y + x[:, :T].reshape(B_, T, H, hd).astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, d_inner).astype(dtype)
    out = dense(_gated_norm(y, z, p["out_norm"]), p["out_proj"], dtype=dtype)
    if return_state:
        return out, S_fin
    return out


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype):
    d_inner, H, hd, N = mamba2_dims(cfg)
    return {
        "state": jnp.zeros((batch, H, N, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_inner), dtype),
    }


def mamba2_prefill(cfg: ArchConfig, p, u: jax.Array):
    """Full forward + final recurrent state as cache."""
    d_inner, H, hd, N = mamba2_dims(cfg)
    out, S = mamba2_forward(cfg, p, u, return_state=True)
    # conv cache: last (W-1) pre-conv x values
    _, x, *_ = _split_proj(cfg, p, u)
    Wc = cfg.conv_width
    conv_cache = x[:, -(Wc - 1) :, :]
    pad = Wc - 1 - conv_cache.shape[1]
    if pad > 0:
        conv_cache = jnp.pad(conv_cache, ((0, 0), (pad, 0), (0, 0)))
    return out, {"state": S, "conv": conv_cache}


def mamba2_step(cfg: ArchConfig, p, u: jax.Array, cache):
    """Single-token decode. u: (B, 1, d_model)."""
    d_inner, H, hd, N = mamba2_dims(cfg)
    dtype = u.dtype
    z, x, Bm, Cm, dt_raw = _split_proj(cfg, p, u)  # (B,1,·)
    conv_in = jnp.concatenate([cache["conv"], x], axis=1)  # (B, W, d_inner)
    w = p["conv_w"].astype(dtype)
    xc = jax.nn.silu((conv_in * w[None, :, :]).sum(axis=1, keepdims=True) + p["conv_b"].astype(dtype))
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))  # (B,H)
    xh = xc[:, 0].reshape(-1, H, hd).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    S = cache["state"]
    S = a[:, :, None, None] * S + jnp.einsum(
        "bh,bn,bhd->bhnd", dt, Bv, xh
    )
    y = jnp.einsum("bn,bhnd->bhd", Cv, S) + xh * p["D"][None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(dtype)
    out = dense(_gated_norm(y, z, p["out_norm"]), p["out_proj"], dtype=dtype)
    return out, {"state": S, "conv": conv_in[:, 1:, :]}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ArchConfig):
    d_inner = int(cfg.lstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    return d_inner, H, d_inner // H


def mlstm_init(cfg: ArchConfig, key):
    d_inner, H, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], cfg.d_model, 2 * d_inner),
        "wq": dense_init(ks[1], d_inner, d_inner),
        "wk": dense_init(ks[2], d_inner, d_inner),
        "wv": dense_init(ks[3], d_inner, d_inner),
        "w_if": dense_init(ks[4], d_inner, 2 * H),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "down_proj": dense_init(ks[5], d_inner, cfg.d_model),
    }


def _mlstm_qkvif(cfg, p, u):
    d_inner, H, hd = mlstm_dims(cfg)
    dt = u.dtype
    xz = dense(u, p["up_proj"], dtype=dt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    B_, T, _ = x_in.shape
    q = dense(x_in, p["wq"], dtype=dt).reshape(B_, T, H, hd)
    k = dense(x_in, p["wk"], dtype=dt).reshape(B_, T, H, hd) * (hd**-0.5)
    v = dense(x_in, p["wv"], dtype=dt).reshape(B_, T, H, hd)
    i_f = dense(x_in, p["w_if"], dtype=dt).astype(jnp.float32) + p["b_if"]
    i_raw, f_raw = jnp.split(i_f, 2, axis=-1)  # (B,T,H)
    return x_in, z, q, k, v, i_raw, f_raw


def _mlstm_cell(carry, xs):
    """Stabilized mLSTM step. carry: (C, n, m)."""
    C, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
    q, k, v, i_raw, f_raw = xs  # (B,H,hd) x3, (B,H) x2
    f_log = -jax.nn.softplus(-f_raw)  # log sigmoid(f)
    m_new = jnp.maximum(f_log + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    C_new = f_g[..., None, None] * C + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n_new = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), jnp.exp(-m_new))
    h = jnp.einsum("bhvd,bhd->bhv", C_new, q) / denom[..., None]
    return (C_new, n_new, m_new), h


def mlstm_forward(cfg: ArchConfig, p, u: jax.Array, cache=None, return_cache=False):
    d_inner, H, hd = mlstm_dims(cfg)
    B_, T, _ = u.shape
    x_in, z, q, k, v, i_raw, f_raw = _mlstm_qkvif(cfg, p, u)
    if cache is None:
        C0 = jnp.zeros((B_, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B_, H, hd), jnp.float32)
        m0 = jnp.zeros((B_, H), jnp.float32)
    else:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
    xs = (
        jnp.moveaxis(q.astype(jnp.float32), 1, 0),
        jnp.moveaxis(k.astype(jnp.float32), 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(i_raw, 1, 0),
        jnp.moveaxis(f_raw, 1, 0),
    )
    (C, n, m), hs = lax.scan(_mlstm_cell, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, T, d_inner).astype(u.dtype)
    from repro.models.ssm import _gated_norm  # self-import for clarity

    out = dense(_gated_norm(h, z, p["out_norm"]), p["down_proj"], dtype=u.dtype)
    if return_cache:
        return out, {"C": C, "n": n, "m": m}
    return out


def mlstm_init_cache(cfg: ArchConfig, batch: int, dtype):
    d_inner, H, hd = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_step(cfg: ArchConfig, p, u: jax.Array, cache):
    out, new_cache = mlstm_forward(cfg, p, u, cache=cache, return_cache=True)
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory, per-head recurrent mixing)
# ---------------------------------------------------------------------------

def slstm_dims(cfg: ArchConfig):
    d_inner = cfg.d_model  # sLSTM operates at model width
    H = cfg.n_heads
    return d_inner, H, d_inner // H


def slstm_init(cfg: ArchConfig, key):
    d_inner, H, hd = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], cfg.d_model, 4 * d_inner),  # z i f o
        "r": 0.1 * jax.random.normal(ks[1], (H, hd, 4 * hd), jnp.float32),
        "b": jnp.zeros((4 * d_inner,), jnp.float32),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "up_proj": dense_init(ks[2], d_inner, int(4 * d_inner / 3)),
        "down_proj": dense_init(ks[3], int(4 * d_inner / 3), cfg.d_model),
    }


def _slstm_cell(p_r, carry, x_t):
    """x_t: (B, 4*d_inner) pre-activations from input; recurrent term
    added from h via block-diagonal per-head R."""
    c, n, h, m = carry  # (B,d_inner) x3, (B,H)
    B_ = h.shape[0]
    H, hd, _ = p_r.shape
    hh = h.reshape(B_, H, hd)
    rec = jnp.einsum("bhd,hdf->bhf", hh, p_r).reshape(B_, 4 * H * hd)
    z_r, i_r, f_r, o_r = jnp.split(x_t + rec, 4, axis=-1)
    zh = jnp.tanh(z_r)
    oh = jax.nn.sigmoid(o_r)
    i_rh = i_r.reshape(B_, H, hd)
    f_rh = f_r.reshape(B_, H, hd)
    f_log = -jax.nn.softplus(-f_rh)
    m_new = jnp.maximum(f_log.max(-1) + m, i_rh.max(-1))  # per-head stabilizer
    i_g = jnp.exp(i_rh - m_new[..., None]).reshape(B_, -1)
    f_g = jnp.exp(f_log + (m - m_new)[..., None]).reshape(B_, -1)
    c_new = f_g * c + i_g * zh
    n_new = f_g * n + i_g
    h_new = oh * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(cfg: ArchConfig, p, u: jax.Array, cache=None, return_cache=False):
    d_inner, H, hd = slstm_dims(cfg)
    B_, T, _ = u.shape
    x_pre = dense(u, p["w_in"], dtype=u.dtype).astype(jnp.float32) + p["b"]
    if cache is None:
        zeros = jnp.zeros((B_, d_inner), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.zeros((B_, H), jnp.float32))
    else:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    cell = lambda cr, xt: _slstm_cell(p["r"], cr, xt)
    (c, n, h, m), hs = lax.scan(cell, carry, jnp.moveaxis(x_pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(u.dtype)  # (B,T,d_inner)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["out_norm"]).astype(u.dtype)
    out = dense(jax.nn.gelu(dense(y, p["up_proj"], dtype=u.dtype)),
                p["down_proj"], dtype=u.dtype)
    if return_cache:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out


def slstm_init_cache(cfg: ArchConfig, batch: int, dtype):
    d_inner, H, hd = slstm_dims(cfg)
    z = jnp.zeros((batch, d_inner), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, H), jnp.float32)}


def slstm_step(cfg: ArchConfig, p, u: jax.Array, cache):
    out, new_cache = slstm_forward(cfg, p, u, cache=cache, return_cache=True)
    return out, new_cache
