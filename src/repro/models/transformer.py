"""Pattern-scan stack executor.

A model is a repeating cycle of block kinds (cfg.cycle) executed
``n_cycles`` times under ``lax.scan`` with parameters stacked over the
cycle dimension, plus an unrolled tail for the remainder layers. HLO size
is therefore O(len(cycle)), not O(n_layers) — a 100-layer model compiles
as fast as a 5-layer one, which is what makes 80 dry-run compiles
feasible (and is just good practice on real TPUs too).

Caches mirror the parameter layout: one stacked pytree per cycle slot
plus per-tail-block pytrees. ``shared_attn`` blocks (Zamba2) read their
weights from a single non-stacked store and only their caches are
per-occurrence.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.common import ArchConfig, apply_norm, dense, norm_init, dense_init

ZERO_AUX = lambda: {"balance_loss": jnp.float32(0.0), "dropped_frac": jnp.float32(0.0)}

ATTN_KINDS = {"attn", "swa", "global", "moe", "swa_moe", "shared_attn", "enc_attn"}


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def block_init(cfg: ArchConfig, key, kind: str):
    ks = jax.random.split(key, 4)
    if kind in ("attn", "swa", "global", "shared_attn", "enc_attn"):
        return {
            "norm1": norm_init(cfg, cfg.d_model),
            "attn": attn.attn_init(cfg, ks[0]),
            "norm2": norm_init(cfg, cfg.d_model),
            "mlp": attn.mlp_init(cfg, ks[1]),
        }
    if kind in ("moe", "swa_moe"):
        return {
            "norm1": norm_init(cfg, cfg.d_model),
            "attn": attn.attn_init(cfg, ks[0]),
            "norm2": norm_init(cfg, cfg.d_model),
            "moe": moe_mod.moe_init(cfg, ks[1]),
        }
    if kind == "cross":
        return {
            "norm1": norm_init(cfg, cfg.d_model),
            "attn": attn.attn_init(cfg, ks[0], cross=True),
            "gate_attn": jnp.zeros((), jnp.float32),
            "norm2": norm_init(cfg, cfg.d_model),
            "mlp": attn.mlp_init(cfg, ks[1]),
            "gate_mlp": jnp.zeros((), jnp.float32),
        }
    if kind == "selfcross":
        return {
            "norm1": norm_init(cfg, cfg.d_model),
            "self_attn": attn.attn_init(cfg, ks[0]),
            "norm_x": norm_init(cfg, cfg.d_model),
            "cross_attn": attn.attn_init(cfg, ks[1], cross=True),
            "norm2": norm_init(cfg, cfg.d_model),
            "mlp": attn.mlp_init(cfg, ks[2]),
        }
    if kind == "mamba2":
        return {"norm1": norm_init(cfg, cfg.d_model), "mixer": ssm.mamba2_init(cfg, ks[0])}
    if kind == "mlstm":
        return {"norm1": norm_init(cfg, cfg.d_model), "mixer": ssm.mlstm_init(cfg, ks[0])}
    if kind == "slstm":
        return {"norm1": norm_init(cfg, cfg.d_model), "mixer": ssm.slstm_init(cfg, ks[0])}
    raise ValueError(f"unknown block kind {kind}")


def _attn_window(cfg: ArchConfig, kind: str) -> int:
    if kind in ("swa", "swa_moe"):
        return cfg.window
    return 0


def _attn_theta(cfg: ArchConfig, kind: str) -> float:
    # gemma3-style: global layers use a larger rope base
    if kind == "global":
        return getattr(cfg, "rope_theta", 1e4) * 100.0
    return cfg.rope_theta


def block_apply(cfg: ArchConfig, kind: str, p, x, *, mode: str, cache, pos, enc_out):
    """Returns (x, new_cache, aux)."""
    aux = ZERO_AUX()
    if kind in ("attn", "swa", "global", "shared_attn", "enc_attn"):
        h = apply_norm(cfg, p["norm1"], x)
        if kind == "enc_attn":
            q, k, v = attn.project_qkv(cfg, p["attn"], h, h)
            T = h.shape[1]
            qpos = jnp.arange(T, dtype=jnp.int32)
            q = attn.rope(q, qpos, cfg.rope_theta)
            k = attn.rope(k, qpos, cfg.rope_theta)
            o = attn.chunked_attention(
                q, k, v, qpos, qpos, causal=False, window=0,
                chunk=cfg.attn_chunk, unroll=cfg.costing,
            )
            a_out = dense(o.reshape(*h.shape[:2], -1), p["attn"]["wo"],
                          dtype=cfg.dtype)
            new_cache = None
        else:
            a_out, new_cache = attn.self_attention(
                cfg,
                p["attn"],
                h,
                mode=mode,
                window=_attn_window(cfg, kind),
                cache=cache,
                pos=pos,
                rope_theta=_attn_theta(cfg, kind),
            )
        x = x + a_out
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + attn.mlp_apply(cfg, p["mlp"], h2)
        return x, new_cache, aux

    if kind in ("moe", "swa_moe"):
        h = apply_norm(cfg, p["norm1"], x)
        a_out, new_cache = attn.self_attention(
            cfg,
            p["attn"],
            h,
            mode=mode,
            window=_attn_window(cfg, kind),
            cache=cache,
            pos=pos,
        )
        x = x + a_out
        h2 = apply_norm(cfg, p["norm2"], x)
        m_out, moe_aux = moe_mod.moe_apply(cfg, p["moe"], h2)
        x = x + m_out
        aux = {k: aux[k] + jnp.float32(moe_aux[k]) for k in aux}
        return x, new_cache, aux

    if kind in ("cross", "selfcross") and mode == "prefill_chunk":
        # the vision/enc cross memory is produced by the admission-time
        # encoder pass, which a mid-stream chunk step doesn't have; the
        # slot pool falls back to batch-1 admission for these archs
        raise NotImplementedError(
            f"chunked prefill is not supported for {kind} blocks")

    if kind == "cross":
        h = apply_norm(cfg, p["norm1"], x)
        if mode in ("decode", "verify"):
            # cache holds the native (B, K, Tv, hd) layout, static
            a_out = attn.cross_attention(cfg, p["attn"], h, cache, native=True)
            new_cache = cache
        else:
            kv = attn.cross_kv(cfg, p["attn"], enc_out)
            new_cache = attn.to_native_kv(kv) if mode == "prefill" else None
            a_out = attn.cross_attention(cfg, p["attn"], h, kv)
        x = x + jnp.tanh(p["gate_attn"]).astype(cfg.dtype) * a_out
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + jnp.tanh(p["gate_mlp"]).astype(cfg.dtype) * attn.mlp_apply(cfg, p["mlp"], h2)
        return x, new_cache, aux

    if kind == "selfcross":
        h = apply_norm(cfg, p["norm1"], x)
        self_cache = cache["self"] if cache is not None else None
        a_out, new_self = attn.self_attention(
            cfg, p["self_attn"], h, mode=mode, window=0, cache=self_cache, pos=pos
        )
        x = x + a_out
        hx = apply_norm(cfg, p["norm_x"], x)
        if mode in ("decode", "verify"):
            new_cross = cache["cross"]  # native layout, static
            x = x + attn.cross_attention(cfg, p["cross_attn"], hx,
                                         cache["cross"], native=True)
        else:
            kv = attn.cross_kv(cfg, p["cross_attn"], enc_out)
            new_cross = attn.to_native_kv(kv) if mode == "prefill" else None
            x = x + attn.cross_attention(cfg, p["cross_attn"], hx, kv)
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + attn.mlp_apply(cfg, p["mlp"], h2)
        new_cache = None
        if mode in ("prefill", "decode", "verify"):
            new_cache = {"self": new_self, "cross": new_cross}
        return x, new_cache, aux

    if kind in ("mamba2", "mlstm", "slstm"):
        if mode == "verify":
            # an SSM/recurrent state is cumulative: a rejected draft
            # can't be "overwritten", it would need a state snapshot per
            # draft token — the opposite of the zero-copy KV story
            raise NotImplementedError(
                f"speculative verify is not supported for {kind} blocks "
                f"(recurrent state has no overwrite-only rollback)")
        if mode == "prefill_chunk":
            # Unlike KV caches there is no positional indexing to hide
            # behind: the chunk is consumed token-by-token through the
            # single-step recurrence, with a per-token live mask so
            # masked rows (free/decoding slots, ragged padding) leave
            # the slot's state byte-identical. The chunk length is
            # small and static, so the unrolled loop stays cheap and
            # the executable count stays one per chunk shape.
            step = {"mamba2": ssm.mamba2_step, "mlstm": ssm.mlstm_step,
                    "slstm": ssm.slstm_step}[kind]
            h = apply_norm(cfg, p["norm1"], x)
            c = cache
            outs = []
            for t in range(h.shape[1]):
                o_t, c_new = step(cfg, p["mixer"], h[:, t:t + 1], c)
                c = _mask_recurrent(c_new, c, pos[:, t])
                outs.append(o_t)
            return x + jnp.concatenate(outs, axis=1), c, aux

    if kind == "mamba2":
        h = apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            out = ssm.mamba2_forward(cfg, p["mixer"], h)
            new_cache = None
        elif mode == "prefill":
            out, new_cache = ssm.mamba2_prefill(cfg, p["mixer"], h)
        else:
            out, new_cache = ssm.mamba2_step(cfg, p["mixer"], h, cache)
            if pos is not None:
                new_cache = _mask_recurrent(
                    new_cache, cache, attn.decode_pos_vector(pos, x.shape[0]))
        return x + out, new_cache, aux

    if kind in ("mlstm", "slstm"):
        fwd = ssm.mlstm_forward if kind == "mlstm" else ssm.slstm_forward
        step = ssm.mlstm_step if kind == "mlstm" else ssm.slstm_step
        h = apply_norm(cfg, p["norm1"], x)
        if mode == "full":
            out = fwd(cfg, p["mixer"], h)
            new_cache = None
        elif mode == "prefill":
            out, new_cache = fwd(cfg, p["mixer"], h, return_cache=True)
        else:
            out, new_cache = step(cfg, p["mixer"], h, cache)
            if pos is not None:
                new_cache = _mask_recurrent(
                    new_cache, cache, attn.decode_pos_vector(pos, x.shape[0]))
        return x + out, new_cache, aux

    raise ValueError(f"unknown block kind {kind}")


def _mask_recurrent(new_cache, cache, pos_vec):
    """Per-slot no-op for a recurrent state update: slots whose position
    is negative (free pool slots, mid-chunked-prefill slots riding a
    batched decode step, ragged chunk padding) keep their old state
    byte-identical. Every recurrent cache leaf is batch-first, so one
    broadcasted ``where`` per leaf suffices — unlike KV writes there is
    no positional clamp to make a masked write land harmlessly."""
    live = pos_vec >= 0
    return jax.tree.map(
        lambda new, old: jnp.where(
            live.reshape((-1,) + (1,) * (new.ndim - 1)),
            new, old.astype(new.dtype)),
        new_cache, cache)


# ---------------------------------------------------------------------------
# Cache construction (shape-only safe: works under jax.eval_shape)
# ---------------------------------------------------------------------------

def block_init_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     enc_len: int, ring_margin: int = 0):
    # KV caches use the decode kernel's native (B, K, S, hd) layout so
    # the per-token hot loop never transposes or pads the cache.
    # ring_margin over-allocates sliding-window rings beyond the
    # attention window so speculative verify blocks can write k+1
    # positions ahead without clobbering live window entries.
    dt = cfg.dtype
    if kind in ("attn", "global", "moe", "shared_attn"):
        return {
            "k": jnp.zeros((batch, cfg.n_kv, max_len, cfg.hd), dt),
            "v": jnp.zeros((batch, cfg.n_kv, max_len, cfg.hd), dt),
        }
    if kind in ("swa", "swa_moe"):
        # ring buffer size; margin only matters for real windows
        W = cfg.window + ring_margin if cfg.window else max_len
        return {
            "k": jnp.zeros((batch, cfg.n_kv, W, cfg.hd), dt),
            "v": jnp.zeros((batch, cfg.n_kv, W, cfg.hd), dt),
        }
    if kind == "cross":
        return {
            "k": jnp.zeros((batch, cfg.n_kv, enc_len, cfg.hd), dt),
            "v": jnp.zeros((batch, cfg.n_kv, enc_len, cfg.hd), dt),
        }
    if kind == "selfcross":
        return {
            "self": {
                "k": jnp.zeros((batch, cfg.n_kv, max_len, cfg.hd), dt),
                "v": jnp.zeros((batch, cfg.n_kv, max_len, cfg.hd), dt),
            },
            "cross": {
                "k": jnp.zeros((batch, cfg.n_kv, enc_len, cfg.hd), dt),
                "v": jnp.zeros((batch, cfg.n_kv, enc_len, cfg.hd), dt),
            },
        }
    if kind == "mamba2":
        return ssm.mamba2_init_cache(cfg, batch, dt)
    if kind == "mlstm":
        return ssm.mlstm_init_cache(cfg, batch, dt)
    if kind == "slstm":
        return ssm.slstm_init_cache(cfg, batch, dt)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack init / run
# ---------------------------------------------------------------------------

def _stacked_init(cfg: ArchConfig, key, kind: str, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(cfg, k, kind))(keys)


def stack_init(cfg: ArchConfig, key) -> dict:
    """Params for the decoder stack (cycles + tail + shared)."""
    out: dict[str, Any] = {"cycles": {}, "tail": {}}
    n_slots = len(cfg.cycle)
    keys = jax.random.split(key, n_slots + len(cfg.tail) + 1)
    for j, kind in enumerate(cfg.cycle):
        if kind == "shared_attn":
            continue
        out["cycles"][f"{j}_{kind}"] = _stacked_init(cfg, keys[j], kind, cfg.n_cycles)
    for i, kind in enumerate(cfg.tail):
        if kind == "shared_attn":
            continue
        out["tail"][f"{i}_{kind}"] = block_init(cfg, keys[n_slots + i], kind)
    if "shared_attn" in cfg.cycle + cfg.tail:
        out["shared"] = block_init(cfg, keys[-1], "shared_attn")
    return out


def stack_init_caches(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int = 0, ring_margin: int = 0):
    caches: dict[str, Any] = {"cycles": {}, "tail": {}}
    for j, kind in enumerate(cfg.cycle):
        one = block_init_cache(cfg, kind, batch, max_len, enc_len, ring_margin)
        caches["cycles"][f"{j}_{kind}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_cycles,) + a.shape), one
        )
    for i, kind in enumerate(cfg.tail):
        caches["tail"][f"{i}_{kind}"] = block_init_cache(
            cfg, kind, batch, max_len, enc_len, ring_margin)
    return caches


def run_stack(
    cfg: ArchConfig,
    params: dict,
    x: jax.Array,
    *,
    mode: str,  # full | prefill | prefill_chunk | verify | decode
    caches=None,
    pos=None,
    enc_out=None,
):
    """Returns (x, new_caches, aux)."""
    cycle = cfg.cycle
    aux0 = ZERO_AUX()
    shared = params.get("shared")

    def cycle_body(carry, xs):
        x, aux = carry
        cyc_params, cyc_caches = xs
        new_caches = {}
        for j, kind in enumerate(cycle):
            slot = f"{j}_{kind}"
            p = shared if kind == "shared_attn" else cyc_params[slot]
            c = cyc_caches[slot] if cyc_caches is not None else None
            x, nc, a = block_apply(
                cfg, kind, p, x, mode=mode, cache=c, pos=pos, enc_out=enc_out
            )
            if nc is not None:
                new_caches[slot] = nc
            aux = {k: aux[k] + a[k] for k in aux}
        return (x, aux), new_caches if new_caches else None

    if cfg.n_cycles > 0:
        cyc_caches = caches["cycles"] if caches is not None else None
        xs = (params["cycles"], cyc_caches)
        body = cycle_body
        if mode == "full" and cfg.remat:
            body = jax.checkpoint(cycle_body, prevent_cse=False)
        if cfg.costing:
            # unrolled for cost_analysis fidelity (see ArchConfig.costing)
            carry = (x, aux0)
            per_cycle = []
            for r in range(cfg.n_cycles):
                xs_r = jax.tree.map(lambda a: a[r], xs)
                carry, y_r = body(carry, xs_r)
                per_cycle.append(y_r)
            (x, aux) = carry
            ys = (
                jax.tree.map(lambda *zs: jnp.stack(zs), *per_cycle)
                if per_cycle[0] is not None
                else None
            )
        else:
            (x, aux), ys = lax.scan(body, (x, aux0), xs)
        new_caches = {"cycles": ys, "tail": {}}
    else:
        aux = aux0
        new_caches = {"cycles": None, "tail": {}}

    for i, kind in enumerate(cfg.tail):
        slot = f"{i}_{kind}"
        p = shared if kind == "shared_attn" else params["tail"][slot]
        c = caches["tail"][slot] if caches is not None else None
        x, nc, a = block_apply(cfg, kind, p, x, mode=mode, cache=c, pos=pos, enc_out=enc_out)
        if nc is not None:
            new_caches["tail"][slot] = nc
        aux = {k: aux[k] + a[k] for k in aux}
    return x, (new_caches if mode in ("prefill", "prefill_chunk", "decode",
                                      "verify")
               else None), aux
