"""Unified telemetry for the progressive-transmission stack.

The package owns one module-global :class:`MetricsRegistry` and one
:class:`Tracer`, both **default-off**: until :func:`configure` (or the
``REPRO_TELEMETRY=1`` environment variable) enables them, every
instrumented call site gets the shared no-op metric and the tracer
drops spans, so the byte clock, token streams, and event logs are
bit-for-bit what they were before instrumentation existed (pinned in
``tests/test_telemetry_invariant.py``).

Call-site contract: fetch metrics at observation time —

    from repro import obs
    obs.get_registry().counter("planes_ored_total").inc(n, dtype=dt)

never cache the metric object across the enable/disable boundary.
"""
from __future__ import annotations

import contextlib
import os

from repro.obs.registry import (NULL_METRIC, Counter, Gauge, Histogram,
                                MetricsRegistry, percentile)
from repro.obs.tracer import SpanRecord, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_METRIC",
    "SpanRecord", "Tracer", "configure", "enabled", "get_registry",
    "get_tracer", "percentile", "reset", "telemetry",
]

_REGISTRY = MetricsRegistry(
    enabled=os.environ.get("REPRO_TELEMETRY", "") not in ("", "0"))
_TRACER = Tracer(_REGISTRY)


def get_registry() -> MetricsRegistry:
    """The process-global registry instrumented code reports into."""
    return _REGISTRY


def get_tracer() -> Tracer:
    """The process-global span tracer (bound to the global registry)."""
    return _TRACER


def enabled() -> bool:
    return _REGISTRY.enabled


def configure(enabled: bool) -> MetricsRegistry:
    """Flip the global registry on or off. Takes effect at the next
    observation (call sites fetch metrics per-call, never cache)."""
    _REGISTRY.enabled = enabled
    return _REGISTRY


def reset() -> None:
    """Drop all accumulated metrics and spans (enable state is kept)."""
    _REGISTRY.clear()
    _TRACER.clear()


@contextlib.contextmanager
def telemetry(enabled: bool = True):
    """Scoped enable/disable: restores the prior state and, on enable,
    clears anything recorded inside the block on the way out. The
    invariant tests run each engine once inside ``telemetry(True)`` and
    once inside ``telemetry(False)`` and diff the outputs."""
    prior = _REGISTRY.enabled
    _REGISTRY.enabled = enabled
    try:
        yield _REGISTRY
    finally:
        _REGISTRY.enabled = prior
        if enabled and not prior:
            reset()
