"""Exporters: Prometheus text format, JSONL, and a structured summary.

One registry, three faithful views:

* :func:`to_prometheus` — the Prometheus text exposition format
  (counters and gauges as-is; histograms as ``summary`` families with
  exact ``quantile`` labels, ``_sum`` and ``_count``). A minimal
  parser, :func:`parse_prometheus`, round-trips the export — CI uses
  it as the "is this actually scrapeable" check.
* :func:`to_summary` — a JSON-able nested dict (counters/gauges by
  labelset, histogram stats with exact percentiles, span records),
  what ``launch/serve.py --metrics`` writes next to the ``.prom`` file.
* :func:`to_jsonl` — one JSON object per sample, for log pipelines.
"""
from __future__ import annotations

import json
import math
import re

from repro.obs.registry import (Counter, Gauge, Histogram, LabelSet,
                                MetricsRegistry)
from repro.obs.tracer import Tracer

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_QUANTILES = (50.0, 90.0, 99.0)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(ls: LabelSet, extra: tuple[tuple[str, str], ...] = ()) -> str:
    items = [*ls, *extra]
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format."""
    out: list[str] = []
    for m in registry.collect():
        if not _NAME_RE.match(m.name):
            raise ValueError(f"invalid metric name {m.name!r}")
        if isinstance(m, (Counter, Gauge)):
            out.append(f"# HELP {m.name} {m.help or m.name}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for ls, v in m.samples():
                out.append(f"{m.name}{_fmt_labels(ls)} {_fmt_value(v)}")
        elif isinstance(m, Histogram):
            # exact-sample histograms export as Prometheus summaries:
            # the quantiles are computed, not bucket-approximated
            out.append(f"# HELP {m.name} {m.help or m.name}")
            out.append(f"# TYPE {m.name} summary")
            for ls, vs in m.samples():
                for q in _QUANTILES:
                    qv = m.percentile(q, **dict(ls))
                    lab = _fmt_labels(ls, (("quantile", f"{q / 100:g}"),))
                    out.append(f"{m.name}{lab} {_fmt_value(qv)}")
                out.append(
                    f"{m.name}_sum{_fmt_labels(ls)} "
                    f"{_fmt_value(float(sum(vs)))}")
                out.append(f"{m.name}_count{_fmt_labels(ls)} {len(vs)}")
    return "\n".join(out) + ("\n" if out else "")


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text format back into
    ``{name: {"type": str, "help": str, "samples": {labelstr: value}}}``.
    Strict enough to catch a malformed export (unknown line shapes,
    samples for undeclared families, bad floats all raise
    ``ValueError``) — the CI round-trip check."""
    families: dict[str, dict] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            families.setdefault(name, {"samples": {}})["help"] = help_text
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            if kind not in ("counter", "gauge", "summary", "histogram",
                            "untyped"):
                raise ValueError(f"line {lineno}: unknown TYPE {kind!r}")
            families.setdefault(name, {"samples": {}})["type"] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {line!r}")
        name = m.group("name")
        base = re.sub(r"_(sum|count)$", "", name)
        fam = families.get(name) or families.get(base)
        if fam is None:
            raise ValueError(
                f"line {lineno}: sample {name!r} before its TYPE line")
        labels = {}
        raw = m.group("labels")
        if raw:
            consumed = sum(
                len(mm.group(0)) for mm in _LABEL_RE.finditer(raw))
            if consumed < len(raw.replace(",", "")):
                raise ValueError(f"line {lineno}: malformed labels {raw!r}")
            labels = {k: v for k, v in _LABEL_RE.findall(raw)}
        try:
            value = float(m.group("value"))
        except ValueError as e:
            raise ValueError(
                f"line {lineno}: bad value {m.group('value')!r}") from e
        key = name + _fmt_labels(tuple(sorted(labels.items())))
        fam["samples"][key] = value
    for name, fam in families.items():
        if "type" not in fam:
            raise ValueError(f"family {name!r} has no TYPE line")
    return families


def to_summary(registry: MetricsRegistry,
               tracer: Tracer | None = None) -> dict:
    """Structured one-source-of-truth summary: every metric with its
    labeled samples; histograms with exact count/sum/min/max/mean and
    p50/p90/p99; span records when a tracer is supplied."""
    def lkey(ls: LabelSet) -> str:
        return ",".join(f"{k}={v}" for k, v in ls) or "_"

    counters, gauges, hists = {}, {}, {}
    for m in registry.collect():
        if isinstance(m, Counter):
            counters[m.name] = {lkey(ls): v for ls, v in m.samples()}
        elif isinstance(m, Gauge):
            gauges[m.name] = {lkey(ls): v for ls, v in m.samples()}
        elif isinstance(m, Histogram):
            hists[m.name] = {
                lkey(ls): m.stats(quantiles=_QUANTILES, **dict(ls))
                for ls, _ in m.samples()}
    out = {"counters": counters, "gauges": gauges, "histograms": hists}
    if tracer is not None:
        out["spans"] = [s.to_dict() for s in tracer.spans]
    return out


def to_jsonl(registry: MetricsRegistry) -> str:
    """One JSON object per sample: ``{"metric", "type", "labels",
    "value"}`` (histograms carry their stats dict as the value)."""
    lines = []
    for m in registry.collect():
        if isinstance(m, Histogram):
            for ls, _ in m.samples():
                lines.append(json.dumps(
                    {"metric": m.name, "type": m.kind, "labels": dict(ls),
                     "value": m.stats(quantiles=_QUANTILES, **dict(ls))},
                    sort_keys=True))
        else:
            for ls, v in m.samples():
                lines.append(json.dumps(
                    {"metric": m.name, "type": m.kind, "labels": dict(ls),
                     "value": v}, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")
