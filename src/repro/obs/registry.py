"""Labeled metrics registry: counters, gauges, histograms.

One source of truth for everything the serving stack measures. Metrics
are interned by name (``registry.counter("x")`` always returns the same
object), carry free-form labels per sample, and histograms keep their
*exact* observations — percentiles are computed from the full sample
set with numpy's linear-interpolation semantics (pinned against
``np.percentile`` by test), not approximated from fixed bucket bounds.
Sessions here are small (thousands of events, not billions), so exact
beats clever.

The registry is **default-off**: the module-global instance created by
:mod:`repro.obs` starts disabled, and a disabled registry hands every
caller the shared :data:`NULL_METRIC` whose operations are no-ops. The
hard invariant this buys (pinned in ``tests/test_telemetry_invariant``)
is that instrumented hot paths — plane ingest, decode windows, the
byte-clock session loop — behave *identically* with telemetry off, and
enabling it only ever observes values the code already computed: no
device syncs, no extra host transfers, no byte-clock perturbation.
"""
from __future__ import annotations

import math
from typing import Any, Iterable

LabelSet = tuple[tuple[str, str], ...]


def _labelset(labels: dict[str, Any]) -> LabelSet:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def percentile(values: list[float] | tuple[float, ...], q: float) -> float:
    """Exact percentile with numpy's default (linear-interpolation)
    semantics, implemented locally so the registry stays importable
    without numpy on a metrics-only consumer. ``q`` in [0, 100].
    Pinned against ``np.percentile`` oracles by test."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    if not values:
        return math.nan
    vs = sorted(values)
    rank = (len(vs) - 1) * (q / 100.0)
    lo = int(math.floor(rank))
    frac = rank - lo
    if frac == 0.0 or lo + 1 >= len(vs):
        return float(vs[lo])
    return float(vs[lo] + (vs[lo + 1] - vs[lo]) * frac)


class _NullMetric:
    """Shared do-nothing stand-in a disabled registry hands out. Every
    mutator accepts any arguments and returns None; reads return inert
    zeros so accidental reads on the disabled path never raise."""

    __slots__ = ()

    def inc(self, amount: float = 1.0, **labels) -> None:
        pass

    def dec(self, amount: float = 1.0, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, **labels) -> float:
        return 0.0

    def count(self, **labels) -> int:
        return 0

    def sum(self, **labels) -> float:
        return 0.0

    def percentile(self, q: float, **labels) -> float:
        return math.nan

    def samples(self) -> list:
        return []


NULL_METRIC = _NullMetric()


class Metric:
    """Base: name + help + per-labelset storage."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._data: dict[LabelSet, Any] = {}

    def labelsets(self) -> list[LabelSet]:
        return sorted(self._data)


class Counter(Metric):
    """Monotonically increasing count (``inc`` rejects negatives)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        ls = _labelset(labels)
        self._data[ls] = self._data.get(ls, 0.0) + amount

    def value(self, **labels) -> float:
        return self._data.get(_labelset(labels), 0.0)

    def samples(self) -> list[tuple[LabelSet, float]]:
        return [(ls, self._data[ls]) for ls in self.labelsets()]


class Gauge(Metric):
    """Last-written value per labelset."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._data[_labelset(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        ls = _labelset(labels)
        self._data[ls] = self._data.get(ls, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        return self._data.get(_labelset(labels), 0.0)

    def samples(self) -> list[tuple[LabelSet, float]]:
        return [(ls, self._data[ls]) for ls in self.labelsets()]


class Histogram(Metric):
    """Exact-sample histogram: every observation is kept, so
    ``percentile`` is exact (numpy linear-interpolation semantics) and
    the exporter can emit any quantile without pre-chosen buckets."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        self._data.setdefault(_labelset(labels), []).append(float(value))

    def count(self, **labels) -> int:
        return len(self._data.get(_labelset(labels), ()))

    def sum(self, **labels) -> float:
        return float(sum(self._data.get(_labelset(labels), ())))

    def values(self, **labels) -> list[float]:
        return list(self._data.get(_labelset(labels), ()))

    def percentile(self, q: float, **labels) -> float:
        return percentile(self._data.get(_labelset(labels), ()), q)

    def stats(self, quantiles: Iterable[float] = (50, 90, 99),
              **labels) -> dict:
        vs = self._data.get(_labelset(labels), [])
        out = {"count": len(vs), "sum": float(sum(vs))}
        if vs:
            out["min"] = float(min(vs))
            out["max"] = float(max(vs))
            out["mean"] = out["sum"] / len(vs)
        for q in quantiles:
            out[f"p{q:g}"] = percentile(vs, q)
        return out

    def samples(self) -> list[tuple[LabelSet, list[float]]]:
        return [(ls, list(self._data[ls])) for ls in self.labelsets()]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Interned, labeled metrics with a master enable switch.

    ``enabled=False`` (how the global registry starts) turns every
    accessor into a constant-time no-op: ``counter()``/``gauge()``/
    ``histogram()`` return the shared :data:`NULL_METRIC` without
    creating anything. Instrumented code therefore fetches its metric
    at the call site (``get_registry().counter(...)``) rather than
    caching it, so flipping ``enabled`` mid-process takes effect on the
    next observation.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str):
        if not self.enabled:
            return NULL_METRIC
        got = self._metrics.get(name)
        if got is None:
            got = cls(name, help)
            self._metrics[name] = got
        elif not isinstance(got, cls):
            raise TypeError(
                f"metric {name!r} already registered as {got.kind}, "
                f"requested {cls.kind}")
        return got

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def collect(self) -> list[Metric]:
        """All registered metrics, name-sorted (export order)."""
        return [self._metrics[n] for n in sorted(self._metrics)]

    def clear(self) -> None:
        self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)
