"""``repro-telemetry`` — analyze session event logs.

Reads one or more ``SessionResult`` JSONL logs (what
``launch/serve.py --event-log`` and the tier-2 CI job write) and
renders the paper's user-experience curve as tables:

* **per-stage**: arrival time on the byte clock, cumulative bytes,
  goodput (bytes/s to that stage), and — when an accuracy table is
  supplied via ``--accuracy`` — accuracy-per-MB;
* **latency**: TTFT (cold start → first emitted token) and
  decode/window cadence;
* **stalls** with p50/p99: upgrade lag (stage arrival → engine
  upgrade), inter-chunk gaps, and fault-channel backoff
  (retry/nack/reconnect).

Everything is computed from the log alone — the analyzer never needs
the model, the registry, or a live session, so it runs on any archived
artifact. ``--check-prom`` additionally round-trips a Prometheus
export through :func:`repro.obs.exporters.parse_prometheus` (the CI
scrapeability check), and ``--validate`` runs every event through the
:mod:`repro.obs.schema` registry.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs.registry import percentile


def load_events(path: str | Path) -> list[dict]:
    """Parse a JSONL log into event records ordered by (t_s, seq)."""
    events = []
    for lineno, line in enumerate(Path(path).read_text().splitlines(), 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{lineno}: not JSON ({e})") from e
        if "kind" not in rec or "t_s" not in rec:
            raise ValueError(f"{path}:{lineno}: not a session event record")
        events.append(rec)
    events.sort(key=lambda e: (e["t_s"], e.get("seq", 0)))
    return events


def _pcts(vals: list[float]) -> dict:
    return {"count": len(vals),
            "p50": percentile(vals, 50), "p99": percentile(vals, 99)}


def analyze(events: list[dict],
            accuracy: dict[int, float] | None = None) -> dict:
    """Reduce an event stream to the report structure. ``accuracy``
    maps stage -> task accuracy (e.g. from an evaluation sweep) and
    enables the accuracy-per-byte column."""
    by_kind: dict[str, list[dict]] = {}
    for e in events:
        by_kind.setdefault(e["kind"], []).append(e)

    # -- per-stage table ------------------------------------------------
    stages = []
    arrival: dict[int, float] = {}
    for e in by_kind.get("stage_complete", ()):
        s = e["stage"]
        if s in arrival:  # a repair can re-announce; keep first arrival
            continue
        arrival[s] = e["t_s"]
        bytes_through = e.get("through")
        row = {"stage": s, "t_s": e["t_s"], "bytes": bytes_through,
               "via_repair": "repair" in e}
        if bytes_through and e["t_s"] > 0:
            row["goodput_bps"] = bytes_through / e["t_s"]
        if accuracy and s in accuracy:
            row["accuracy"] = accuracy[s]
            if bytes_through:
                row["acc_per_mb"] = accuracy[s] / (bytes_through / 2**20)
        stages.append(row)

    # -- latency --------------------------------------------------------
    latency: dict = {}
    cold = by_kind.get("cold_start", ())
    if cold:
        t0 = cold[0]["t_s"]
        latency["cold_start_s"] = t0
        first_tok = None
        for e in events:
            if e["kind"] == "decode_step":
                first_tok = e["t_s"]
                break
            if e["kind"] == "pool_window" and e.get("tokens", 0) > 0:
                first_tok = e["t_s"]
                break
        if first_tok is not None:
            latency["first_token_s"] = first_tok
            latency["ttft_s"] = first_tok - t0
    results = by_kind.get("result_ready", ())
    if results:
        latency["result_ready"] = {
            e["stage"]: e["t_s"] for e in results}
    decode_ts = [e["t_s"] for e in by_kind.get("decode_step", ())]
    if len(decode_ts) > 1:
        gaps = [b - a for a, b in zip(decode_ts, decode_ts[1:])]
        latency["decode_gap_s"] = _pcts(gaps)
    windows = by_kind.get("pool_window", ())
    if windows:
        latency["pool_windows"] = {
            "count": len(windows),
            "tokens": sum(e.get("tokens", 0) for e in windows),
            "steps": sum(e.get("steps", 0) for e in windows)}

    # -- stalls ---------------------------------------------------------
    stalls: dict = {}
    upgrade_lags = []
    for e in by_kind.get("upgrade", ()):
        s = e.get("stage")
        if s in arrival:
            upgrade_lags.append(e["t_s"] - arrival[s])
    if upgrade_lags:
        stalls["upgrade_lag_s"] = _pcts(upgrade_lags)
    chunk_ts = [e["t_s"] for e in by_kind.get("chunk", ())]
    if len(chunk_ts) > 1:
        stalls["chunk_gap_s"] = _pcts(
            [b - a for a, b in zip(chunk_ts, chunk_ts[1:])])
    backoffs = [e["backoff_s"] for k in ("retry", "reconnect")
                for e in by_kind.get(k, ()) if "backoff_s" in e]
    backoffs += [e["rerequest_backoff_s"] for e in by_kind.get("nack", ())]
    if backoffs:
        stalls["backoff_s"] = _pcts(backoffs)

    # -- speculation / transport ---------------------------------------
    extras: dict = {}
    accepts = by_kind.get("accept_round", ())
    if accepts:
        rates = [e["rate"] for e in accepts if "rate" in e]
        extras["speculation"] = {"rounds": len(accepts),
                                 "accept_rate": _pcts(rates)}
    ts = by_kind.get("transport_summary", ())
    if ts:
        extras["transport"] = {
            k: v for k, v in ts[-1].items()
            if k not in ("t_s", "kind", "seq")}

    return {"events": len(events),
            "kinds": {k: len(v) for k, v in sorted(by_kind.items())},
            "stages": stages, "latency": latency, "stalls": stalls,
            **extras}


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _table(headers: list[str], rows: list[list]) -> str:
    cells = [[_fmt(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    def line(parts):
        return "  ".join(p.ljust(w) for p, w in zip(parts, widths)).rstrip()
    return "\n".join([line(headers),
                      line(["-" * w for w in widths]),
                      *[line(r) for r in cells]])


def render(report: dict, title: str = "") -> str:
    out = []
    if title:
        out += [f"== {title} ==", ""]
    out.append(f"events: {report['events']}  "
               + "  ".join(f"{k}={v}" for k, v in report["kinds"].items()))
    if report["stages"]:
        has_acc = any("accuracy" in r for r in report["stages"])
        headers = ["stage", "t_s", "bytes", "goodput_B/s"]
        if has_acc:
            headers += ["accuracy", "acc/MB"]
        headers += ["repair"]
        rows = []
        for r in report["stages"]:
            row = [r["stage"], r["t_s"], r.get("bytes"),
                   r.get("goodput_bps")]
            if has_acc:
                row += [r.get("accuracy"), r.get("acc_per_mb")]
            row += [r["via_repair"]]
            rows.append(row)
        out += ["", "per-stage arrivals:", _table(headers, rows)]
    lat = report["latency"]
    if lat:
        out += ["", "latency:"]
        if "ttft_s" in lat:
            out.append(f"  ttft_s={_fmt(lat['ttft_s'])} "
                       f"(cold_start_s={_fmt(lat.get('cold_start_s'))}, "
                       f"first_token_s={_fmt(lat.get('first_token_s'))})")
        if "result_ready" in lat:
            out.append("  result_ready: " + "  ".join(
                f"stage{s}@{_fmt(t)}s"
                for s, t in sorted(lat["result_ready"].items())))
        if "decode_gap_s" in lat:
            g = lat["decode_gap_s"]
            out.append(f"  decode_gap_s: n={g['count']} "
                       f"p50={_fmt(g['p50'])} p99={_fmt(g['p99'])}")
        if "pool_windows" in lat:
            w = lat["pool_windows"]
            out.append(f"  pool_windows: n={w['count']} "
                       f"tokens={w['tokens']} steps={w['steps']}")
    if report["stalls"]:
        rows = [[name, s["count"], s["p50"], s["p99"]]
                for name, s in sorted(report["stalls"].items())]
        out += ["", "stalls:", _table(["metric", "n", "p50", "p99"], rows)]
    if "speculation" in report:
        sp = report["speculation"]
        r = sp["accept_rate"]
        out += ["", f"speculation: rounds={sp['rounds']} accept_rate "
                    f"p50={_fmt(r['p50'])} p99={_fmt(r['p99'])}"]
    if "transport" in report:
        out += ["", "transport: " + "  ".join(
            f"{k}={v}" for k, v in report["transport"].items()
            if not isinstance(v, dict))]
    return "\n".join(out)


def _parse_accuracy(spec: str | None) -> dict[int, float] | None:
    """``--accuracy 1=0.31,2=0.52,4=0.66`` or a path to a JSON file
    mapping stage -> accuracy."""
    if not spec:
        return None
    p = Path(spec)
    if p.exists():
        raw = json.loads(p.read_text())
        return {int(k): float(v) for k, v in raw.items()}
    out = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        out[int(k)] = float(v)
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-telemetry",
        description="Analyze session event logs (JSONL) into per-stage "
                    "goodput/TTFT/stall tables with p50/p99.")
    ap.add_argument("logs", nargs="*", help="session JSONL log files")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of tables")
    ap.add_argument("--accuracy", default=None,
                    help="stage accuracies: '1=0.31,4=0.66' or a JSON "
                         "file path; enables the accuracy-per-MB column")
    ap.add_argument("--validate", action="store_true",
                    help="validate every event against the schema "
                         "registry before analyzing")
    ap.add_argument("--check-prom", default=None, metavar="PATH",
                    help="parse a Prometheus text export and exit "
                         "(round-trip scrapeability check)")
    args = ap.parse_args(argv)

    if args.check_prom:
        from repro.obs.exporters import parse_prometheus
        text = Path(args.check_prom).read_text()
        families = parse_prometheus(text)
        n = sum(len(f["samples"]) for f in families.values())
        print(f"{args.check_prom}: OK — {len(families)} families, "
              f"{n} samples")
        if not args.logs:
            return 0

    if not args.logs:
        ap.error("no logs given (and no --check-prom)")

    accuracy = _parse_accuracy(args.accuracy)
    reports = {}
    for log in args.logs:
        events = load_events(log)
        if args.validate:
            from repro.obs.schema import validate_event
            for e in events:
                validate_event(e)
        reports[log] = analyze(events, accuracy=accuracy)

    if args.json:
        print(json.dumps(reports, indent=2, sort_keys=True))
    else:
        for i, (log, rep) in enumerate(reports.items()):
            if i:
                print()
            print(render(rep, title=log))
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        # e.g. `repro-telemetry ... | head`; devnull stdout so the
        # interpreter's exit flush doesn't raise a second time
        import os
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        sys.exit(0)
