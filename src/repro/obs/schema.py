"""Event-schema registry for the session JSONL log.

Every event kind the co-simulation can emit is enumerated here with its
required and optional payload fields and their types. The replay test
(``tests/test_obs.py``) runs real ``browser-3g`` and
``browser-3g-lossy`` sessions and validates every event against this
table, so a payload rename, a dropped field, or a new unregistered kind
fails loudly instead of silently drifting (the PR 9 ``--event-log``
clobber is exactly the class of bug this catches).

``validate_event`` accepts either a :class:`SessionEvent` or a decoded
JSONL record (with top-level ``t_s``/``kind``/``seq``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

_NUM = (int, float)
_STR = (str,)
_INT = (int,)
_BOOL = (bool,)
_LIST = (list,)
_DICT = (dict,)


class SchemaError(ValueError):
    """An event failed validation against the registered schema."""


@dataclasses.dataclass(frozen=True)
class EventSchema:
    """Field table for one event kind. ``required``/``optional`` map
    field name to the tuple of accepted Python types (post-JSON, so
    tuples appear as lists). ``allow_extra`` admits unenumerated
    fields — only ``fault`` uses it, since injector kinds carry
    kind-specific detail."""

    kind: str
    required: Mapping[str, tuple]
    optional: Mapping[str, tuple] = dataclasses.field(default_factory=dict)
    allow_extra: bool = False

    def validate(self, data: Mapping[str, Any]) -> None:
        for field, types in self.required.items():
            if field not in data:
                raise SchemaError(
                    f"{self.kind}: missing required field {field!r} "
                    f"(payload keys: {sorted(data)})")
            self._check_type(field, data[field], types)
        for field, value in data.items():
            if field in self.required:
                continue
            if field in self.optional:
                self._check_type(field, value, self.optional[field])
            elif not self.allow_extra:
                raise SchemaError(
                    f"{self.kind}: unexpected field {field!r}")

    def _check_type(self, field: str, value: Any, types: tuple) -> None:
        # bool subclasses int; don't let a bool satisfy a numeric field
        if isinstance(value, bool) and bool not in types:
            raise SchemaError(
                f"{self.kind}.{field}: got bool, expected "
                f"{'/'.join(t.__name__ for t in types)}")
        if value is None or isinstance(value, types):
            return
        raise SchemaError(
            f"{self.kind}.{field}: got {type(value).__name__} "
            f"({value!r}), expected "
            f"{'/'.join(t.__name__ for t in types)}")


EVENT_SCHEMAS: dict[str, EventSchema] = {s.kind: s for s in [
    # -- byte-clock delivery events -------------------------------------
    EventSchema("chunk", {"bytes": _INT, "through": _INT}),
    EventSchema("header", {"bytes": _INT}),
    EventSchema("stage_complete", {"stage": _INT},
                {"through": _INT, "repair": _INT}),
    EventSchema("result_ready",
                {"stage": _INT, "process_start_s": _NUM}),
    # -- serving events -------------------------------------------------
    EventSchema("cold_start", {"stage": _INT},
                {"prompt_len": _INT, "n_slots": _INT, "clients": _INT}),
    EventSchema("decode_step", {"step": _INT, "stage": _INT}),
    EventSchema("upgrade", {"step": _INT, "stage": _INT}),
    EventSchema("accept_round",
                {"k": _INT, "accepted": (int, list), "rate": _NUM,
                 "stage": _INT},
                {"round": _INT, "emitted": _LIST,
                 "effective_bits": _DICT}),
    EventSchema("submit", {"rid": _INT}),
    EventSchema("admit", {"rid": _INT}),
    EventSchema("evict", {"rid": _INT}),
    EventSchema("pool_window",
                {"steps": _INT, "tokens": _INT, "active": _INT,
                 "stage": _INT}),
    # -- fault-channel events -------------------------------------------
    # payload field is "fault" (not "kind"): the JSONL export flattens
    # the payload next to the envelope, and a payload "kind" would
    # shadow the event kind (a real bug this schema caught)
    EventSchema("fault", {"fault": _STR}, allow_extra=True),
    EventSchema("retry",
                {"target": _STR, "attempt": _INT, "backoff_s": _NUM}),
    # unit-scoped events name the wire unit "unit", never "seq" — the
    # JSONL envelope owns "seq" (the event sequence number)
    EventSchema("quarantine", {"reason": _STR},
                {"unit": _INT, "target": _STR}),
    EventSchema("nack", {"unit": _INT, "rerequest_backoff_s": _NUM}),
    EventSchema("repair",
                {"unit": _INT, "attempt": _INT, "ok": _BOOL}),
    EventSchema("reconnect",
                {"reason": _STR, "cursor": _LIST, "attempt": _INT,
                 "backoff_s": _NUM}),
    EventSchema("resume", {"offset": _INT, "unit_seq": _INT}),
    EventSchema("transport_summary",
                {"injected": _DICT, "deliveries": _INT,
                 "quarantined": _INT, "repaired_units": _INT,
                 "duplicate_units": _INT, "reconnects": _INT,
                 "pending_nacks": _INT, "verified_units": _INT},
                {"framing_overhead": _DICT}),
]}

# top-level keys of a JSONL record that are envelope, not payload
_ENVELOPE = ("t_s", "kind", "seq")


def validate_event(event: Any) -> None:
    """Validate one event — a ``SessionEvent`` (anything with
    ``.kind``/``.data``) or a decoded JSONL record dict. Raises
    :class:`SchemaError` on unknown kinds, missing/unexpected fields,
    or type mismatches."""
    if isinstance(event, Mapping):
        if "kind" not in event or "t_s" not in event:
            raise SchemaError(
                f"record missing t_s/kind envelope: {sorted(event)}")
        if not isinstance(event["t_s"], _NUM) or isinstance(
                event["t_s"], bool):
            raise SchemaError(f"t_s must be numeric, got {event['t_s']!r}")
        if "seq" in event and not isinstance(event["seq"], int):
            raise SchemaError(f"seq must be int, got {event['seq']!r}")
        kind = event["kind"]
        data = {k: v for k, v in event.items() if k not in _ENVELOPE}
    else:
        kind = event.kind
        data = event.data
    schema = EVENT_SCHEMAS.get(kind)
    if schema is None:
        raise SchemaError(
            f"unknown event kind {kind!r} "
            f"(registered: {sorted(EVENT_SCHEMAS)})")
    schema.validate(data)


def validate_jsonl(text: str) -> int:
    """Validate every line of a session JSONL log; returns the number
    of events checked. Raises :class:`SchemaError` with the offending
    line number on the first failure."""
    n = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise SchemaError(f"line {lineno}: not JSON ({e})") from e
        try:
            validate_event(rec)
        except SchemaError as e:
            raise SchemaError(f"line {lineno}: {e}") from e
        n += 1
    return n
