"""Dual-clock span tracer.

The co-simulation runs on two clocks at once: the **simulated
byte clock** (seconds of the bandwidth trace — deterministic, the
clock stage arrivals and session events are stamped with) and **host
wall time** (``time.perf_counter`` — what decode windows and upgrade
enqueues actually cost on this machine). A single latency number is
meaningless without saying which clock it lives on, so a
:class:`SpanRecord` carries both sides explicitly and either may be
absent: engines record wall-only spans (they never see the byte
clock), the session records sim-only spans (its work is charged by the
trace, not measured), and ``repro-telemetry`` reports always name the
clock.

Spans also feed the metrics registry (histograms
``span_<name>_wall_s`` / ``span_<name>_sim_s``) so the Prometheus and
summary exports carry the same percentiles the span list does. Like
everything in :mod:`repro.obs`, a tracer over a disabled registry
records nothing at all.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span. ``wall_s`` is host-measured duration;
    ``sim_t0``/``sim_t1`` bound the span on the simulated byte clock.
    Either clock (not both) may be absent."""

    name: str
    labels: dict
    wall_s: float | None = None
    sim_t0: float | None = None
    sim_t1: float | None = None

    @property
    def sim_s(self) -> float | None:
        if self.sim_t0 is None or self.sim_t1 is None:
            return None
        return self.sim_t1 - self.sim_t0

    def to_dict(self) -> dict:
        d = {"name": self.name, **self.labels}
        if self.wall_s is not None:
            d["wall_s"] = self.wall_s
        if self.sim_t0 is not None:
            d["sim_t0"] = self.sim_t0
        if self.sim_t1 is not None:
            d["sim_t1"] = self.sim_t1
            if self.sim_t0 is not None:
                d["sim_s"] = self.sim_s
        return d


class Tracer:
    """Span sink bound to a registry. Inert while the registry is
    disabled: ``record`` drops the span, ``span()`` skips even the
    clock reads, so tracing a disabled session allocates nothing."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.spans: list[SpanRecord] = []

    def record(self, name: str, *, wall_s: float | None = None,
               sim_t0: float | None = None, sim_t1: float | None = None,
               **labels) -> SpanRecord | None:
        if not self.registry.enabled:
            return None
        rec = SpanRecord(name=name, labels=labels, wall_s=wall_s,
                         sim_t0=sim_t0, sim_t1=sim_t1)
        self.spans.append(rec)
        if wall_s is not None:
            self.registry.histogram(
                f"span_{name}_wall_s",
                f"host wall seconds of {name} spans").observe(
                    wall_s, **labels)
        if rec.sim_s is not None:
            self.registry.histogram(
                f"span_{name}_sim_s",
                f"simulated byte-clock seconds of {name} spans").observe(
                    rec.sim_s, **labels)
        return rec

    @contextlib.contextmanager
    def span(self, name: str, *, sim_t0: float | None = None,
             sim_t1: float | None = None, **labels):
        """Measure a wall-clock span around a block; the caller may
        additionally stamp the byte-clock bounds it knows."""
        if not self.registry.enabled:
            yield None
            return
        t0 = time.perf_counter()
        try:
            yield None
        finally:
            self.record(name, wall_s=time.perf_counter() - t0,
                        sim_t0=sim_t0, sim_t1=sim_t1, **labels)

    def of(self, name: str) -> list[SpanRecord]:
        return [s for s in self.spans if s.name == name]

    def clear(self) -> None:
        self.spans.clear()
