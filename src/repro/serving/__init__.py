from repro.serving.engine import ProgressiveServer, GenerationResult

__all__ = ["ProgressiveServer", "GenerationResult"]
