from repro.serving.engine import (GenerationResult, ProgressiveServer,
                                  WireStoreReceiver, resident_report)

__all__ = ["ProgressiveServer", "GenerationResult", "WireStoreReceiver",
           "resident_report"]
