from repro.serving.engine import (GenerationResult, PoolRequest,
                                  PoolStepStats, ProgressiveServer,
                                  SlotPoolEngine, WireStoreReceiver,
                                  resident_report)
from repro.serving.speculative import (SpecConfig, SpeculativeEngine,
                                       SpeculativeResult,
                                       SpeculativeSlotPool)

__all__ = ["ProgressiveServer", "GenerationResult", "WireStoreReceiver",
           "SlotPoolEngine", "PoolRequest", "PoolStepStats",
           "resident_report", "SpecConfig", "SpeculativeEngine",
           "SpeculativeResult", "SpeculativeSlotPool"]
