from repro.serving.engine import (GenerationResult, PoolRequest,
                                  PoolStepStats, ProgressiveServer,
                                  SlotPoolEngine, WireStoreReceiver,
                                  resident_report)

__all__ = ["ProgressiveServer", "GenerationResult", "WireStoreReceiver",
           "SlotPoolEngine", "PoolRequest", "PoolStepStats",
           "resident_report"]
