"""Progressive serving engine.

The deployment story of the paper, pod-side: a server starts with the
MSB planes of the weights, begins serving immediately, and upgrades
precision *in place* between decode steps as later planes arrive. The KV
cache and the compiled decode executable survive upgrades (weight
values change; shapes/dtypes don't), so an upgrade costs one integer
OR + dequantize — no recompilation, no cache invalidation, no request
draining. That is the TPU-serving analogue of the paper's Fig. 4
concurrent download/inference timeline.

The accumulators live in the shared PlaneStore (via ``ReceiverState``):
a stage upgrade is one batched integer Pallas launch over the flat
buffer, and re-dequantization touches only the tensors that actually
received planes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import wire
from repro.core.progressive import ProgressiveModel, ReceiverState, rebuild_params
from repro.models.model import Model


@dataclasses.dataclass
class GenerationResult:
    tokens: Any           # (B, steps) generated token ids
    stage_at_step: list   # precision stage used for each decode step
    upgrades: list        # (step, stage) upgrade events
    per_step_s: list


class WireStoreReceiver:
    """Adapts a wire-fed :class:`~repro.transmission.client.ProgressiveClient`
    as a server's parameter source, so the *same* device-resident
    PlaneStore that the byte stream fills is the one the server decodes
    from — no second ingest, no second set of Pallas launches.

    ``materialize`` reads only *completed* stages: it goes straight to
    ``store.materialize_leaves()`` without flushing the client's pending
    partial-stage planes, so the served params are exactly the stage
    prefix (bit-identical to ``transmit_reconstruct`` at that stage) —
    mid-stage planes land with their stage's completion flush.
    """

    def __init__(self, client, prog: ProgressiveModel):
        self.client = client
        self.prog = prog

    @property
    def stages_complete(self) -> int:
        return self.client.stages_complete

    def materialize(self):
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.materialize_leaves()
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)


class ProgressiveServer:
    """Holds device-resident plane accumulators + a jit'd decode step.

    Two feeding modes:

    * pull (default): ``receive_stage()`` ingests the next stage's
      planes from ``self.prog`` into the server's own ReceiverState
      (server-push in a real deployment).
    * receiver: constructed with ``receiver=`` (e.g.
      :class:`WireStoreReceiver` over the wire client's store) the
      server holds no accumulators of its own — ``receive_stage()``
      re-materializes from the externally-fed store. This is what the
      co-simulation :class:`~repro.transmission.session.Session` uses:
      bytes are ingested once, by the client.
    """

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None):
        self.model = model
        self.prog = prog
        self.max_len = max_len
        self._receiver = receiver
        self.state = None if receiver is not None else ReceiverState.init(prog)
        self._consumed = 0  # receiver mode: stages reflected in params
        self.params = None  # materialized at current precision
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.caches = None
        self.pos = 0

    # -- precision management ------------------------------------------------
    @property
    def stage(self) -> int:
        if self._receiver is not None:
            return self._consumed
        return self.state.received_stages

    @property
    def stages_available(self) -> int:
        """Stages the server could upgrade to right now."""
        if self._receiver is not None:
            return self._receiver.stages_complete
        return self.prog.n_stages

    def receive_stage(self) -> None:
        """Pull the next stage's planes (server-push in a real
        deployment; here the planes live in ``self.prog``), or — in
        receiver mode — refresh params from the externally-fed store,
        catching up to every stage the receiver has completed.

        The OR is one batched ``plane_or_segments`` launch over the
        store's flat buffer, and the materialize is incremental: only
        tensors whose accumulator changed are re-dequantized — tensors
        whose schedule is exhausted (or that missed this shipment) come
        back as the *same* cached array objects, so the jitted decode
        sees an unchanged buffer for them."""
        if self._receiver is not None:
            avail = self._receiver.stages_complete
            if avail <= self._consumed:
                raise RuntimeError(
                    f"receiver has no new stage (at {avail}, "
                    f"served {self._consumed})")
            self._consumed = avail
            self.params = self._receiver.materialize()
            return
        s = self.state.received_stages + 1
        self.state = self.state.receive(self.prog.stage(s))
        self.params = self.state.materialize()

    # -- serving ---------------------------------------------------------------
    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        last_logits, caches = self._prefill(self.params, batch)
        self.caches = self.model.grow_caches(caches, self.max_len)
        self.pos = batch["tokens"].shape[1]
        self.last_logits = last_logits

    def decode(self, steps: int, *, stage_arrival: Callable[[int], bool] | None = None) -> GenerationResult:
        """Greedy-decode ``steps`` tokens; before each step, consult
        ``stage_arrival(step)`` — True means the next plane landed and we
        upgrade in place (KV cache untouched)."""
        toks = []
        stage_at, upgrades, per_step = [], [], []
        logits = self.last_logits
        for i in range(steps):
            if stage_arrival and self.stage < self.prog.n_stages and stage_arrival(i):
                self.receive_stage()
                upgrades.append((i, self.stage))
            t0 = time.perf_counter()
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, self.caches = self._decode(
                self.params, self.caches, nxt, jnp.int32(self.pos)
            )
            jax.block_until_ready(logits)
            per_step.append(time.perf_counter() - t0)
            self.pos += 1
            toks.append(nxt[:, 0])
            stage_at.append(self.stage)
        self.last_logits = logits
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            stage_at_step=stage_at,
            upgrades=upgrades,
            per_step_s=per_step,
        )
