"""Progressive serving engine: single-stream server + continuous-
batching slot pool.

The deployment story of the paper, pod-side: a server starts with the
MSB planes of the weights, begins serving immediately, and upgrades
precision *in place* between decode steps as later planes arrive. The KV
cache and the compiled decode executable survive upgrades (weight
values change; shapes/dtypes don't), so an upgrade costs one integer
OR + dequantize — no recompilation, no cache invalidation, no request
draining. That is the TPU-serving analogue of the paper's Fig. 4
concurrent download/inference timeline.

Two engines share the precision machinery:

* :class:`ProgressiveServer` — the lock-stepped single stream (every
  slot at the same position). Kept for parity baselines, prefix tests
  and the Fig.-4 co-simulation.
* :class:`SlotPoolEngine` — continuous batching: a fixed pool of
  ``n_slots`` decode slots over ONE set of device caches in the flash
  kernel's native ``(B, Kh, S, hd)`` layout. Requests are admitted into
  free slots mid-flight (their prompt prefilled straight into the
  slot's cache region), finished requests are evicted, and every step
  is one batched ragged ``decode_step`` — per-slot ``(B,)`` positions,
  one compiled executable for the lifetime of the pool, upgrades
  applied between batched steps at zero recompiles.

Both engines dispatch **asynchronously**: the device is never host-
synced per token. Greedy sampling chains on device (argmax feeds the
next step), and the host only blocks on a bounded in-flight window
(``dispatch_window`` steps) before reading token values — so plane
ingest, admission bookkeeping and upgrade scheduling all overlap device
decode. ``sync=True`` restores the old block-per-token behavior (and
its per-token timing semantics) for comparable benchmarks.

The accumulators live in the shared PlaneStore: a stage upgrade is one
batched integer Pallas launch over the flat buffer. What the decode
step *sees* is governed by ``resident``:

* ``resident="fp"`` (paper): each upgrade re-dequantizes the dirty
  tensors into float leaves (incremental eq. 5) — a full fp copy of the
  model lives in HBM next to the accumulators.
* ``resident="quantized"`` (SLIDE-style): the live param pytree holds
  :class:`~repro.core.quantize.QuantizedTensor` *views* over the
  accumulators; eq. (5) runs fused into every matmul
  (``kernels/dequant_matmul``) and no fp weight buffer ever exists.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core import wire
from repro.core.progressive import ProgressiveModel, ReceiverState, rebuild_params
from repro.core.quantize import QuantizedTensor
from repro.models.common import quantized_resident_eligible
from repro.models.model import Model

RESIDENT_MODES = ("fp", "quantized")


@dataclasses.dataclass
class GenerationResult:
    tokens: Any           # (B, steps) generated token ids
    stage_at_step: list   # precision stage used for each decode step
    upgrades: list        # (step, stage) upgrade events
    per_step_s: list      # sync: measured per token; async: window_s/steps
    window_s: list = dataclasses.field(default_factory=list)
    #                    # (steps_in_window, wall_seconds) per flushed window
    ttft_s: float = 0.0   # wall time until the first token's value is on host
    tpot_s: float = 0.0   # total wall time / steps
    mode: str = "sync"    # "sync" (block per token) | "async" (windowed)


def resident_report(params) -> dict:
    """Leaf-type audit of a live param pytree: how many leaves are
    quantized-resident vs float, and the HBM bytes each side holds.
    ``quantized_bytes`` counts the uint accumulator views (what a
    quantized-resident server actually keeps for its weights);
    ``fp_bytes`` counts float leaves — for ``resident='quantized'``
    that is only the small non-matmul remainder (norms, gates, conv
    kernels), and the audit is exactly the acceptance check that no fp
    weight buffer exists.

    Buffers are counted ONCE per distinct array object: a speculative
    engine's draft view shares the target view's accumulators (and the
    fp remainder) verbatim, so auditing ``(target, draft)`` together
    shows zero extra resident weight bytes next to the target alone —
    ``aliased_leaves`` counts the shared ones. ``effective_bits`` maps
    each quantized leaf's path to its served precision
    ``min(received_bits, keep_bits)``, which is what tells a draft view
    apart from the full view (the buffers are identical)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    n_q = n_fp = q_bytes = fp_bytes = meta_bytes = aliased = 0
    eff_bits: dict[str, int] = {}
    seen: set[int] = set()
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        if isinstance(leaf, QuantizedTensor):
            n_q += 1
            if id(leaf.q) in seen:
                aliased += 1
            else:
                seen.add(id(leaf.q))
                q_bytes += leaf.q.size * leaf.q.dtype.itemsize
            for m in (leaf.lo, leaf.hi, leaf.scale, leaf.offset,
                      leaf.received_bits, leaf.keep_bits):
                if m is not None:
                    meta_bytes += np.size(m) * m.dtype.itemsize
            eff = leaf.bits
            if leaf.received_bits is not None:
                eff = int(np.max(np.asarray(leaf.received_bits)))
            if leaf.keep_bits is not None:
                eff = min(eff, int(np.max(np.asarray(leaf.keep_bits))))
            eff_bits[pstr] = eff
        else:
            n_fp += 1
            if id(leaf) in seen:
                aliased += 1
            else:
                seen.add(id(leaf))
                fp_bytes += np.size(leaf) * jnp.asarray(leaf).dtype.itemsize
    return {"quantized_leaves": n_q, "fp_leaves": n_fp,
            "quantized_bytes": q_bytes, "fp_bytes": fp_bytes,
            "metadata_bytes": meta_bytes, "aliased_leaves": aliased,
            "effective_bits": eff_bits}


class WireStoreReceiver:
    """Adapts a wire-fed :class:`~repro.transmission.client.ProgressiveClient`
    as a server's parameter source, so the *same* device-resident
    PlaneStore that the byte stream fills is the one the server decodes
    from — no second ingest, no second set of Pallas launches.

    ``materialize`` reads only *completed* stages: it goes straight to
    the store without flushing the client's pending partial-stage
    planes, so the served params are exactly the stage prefix
    (bit-identical to ``transmit_reconstruct`` at that stage) —
    mid-stage planes land with their stage's completion flush.
    """

    def __init__(self, client, prog: ProgressiveModel):
        self.client = client
        self.prog = prog

    @property
    def stages_complete(self) -> int:
        return self.client.stages_complete

    @property
    def store(self):
        return self.client.store

    def transport_health(self) -> dict:
        """Fault-tolerance counters of the underlying client (inert
        zeros on a trusted v1/v2 stream). ``stages_complete`` counts
        only *verified* checkpoints, so while a damaged unit is being
        re-fetched the engine keeps serving at the last verified stage
        — this surface is how operators see that happening."""
        c = self.client
        return {
            "integrity": bool(getattr(c, "integrity", False)),
            "stages_complete": c.stages_complete,
            "verified_units": getattr(c, "verified_units", 0),
            "pending_nacks": len(getattr(c, "nacks", {})),
            "quarantined": len(getattr(c, "quarantine_log", [])),
            "duplicate_units": getattr(c, "duplicate_units", 0),
            "resume_cursor": list(getattr(c, "resume_cursor", (0, 0))),
        }

    def materialize(self):
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.materialize_leaves()
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)

    def materialize_resident(self, eligible=quantized_resident_eligible,
                             *, bits=None):
        """Quantized-resident view over the client's store: weight
        leaves stay QuantizedTensor accumulator views; this is the
        'metadata refresh' of an upgrade — no ``materialize()`` at
        all for the weights. ``bits=b`` yields the truncated-precision
        draft view (same accumulators, zero extra weight bytes)."""
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.quantized_leaves(eligible=eligible,
                                                    bits=bits)
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)


class PrecisionManagedEngine:
    """Shared precision machinery: plane accumulators (own ReceiverState
    or an external receiver's store), residency-aware param refresh, and
    the jit'd prefill/decode entry points. Both the single-stream
    server and the slot pool extend this."""

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp", mesh=None):
        if resident not in RESIDENT_MODES:
            raise ValueError(
                f"resident must be one of {RESIDENT_MODES}, got {resident!r}")
        self.model = model
        self.prog = prog
        self.max_len = max_len
        self.resident = resident
        self.mesh = mesh
        self._receiver = receiver
        self.state = (None if receiver is not None
                      else ReceiverState.init(prog, mesh=mesh))
        self._consumed = 0  # receiver mode: stages reflected in params
        self.params = None  # live param pytree at current precision
        self._prefill = jax.jit(self._meshed(model.prefill))
        self._decode = jax.jit(self._meshed(model.decode_step))

    def _meshed(self, fn):
        """Wrap a model entry point so its *trace* runs under
        ``models.common.serving_mesh(self.mesh)``: every dispatch-helper
        output gets a replicated sharding constraint, which keeps all
        GSPMD-inserted collectives pure gathers (bit-exact — no sharded
        contractions, no partial-sum all-reduces; see
        ``launch.sharding.serving_spec_for_param``). Identity when the
        engine is single-device. The wrapper closes over the mesh value,
        not ``self``, so jit caching is unaffected."""
        if self.mesh is None:
            return fn
        mesh = self.mesh
        from repro.models.common import serving_mesh

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with serving_mesh(mesh):
                return fn(*args, **kwargs)
        return wrapped

    # -- precision management ------------------------------------------------
    @property
    def stage(self) -> int:
        if self._receiver is not None:
            return self._consumed
        return self.state.received_stages

    @property
    def stages_available(self) -> int:
        """Stages the server could upgrade to right now."""
        if self._receiver is not None:
            return self._receiver.stages_complete
        return self.prog.n_stages

    def decode_cache_size(self) -> int:
        """Compiled-executable count of the jitted decode step. The
        zero-recompile guarantee is exactly 'this stays 1 across every
        upgrade' — and for the slot pool, across every admission and
        eviction too."""
        return self._decode._cache_size()

    def _refresh_params(self) -> None:
        """Rebuild the live param pytree from the current accumulators
        at the current residency."""
        if self._receiver is not None:
            self.params = (self._receiver.materialize_resident()
                           if self.resident == "quantized"
                           else self._receiver.materialize())
        else:
            self.params = (self.state.materialize_resident(
                quantized_resident_eligible)
                if self.resident == "quantized"
                else self.state.materialize())

    def resident_report(self) -> dict:
        """Leaf-type audit of the *live* params (see
        :func:`resident_report`)."""
        if self.params is None:
            raise RuntimeError("no planes received yet")
        return resident_report(self.params)

    def receive_stage(self) -> None:
        """Pull the next stage's planes (server-push in a real
        deployment; here the planes live in ``self.prog``), or — in
        receiver mode — refresh params from the externally-fed store,
        catching up to every stage the receiver has completed.

        The OR is one batched ``plane_or_segments`` launch over the
        store's flat buffer. With ``resident="fp"`` the refresh is the
        store's incremental eq.-(5) materialize (only dirty tensors
        re-dequantize); with ``resident="quantized"`` it is a metadata
        refresh — new accumulator views + new traced scale/offset
        values, no weight dequantization anywhere."""
        t0 = time.perf_counter()
        if self._receiver is not None:
            avail = self._receiver.stages_complete
            if avail <= self._consumed:
                raise RuntimeError(
                    f"receiver has no new stage (at {avail}, "
                    f"served {self._consumed})")
            self._consumed = avail
            t1 = time.perf_counter()   # ingest happened externally
            self._refresh_params()
        else:
            s = self.state.received_stages + 1
            self.state = self.state.receive(self.prog.stage(s))
            t1 = time.perf_counter()
            self._refresh_params()
        # enqueue-time split consumed by upgrade_if_available's log
        self._last_upgrade_split = {
            "ingest_s": t1 - t0,
            "refresh_s": time.perf_counter() - t1,
        }
        if _obs.enabled():
            tr = _obs.get_tracer()
            tr.record("upgrade_ingest",
                      wall_s=self._last_upgrade_split["ingest_s"],
                      stage=self.stage)
            tr.record("upgrade_refresh",
                      wall_s=self._last_upgrade_split["refresh_s"],
                      stage=self.stage)


class ProgressiveServer(PrecisionManagedEngine):
    """Single lock-stepped request stream over device-resident plane
    accumulators + one jit'd decode step.

    Two feeding modes:

    * pull (default): ``receive_stage()`` ingests the next stage's
      planes from ``self.prog`` into the server's own ReceiverState
      (server-push in a real deployment).
    * receiver: constructed with ``receiver=`` (e.g.
      :class:`WireStoreReceiver` over the wire client's store) the
      server holds no accumulators of its own — ``receive_stage()``
      refreshes params from the externally-fed store. This is what the
      co-simulation :class:`~repro.transmission.session.Session` uses:
      bytes are ingested once, by the client.

    And two residency modes (``resident="fp" | "quantized"``), see the
    module docstring. Both serve the identical token stream — pinned by
    tests — but quantized residency allocates no fp weight buffers and
    upgrades without touching eq. (5) for the weights.
    """

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp", mesh=None):
        super().__init__(model, prog, max_len, receiver=receiver,
                         resident=resident, mesh=mesh)
        self.caches = None
        self.pos = 0

    # -- serving ---------------------------------------------------------------
    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        last_logits, caches = self._prefill(self.params, batch)
        self.caches = self.model.grow_caches(caches, self.max_len)
        self.pos = batch["tokens"].shape[1]
        self.last_logits = last_logits

    def decode(self, steps: int, *,
               stage_arrival: Callable[[int], bool] | None = None,
               sync: bool = False,
               dispatch_window: int = 8) -> GenerationResult:
        """Greedy-decode ``steps`` tokens; before each step, consult
        ``stage_arrival(step)`` — True means the next plane landed and we
        upgrade in place (KV cache untouched; checking is host-side
        bookkeeping, so it costs no device sync).

        Dispatch is asynchronous by default: greedy sampling chains on
        device and the host blocks only every ``dispatch_window`` steps,
        so ingest and token reads overlap decode. ``per_step_s`` is then
        *derived* (window wall time / steps in window); ``window_s``
        holds the honest measurements and ``ttft_s``/``tpot_s`` the
        serving-level latencies. ``sync=True`` restores the old
        block-per-token behavior and its per-token timings."""
        if sync:
            dispatch_window = 1
        toks = []
        stage_at, upgrades, per_step = [], [], []
        window_s: list[tuple[int, float]] = []
        logits = self.last_logits
        t_start = time.perf_counter()
        ttft = None
        win_t0 = t_start
        win_steps = 0
        for i in range(steps):
            if stage_arrival and self.stage < self.prog.n_stages and stage_arrival(i):
                self.receive_stage()
                upgrades.append((i, self.stage))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, self.caches = self._decode(
                self.params, self.caches, nxt, jnp.int32(self.pos)
            )
            self.pos += 1
            toks.append(nxt[:, 0])
            stage_at.append(self.stage)
            win_steps += 1
            if win_steps >= dispatch_window or i == steps - 1:
                jax.block_until_ready(logits)
                now = time.perf_counter()
                if ttft is None:
                    ttft = now - t_start
                dt = now - win_t0
                window_s.append((win_steps, dt))
                per_step.extend([dt / win_steps] * win_steps)
                if _obs.enabled():
                    _obs.get_tracer().record(
                        "decode_window", wall_s=dt, engine="single")
                win_t0 = now
                win_steps = 0
        total = time.perf_counter() - t_start
        self.last_logits = logits
        if _obs.enabled():
            reg = _obs.get_registry()
            reg.histogram("engine_ttft_s",
                          "wall seconds to first token value").observe(
                              ttft or 0.0, engine="single")
            reg.counter("engine_tokens_total",
                        "tokens emitted by serving engines").inc(
                            steps, engine="single")
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            stage_at_step=stage_at,
            upgrades=upgrades,
            per_step_s=per_step,
            window_s=window_s,
            ttft_s=ttft or 0.0,
            tpot_s=total / max(steps, 1),
            mode="sync" if sync else "async",
        )


# ---------------------------------------------------------------------------
# Continuous batching: the slot pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolRequest:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: Any                  # (S,) int32 token ids
    max_new_tokens: int
    extras: dict = dataclasses.field(default_factory=dict)
    # per-request fixed-size side inputs (e.g. "vision_embeds",
    # (vision_tokens, d_vision)), each WITHOUT the leading batch dim.
    # Prompt-derived encoder inputs ("enc_input") are not poolable —
    # see SlotPoolEngine.__init__


@dataclasses.dataclass
class _Slot:
    rid: int | None = None       # None = free
    dispatched: int = 0          # decode steps issued for this request
    budget: int = 0

    @property
    def free(self) -> bool:
        return self.rid is None


@dataclasses.dataclass
class PoolStepStats:
    """Host-visible outcome of a flushed dispatch window. The upgrade
    fields are the per-window overlap accounting: ``upgrades`` precision
    upgrades were enqueued while this window's steps were in flight, and
    enqueueing them held the host for ``upgrade_enqueue_s`` — the wall
    clock the window actually lost to upgrades (the device-side OR +
    refresh overlaps dispatched decode work)."""

    steps: int
    wall_s: float
    tokens_emitted: int
    upgrades: int = 0
    upgrade_enqueue_s: float = 0.0
    prefill_ticks: int = 0  # chunked-prefill blocks advanced this window


_RECURRENT_KINDS = ("mamba2", "mlstm", "slstm")
_CROSS_KINDS = ("cross", "selfcross")
_WINDOW_KINDS = ("swa", "swa_moe")


class SlotPoolEngine(PrecisionManagedEngine):
    """Continuous-batching progressive serving.

    A fixed pool of ``n_slots`` decode slots shares ONE cache pytree in
    the flash kernel's native ``(B, Kh, S, hd)`` layout, one live param
    pytree over the PlaneStore accumulators, and one compiled ragged
    ``decode_step`` (per-slot ``(B,)`` positions). Eviction just frees
    the host-side slot record. Neither admission nor eviction touches
    the decode executable.

    Admission is **chunked** by default (``chunked_prefill``): the
    prompt is staged host-side and consumed ``prefill_chunk`` tokens at
    a time by a batched ragged ``prefill_chunk`` launch that writes
    prompt KV straight into the slot's pooled cache rows — no batch-1
    prefill, no ``grow_caches``, no cache-sized copy on the admit path,
    and ONE compiled executable per chunk shape no matter how many
    distinct prompt lengths arrive (a flash crowd of novel lengths used
    to pay one prefill compile each). Chunk steps interleave with
    decode steps inside the dispatch window, so multiple queued
    requests make admission progress per window while resident slots
    keep decoding; a mid-prefill slot's device ``pos`` stays -1, which
    masks it out of every interleaved decode step (KV writes and
    recurrent-state updates included). Cross-attention archs (whose
    admission must run the vision/enc encoder) fall back to the legacy
    batch-1 path, with prompt lengths padded to power-of-two buckets
    (``prefill_buckets``) where masked positions are supported, so the
    prefill executable count is O(log max_len), not O(distinct
    lengths).

    Decode is dispatched in bounded asynchronous windows: within a
    window, greedy sampling chains device-side with no host sync;
    between windows the host reads token values, completes/evicts
    finished requests, admits queued ones, and applies precision
    upgrades — "batch-step granularity", zero recompiles (the PR-3
    traced ``received_bits`` invariant holds: nothing static changes).
    Upgrades are **zero-stall** by default (``double_buffer``): the
    PlaneStore ingest never donates its accumulators, so the OR +
    eq.-(5) refresh builds NEW buffers while in-flight steps read the
    old ones; ``upgrade_if_available`` just enqueues that work and the
    next dispatched step picks up the refreshed params in program
    order — no ``block_until_ready`` fence anywhere in the serving
    loop. Per-window overlap accounting lands in
    :class:`PoolStepStats`.

    Tokens emitted by a free slot are discarded on host; the kernel
    masks a free slot's whole cache row (``q_pos = -1``), so it costs
    one lane of the batched launch and never NaNs.
    """

    def __init__(self, model: Model, prog: ProgressiveModel, *,
                 n_slots: int, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp",
                 dispatch_window: int = 8,
                 eos_id: int | None = None,
                 ring_margin: int = 0,
                 chunked_prefill: bool | None = None,
                 prefill_chunk: int = 8,
                 prefill_buckets: bool = True,
                 double_buffer: bool = True,
                 mesh=None):
        super().__init__(model, prog, max_len, receiver=receiver,
                         resident=resident, mesh=mesh)
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if model.cfg.enc_layers:
            # audio enc-dec: the cross-cache length is prompt-derived
            # (enc frames = seq // divisor), so per-request caches don't
            # tile into one fixed pool cache without per-slot memory
            # masking — single-stream serving still covers these archs
            raise NotImplementedError(
                "SlotPoolEngine does not support encoder-decoder models "
                "with prompt-derived encoder lengths (cfg.enc_layers > 0); "
                "use ProgressiveServer")
        kinds = set(model.cfg.cycle) | set(model.cfg.tail)
        chunk_ok = not (kinds & set(_CROSS_KINDS))
        if chunked_prefill is None:
            chunked_prefill = chunk_ok
        elif chunked_prefill and not chunk_ok:
            raise NotImplementedError(
                "chunked prefill is not supported for cross-attention "
                "archs (admission must run the vision/enc encoder); use "
                "chunked_prefill=None to fall back automatically")
        self.chunked_prefill = bool(chunked_prefill)
        self.prefill_chunk = max(1, int(prefill_chunk))
        if self.chunked_prefill and model.cfg.window and \
                (kinds & set(_WINDOW_KINDS)):
            # a chunk writes prefill_chunk positions ahead of the oldest
            # live window entry — same over-allocation argument as
            # speculative verify blocks (attention.py ring check)
            ring_margin = max(ring_margin, self.prefill_chunk)
        self._ring_margin = ring_margin
        # masked-position padding is only sound for plain attention: a
        # sliding-window ring has no masked slots and a recurrent state
        # would consume the padding tokens
        self.prefill_buckets = bool(prefill_buckets) and not \
            (kinds & (set(_WINDOW_KINDS) | set(_RECURRENT_KINDS)))
        self.double_buffer = bool(double_buffer)
        self.n_slots = n_slots
        self.dispatch_window = max(1, dispatch_window)
        # ring_margin over-allocates sliding-window ring caches for
        # speculative verify blocks and prefill chunks
        self.caches = model.init_caches(n_slots, max_len,
                                        ring_margin=ring_margin)
        self.pos = jnp.full((n_slots,), -1, jnp.int32)
        self.last_logits = jnp.full((n_slots, model.cfg.vocab), 0.0,
                                    jnp.float32)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: list[PoolRequest] = []       # FIFO admission backlog
        self.outputs: dict[int, list[int]] = {}  # rid -> generated tokens
        self.stage_log: dict[int, list[int]] = {}  # rid -> stage per token
        self.admit_stage: dict[int, int] = {}      # rid -> prefill stage
        self.admitted_order: list[int] = []        # rids, actual admission
        self.completed: set[int] = set()
        self._retired: set[int] = set()  # evicted, final window not yet flushed
        # in-flight dispatched steps awaiting a flush:
        # (tokens (B,1) device array, {slot: rid} snapshot, stage)
        self._pending: list[tuple[Any, dict[int, int], int]] = []
        self._win_t0: float | None = None
        self.window_stats: list[PoolStepStats] = []
        self.upgrade_stall_s: float = 0.0    # host time blocked on upgrades
        self.upgrade_enqueue_s: float = 0.0  # host time enqueueing them
        self.upgrade_log: list[dict] = []    # per-upgrade overlap record
        self.upgrades: list[tuple[int, int]] = []  # (global step, stage)
        self._step_count = 0
        self._tick_count = 0  # chunked-prefill blocks consumed
        self._win_upgrades = 0
        self._win_upgrade_enqueue_s = 0.0
        self._win_prefill_ticks = 0
        # chunked admission: slot -> staged prompt + consumption offset;
        # slots here hold a request (not free) but are NOT decoding yet
        self._prefill_state: dict[int, dict] = {}
        self._chunk_step = jax.jit(self._meshed(_make_chunk_step(model)))
        # device-side companions updated by the chunk step when a slot's
        # prefill completes: the argmax of its last prompt row (the
        # request's first greedy token) lands in _last_tok (consumed by
        # the speculative pool's draft chain) and _first_cap (read at
        # flush for deferred first-token emission)
        self._last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._first_cap = jnp.zeros((n_slots,), jnp.int32)
        self._recurrent_cycle_keys = [
            f"{j}_{kind}" for j, kind in enumerate(model.cfg.cycle)
            if kind in _RECURRENT_KINDS]
        self._recurrent_tail_keys = [
            f"{i}_{kind}" for i, kind in enumerate(model.cfg.tail)
            if kind in _RECURRENT_KINDS]
        specs = model.input_specs(batch=1, seq_len=2, mode="prefill")
        self._extra_specs = {k: tuple(s.shape[1:])
                             for k, s in specs.items() if k != "tokens"}
        self._submit_t: dict[int, float] = {}   # rid -> submit wall time
        self.ttft_s: dict[int, float] = {}      # rid -> first-token latency
        # eos termination is checked at flush boundaries: a request may
        # decode up to dispatch_window - 1 tokens past its eos (the
        # standard async continuous-batching tradeoff); those trailing
        # tokens are dropped from its output
        self.eos_id = eos_id

    # -- admission / eviction ----------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_rids(self) -> dict[int, int]:
        """Slots actively DECODING: admitted, prefill complete. A slot
        mid-chunked-prefill holds a request (not free) but is excluded —
        it joins decode snapshots once its last prompt chunk lands."""
        return {i: s.rid for i, s in enumerate(self.slots)
                if not s.free and i not in self._prefill_state}

    def submit(self, request: PoolRequest) -> None:
        """Queue a request; it is admitted into the next free slot at
        the next admission point (immediately if one is free). A
        malformed request raises HERE — before any device work."""
        self._validate_request(request)
        self._submit_t[request.rid] = time.perf_counter()
        self.queue.append(request)
        self._admit_from_queue()

    def _validate_request(self, req: PoolRequest) -> None:
        """Host-side (numpy-level) validation: nothing is traced,
        transferred or launched before a request is known to be
        well-formed. In particular a (1, S) prompt is rejected outright
        rather than silently squeezing through batch-1 prefill, and a
        bad ``extras`` shape fails before the prefill launch."""
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"PoolRequest.prompt must be one-dimensional (S,), got "
                f"shape {prompt.shape}")
        if prompt.shape[0] < 1:
            raise ValueError("PoolRequest.prompt must hold >= 1 token")
        if prompt.shape[0] + req.max_new_tokens > self.max_len:
            # write positions reach prompt_len + budget - 1; past max_len
            # the cache write would silently clamp onto the last slot
            raise ValueError(
                f"request needs {prompt.shape[0]} prompt + "
                f"{req.max_new_tokens} new tokens > max_len {self.max_len}")
        for k, v in req.extras.items():
            if k not in self._extra_specs:
                raise ValueError(
                    f"unknown extras key {k!r}; this arch accepts "
                    f"{sorted(self._extra_specs)}")
            got, want = tuple(np.shape(v)), self._extra_specs[k]
            if got != want:
                raise ValueError(
                    f"extras[{k!r}] must have per-request shape {want} "
                    f"(no batch dim), got {got}")

    def _admit_from_queue(self) -> None:
        while self.queue and (free := self.free_slots()):
            self._admit(free[0], self.queue.pop(0))

    def _admit(self, slot: int, req: PoolRequest) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        prompt = np.asarray(req.prompt, np.int32)
        self.slots[slot] = _Slot(rid=req.rid, dispatched=0,
                                 budget=req.max_new_tokens)
        self.outputs.setdefault(req.rid, [])
        self.stage_log.setdefault(req.rid, [])
        self.admit_stage[req.rid] = self.stage
        self.admitted_order.append(req.rid)
        self._post_admit(slot, req, int(prompt.shape[0]))
        if self.chunked_prefill and not req.extras:
            self._begin_chunked_prefill(slot, req, prompt)
        else:
            self._admit_batch1(slot, req, prompt)

    def _post_admit(self, slot: int, req: PoolRequest,
                    prompt_len: int) -> None:
        """Subclass hook, called once per admission before the prompt
        is consumed (speculative pool: position ceiling bookkeeping)."""

    def _begin_chunked_prefill(self, slot: int, req: PoolRequest,
                               prompt: np.ndarray) -> None:
        """Chunked admission is host bookkeeping only: stage the prompt
        and let :meth:`_prefill_tick` consume it ``prefill_chunk``
        tokens per block, writing KV straight into the slot's pooled
        cache rows. No KV reset is needed — a prior occupant's stale
        rows are provably invisible (causal mask + decode overwrites
        position p before any query >= p exists; a ring assigns
        non-negative k_pos only to slots the new occupant has written).
        A RECURRENT state is cumulative rather than positional, so it
        IS zeroed here. The slot's device pos stays -1 until the last
        chunk lands, masking it out of interleaved decode steps."""
        self._reset_recurrent_slot(slot)
        self._prefill_state[slot] = {"prompt": prompt, "off": 0,
                                     "rid": req.rid,
                                     "len": int(prompt.shape[0])}

    def _admit_batch1(self, slot: int, req: PoolRequest,
                      prompt: np.ndarray) -> None:
        """Legacy admission: batch-1 prefill, grow to max_len, one
        per-leaf slot write. Kept for cross-attention archs (the
        vision/enc encoder runs here) and as the explicit
        ``chunked_prefill=False`` baseline. With ``prefill_buckets``
        the prompt is padded to a power-of-two bucket with masked
        positions, so this path compiles O(log max_len) prefill
        variants instead of one per distinct prompt length."""
        L = int(prompt.shape[0])
        tokens = jnp.asarray(prompt)[None, :]
        n_valid = None
        if self.prefill_buckets:
            bucket = min(max(1 << (L - 1).bit_length(), 1), self.max_len)
            if bucket > L:
                tokens = jnp.pad(tokens, ((0, 0), (0, bucket - L)))
            n_valid = jnp.asarray([L], jnp.int32)
        batch = {"tokens": tokens}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)[None]
        if n_valid is None:
            last_logits, caches = self._prefill(self.params, batch)
        else:
            last_logits, caches = self._prefill(self.params, batch, n_valid)
        caches = self._grow_admitted(caches, L)
        self.caches = _write_slot_tree(self.caches, caches, slot,
                                       self.n_slots)
        self.pos = self.pos.at[slot].set(L)
        self.last_logits = self.last_logits.at[slot].set(
            last_logits[0].astype(self.last_logits.dtype))
        self._post_admit_batch1(slot, req, last_logits, L)

    def _grow_admitted(self, caches, prompt_len: int):
        """Grow a batch-1 prefill's caches to pool shape (subclassed to
        repack sliding-window rings by the speculative margin)."""
        return self.model.grow_caches(caches, self.max_len)

    def _post_admit_batch1(self, slot: int, req: PoolRequest,
                           last_logits, prompt_len: int) -> None:
        """Subclass hook after a batch-1 admission's device writes
        (speculative pool: immediate first-token emission)."""

    def _reset_recurrent_slot(self, slot: int) -> None:
        """Zero one slot's recurrent-state rows (mamba2/mlstm/slstm
        caches are cumulative — unlike KV rows, a prior occupant's
        state would leak into the new request). Host-side .at[].set
        per recurrent block, nothing cache-sized is copied."""
        for key in self._recurrent_cycle_keys:
            # stacked over cycles: leaves are (R, B, ...)
            self.caches["cycles"][key] = jax.tree.map(
                lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)),
                self.caches["cycles"][key])
        for key in self._recurrent_tail_keys:
            self.caches["tail"][key] = jax.tree.map(
                lambda a: a.at[slot].set(jnp.zeros((), a.dtype)),
                self.caches["tail"][key])

    def _prefill_tick(self) -> None:
        """Advance every mid-prefill slot by one (B, chunk) block — a
        single batched ``prefill_chunk`` launch; free and decoding
        slots ride along fully masked (tok_pos = -1). When a slot's
        last prompt token is inside this block, the device side
        installs its end position, last-row logits and first greedy
        token, so the slot joins the next decode snapshot with no host
        sync."""
        if not self._prefill_state:
            return
        C, B = self.prefill_chunk, self.n_slots
        toks = np.zeros((B, C), np.int32)
        tpos = np.full((B, C), -1, np.int32)
        frow = np.full((B,), -1, np.int32)
        done: list[int] = []
        for slot, st in self._prefill_state.items():
            off, L = st["off"], st["len"]
            if off == 0:
                # the stage the prompt is actually consumed at — an
                # upgrade may land between submit and the first chunk
                # tick. (Chunks beyond the first are not re-recorded: a
                # mid-prefill upgrade makes a single "prefill stage"
                # ill-defined; parity tests pin the upgrade-free case.)
                self.admit_stage[st["rid"]] = self.stage
            n = min(C, L - off)
            toks[slot, :n] = st["prompt"][off:off + n]
            tpos[slot, :n] = np.arange(off, off + n, dtype=np.int32)
            if off + n == L:
                frow[slot] = n - 1
                done.append(slot)
            st["off"] = off + n
        (self.caches, self.pos, self.last_logits, self._last_tok,
         self._first_cap) = self._chunk_step(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(tpos),
            jnp.asarray(frow), self.pos, self.last_logits, self._last_tok,
            self._first_cap)
        self._tick_count += 1
        self._win_prefill_ticks += 1
        for slot in done:
            del self._prefill_state[slot]
            self._on_prefill_complete(slot)

    def _on_prefill_complete(self, slot: int) -> None:
        """Subclass hook when a slot's chunked prefill finishes
        (speculative pool: deferred first-token emission)."""

    def prefill_cache_size(self) -> int:
        """Compiled-executable count on the ADMISSION path — the
        admission analogue of :meth:`decode_cache_size`. Chunked mode:
        one per chunk shape (one, in practice). Batch-1 mode: one per
        prompt-length bucket (O(log max_len) with ``prefill_buckets``,
        one per distinct length without)."""
        if self.chunked_prefill:
            return self._chunk_step._cache_size()
        return self._prefill._cache_size()

    def _note_first_token(self, rid: int) -> None:
        t = self._submit_t.get(rid)
        if t is not None and rid not in self.ttft_s:
            self.ttft_s[rid] = time.perf_counter() - t
            if _obs.enabled():
                _obs.get_registry().histogram(
                    "engine_ttft_s",
                    "wall seconds to first token value").observe(
                        self.ttft_s[rid], engine=type(self).__name__)

    def _evict(self, slot: int) -> int:
        rid = self.slots[slot].rid
        self.slots[slot] = _Slot()
        self.pos = self.pos.at[slot].set(-1)
        self._retired.add(rid)  # completed once its last window flushes
        return rid

    # -- batched ragged decode ---------------------------------------------
    def step(self) -> dict[int, int]:
        """One scheduling tick: advance chunked prefills by one block
        (if any are staged), then dispatch ONE batched decode step for
        every decoding slot (free and mid-prefill slots ride along
        masked). Returns the ``{slot: rid}`` snapshot of who the decode
        step ran for — empty when nothing is decoding yet. No host sync
        happens here, for either half."""
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        if self._win_t0 is None:
            self._win_t0 = time.perf_counter()
        self._prefill_tick()
        snapshot = self.active_rids()
        if not snapshot:
            return snapshot
        nxt = jnp.argmax(self.last_logits, axis=-1).astype(jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, self.caches, nxt,
                                           self.pos)
        active = jnp.asarray(
            [i in snapshot for i in range(self.n_slots)], dtype=bool)
        self.pos = jnp.where(active, self.pos + 1, self.pos)
        self.last_logits = logits
        self._pending.append((nxt, snapshot, self.stage))
        self._step_count += 1
        # dispatch-time bookkeeping: budgets decrement without reading
        # token values, so length-complete slots free immediately
        for slot in snapshot:
            s = self.slots[slot]
            s.dispatched += 1
            if s.dispatched >= s.budget:
                self._evict(slot)
        return snapshot

    def flush(self) -> PoolStepStats | None:
        """Block on the in-flight window, distribute token values to
        their requests, complete eos/budget-finished ones."""
        if not self._pending:
            return None
        jax.block_until_ready(self.last_logits)
        toks = np.asarray(jnp.concatenate([t for t, _, _ in self._pending],
                                          axis=1))  # (B, n_pending)
        wall = time.perf_counter() - (self._win_t0 or time.perf_counter())
        emitted = 0
        eos_hit: set[int] = set()
        for j, (_, snapshot, stage) in enumerate(self._pending):
            for slot, rid in snapshot.items():
                if rid in eos_hit:
                    continue
                tok = int(toks[slot, j])
                if not self.outputs[rid]:
                    self._note_first_token(rid)
                self.outputs[rid].append(tok)
                self.stage_log[rid].append(stage)
                emitted += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos_hit.add(rid)
                    # the slot may already be freed by budget bookkeeping
                    if not self.slots[slot].free and \
                            self.slots[slot].rid == rid:
                        self._evict(slot)
        # every retired request's final in-flight tokens just landed;
        # incremental, so a long-lived pool never rescans its history
        self.completed |= self._retired
        self._retired.clear()
        stats = PoolStepStats(steps=len(self._pending), wall_s=wall,
                              tokens_emitted=emitted,
                              upgrades=self._win_upgrades,
                              upgrade_enqueue_s=self._win_upgrade_enqueue_s,
                              prefill_ticks=self._win_prefill_ticks)
        return self._record_window(stats)

    def _record_window(self, stats: PoolStepStats) -> PoolStepStats:
        """Window chokepoint shared with the speculative pool: append
        to the legacy ``window_stats`` view, reset the per-window
        accumulators, mirror the stats into the telemetry registry."""
        self.window_stats.append(stats)
        self._pending.clear()
        self._win_t0 = None
        self._win_upgrades = 0
        self._win_upgrade_enqueue_s = 0.0
        self._win_prefill_ticks = 0
        if _obs.enabled():
            engine = type(self).__name__
            reg = _obs.get_registry()
            reg.counter("engine_tokens_total",
                        "tokens emitted by serving engines").inc(
                            stats.tokens_emitted, engine=engine)
            reg.counter("engine_prefill_ticks_total",
                        "chunked prefill ticks").inc(
                            stats.prefill_ticks, engine=engine)
            reg.histogram("engine_window_steps",
                          "decode steps per flushed window").observe(
                              stats.steps, engine=engine)
            _obs.get_tracer().record("decode_window", wall_s=stats.wall_s,
                                     engine=engine)
        return stats

    def upgrade_if_available(self) -> bool:
        """Apply newly-arrived precision: in receiver mode this catches
        up to every stage the externally-fed store has completed; in
        pull mode (no receiver) it advances ONE stage per call — the
        caller models the arrival cadence, exactly like
        ``ProgressiveServer.decode``'s ``stage_arrival``.

        With ``double_buffer=True`` (default) this only ENQUEUES the
        upgrade: ``plane_or_segments`` never donates the store's
        accumulators, so the OR + eq.-(5) refresh builds new buffers
        while in-flight decode steps keep reading the old ones —
        functional double buffering, no fence, and the next dispatched
        step consumes the refreshed params in device program order.
        The host cost is the enqueue time alone (``upgrade_enqueue_s``,
        also surfaced per window in :class:`PoolStepStats`).
        ``double_buffer=False`` restores the old
        ``block_until_ready`` fence for A/B stall measurement; either
        way ``upgrade_stall_s`` records the honest measured host-
        blocked time and ``upgrade_log`` the per-upgrade split."""
        if self.stage >= self.prog.n_stages or \
                self.stages_available <= self.stage:
            return False
        t0 = time.perf_counter()
        self.receive_stage()
        enqueue_s = time.perf_counter() - t0
        if not self.double_buffer:
            jax.block_until_ready(jax.tree.leaves(self.params))
        stall_s = time.perf_counter() - t0
        self.upgrade_enqueue_s += enqueue_s
        self.upgrade_stall_s += stall_s
        self._win_upgrades += 1
        self._win_upgrade_enqueue_s += enqueue_s
        split = getattr(self, "_last_upgrade_split", None) or {}
        self._record_upgrade({
            "step": self._step_count, "stage": self.stage,
            "enqueue_s": enqueue_s, "stall_s": stall_s,
            # enqueue split: host time ingesting planes (store OR
            # dispatch; ~0 in receiver mode where the wire client
            # ingested) vs refreshing the resident param views. The
            # fence component (stall - enqueue) is 0 with double_buffer.
            "ingest_s": split.get("ingest_s", 0.0),
            "refresh_s": split.get("refresh_s", 0.0),
            "fence_s": stall_s - enqueue_s,
            "sharded": self.mesh is not None,
            "double_buffer": self.double_buffer})
        self.upgrades.append((self._step_count, self.stage))
        return True

    def _record_upgrade(self, rec: dict) -> None:
        """Upgrade chokepoint: the legacy ``upgrade_log`` record plus
        registry counters/histograms over the same values."""
        self.upgrade_log.append(rec)
        if _obs.enabled():
            engine = type(self).__name__
            reg = _obs.get_registry()
            reg.counter("engine_upgrades_total",
                        "precision upgrades applied").inc(
                            engine=engine, stage=rec["stage"])
            reg.histogram("engine_upgrade_enqueue_s",
                          "host enqueue seconds per upgrade").observe(
                              rec["enqueue_s"], engine=engine)
            reg.histogram("engine_upgrade_stall_s",
                          "host-blocked seconds per upgrade").observe(
                              rec["stall_s"], engine=engine)

    def run(self, *, max_steps: int = 100_000,
            on_window: Callable[[int], None] | None = None) -> dict[int, list[int]]:
        """Drive the pool until every submitted request completes.
        ``on_window(step_count)`` runs at every window boundary (the
        session uses it to feed bytes / admit staggered arrivals /
        upgrade)."""
        while (any(not s.free for s in self.slots) or self.queue):
            for _ in range(self.dispatch_window):
                if not any(not s.free for s in self.slots):
                    break
                self.step()
                if self._step_count >= max_steps:
                    break
            self.flush()
            self._admit_from_queue()
            if on_window is not None:
                on_window(self._step_count)
            if self._step_count >= max_steps:
                break
        self.flush()
        return {rid: list(v) for rid, v in self.outputs.items()}


def _make_chunk_step(model: Model):
    """Build the jitted chunked-admission step: consume one (B, C)
    prompt block into the pooled caches and, for slots whose final
    prompt token is inside this block (``final_row[b] >= 0`` = its row
    index), install their decode handoff state device-side — end
    position, last-row logits, and the argmax first token (into both
    the last-token chain and the first-token capture buffer). Slots
    with ``final_row = -1`` (mid-prompt, decoding, free) pass their
    state through untouched. ONE executable per (B, C) shape serves
    every admission regardless of prompt length."""

    def chunk_step(params, caches, tokens, tok_pos, final_row, pos,
                   last_logits, last_tok, first_cap):
        logits, caches = model.prefill_chunk(params, caches, tokens,
                                             tok_pos)
        C = tokens.shape[1]
        row = jnp.clip(final_row, 0, C - 1)
        sel = jnp.take_along_axis(logits, row[:, None, None],
                                  axis=1)[:, 0]               # (B, V)
        done = final_row >= 0
        last_logits = jnp.where(done[:, None],
                                sel.astype(last_logits.dtype), last_logits)
        end = jnp.take_along_axis(tok_pos, row[:, None], axis=1)[:, 0] + 1
        pos = jnp.where(done, end, pos)
        first = jnp.argmax(sel, axis=-1).astype(jnp.int32)
        last_tok = jnp.where(done[:, None], first[:, None], last_tok)
        first_cap = jnp.where(done, first, first_cap)
        return caches, pos, last_logits, last_tok, first_cap

    return chunk_step


def _write_slot_tree(pool, one, slot: int, n_slots: int):
    """Write a batch-1 cache pytree into batch row ``slot`` of the pool
    cache pytree. The batch axis of each leaf is located structurally:
    it is the one axis where the pool leaf is ``n_slots`` wide and the
    single-request leaf is 1 (leaves with identical shapes — n_slots ==
    1 — are replaced outright)."""

    def write(p, o):
        if p.shape == o.shape:
            return o.astype(p.dtype)
        cand = [d for d, (a, b) in enumerate(zip(p.shape, o.shape))
                if a != b]
        if len(cand) != 1 or o.shape[cand[0]] != 1 or \
                p.shape[cand[0]] != n_slots:
            raise ValueError(
                f"cannot locate batch axis: pool {p.shape} vs one {o.shape}")
        start = [0] * p.ndim
        start[cand[0]] = slot
        return jax.lax.dynamic_update_slice(p, o.astype(p.dtype), start)

    return jax.tree.map(write, pool, one)
