"""Progressive serving engine.

The deployment story of the paper, pod-side: a server starts with the
MSB planes of the weights, begins serving immediately, and upgrades
precision *in place* between decode steps as later planes arrive. The KV
cache and the compiled decode executable survive upgrades (weight
values change; shapes/dtypes don't), so an upgrade costs one integer
OR + dequantize — no recompilation, no cache invalidation, no request
draining. That is the TPU-serving analogue of the paper's Fig. 4
concurrent download/inference timeline.

The accumulators live in the shared PlaneStore (via ``ReceiverState``):
a stage upgrade is one batched integer Pallas launch over the flat
buffer, and re-dequantization touches only the tensors that actually
received planes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.progressive import ProgressiveModel, ReceiverState
from repro.models.model import Model


@dataclasses.dataclass
class GenerationResult:
    tokens: Any           # (B, steps) generated token ids
    stage_at_step: list   # precision stage used for each decode step
    upgrades: list        # (step, stage) upgrade events
    per_step_s: list


class ProgressiveServer:
    """Holds device-resident plane accumulators + a jit'd decode step."""

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int):
        self.model = model
        self.prog = prog
        self.max_len = max_len
        self.state = ReceiverState.init(prog)
        self.params = None  # materialized at current precision
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.caches = None
        self.pos = 0

    # -- precision management ------------------------------------------------
    @property
    def stage(self) -> int:
        return self.state.received_stages

    def receive_stage(self) -> None:
        """Pull the next stage's planes (server-push in a real
        deployment; here the planes live in ``self.prog``).

        The OR is one batched ``plane_or_segments`` launch over the
        store's flat buffer, and the materialize is incremental: only
        tensors whose accumulator changed are re-dequantized — tensors
        whose schedule is exhausted (or that missed this shipment) come
        back as the *same* cached array objects, so the jitted decode
        sees an unchanged buffer for them."""
        s = self.state.received_stages + 1
        self.state = self.state.receive(self.prog.stage(s))
        self.params = self.state.materialize()

    # -- serving ---------------------------------------------------------------
    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        last_logits, caches = self._prefill(self.params, batch)
        self.caches = self.model.grow_caches(caches, self.max_len)
        self.pos = batch["tokens"].shape[1]
        self.last_logits = last_logits

    def decode(self, steps: int, *, stage_arrival: Callable[[int], bool] | None = None) -> GenerationResult:
        """Greedy-decode ``steps`` tokens; before each step, consult
        ``stage_arrival(step)`` — True means the next plane landed and we
        upgrade in place (KV cache untouched)."""
        toks = []
        stage_at, upgrades, per_step = [], [], []
        logits = self.last_logits
        for i in range(steps):
            if stage_arrival and self.stage < self.prog.n_stages and stage_arrival(i):
                self.receive_stage()
                upgrades.append((i, self.stage))
            t0 = time.perf_counter()
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, self.caches = self._decode(
                self.params, self.caches, nxt, jnp.int32(self.pos)
            )
            jax.block_until_ready(logits)
            per_step.append(time.perf_counter() - t0)
            self.pos += 1
            toks.append(nxt[:, 0])
            stage_at.append(self.stage)
        self.last_logits = logits
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            stage_at_step=stage_at,
            upgrades=upgrades,
            per_step_s=per_step,
        )
