"""Progressive serving engine: single-stream server + continuous-
batching slot pool.

The deployment story of the paper, pod-side: a server starts with the
MSB planes of the weights, begins serving immediately, and upgrades
precision *in place* between decode steps as later planes arrive. The KV
cache and the compiled decode executable survive upgrades (weight
values change; shapes/dtypes don't), so an upgrade costs one integer
OR + dequantize — no recompilation, no cache invalidation, no request
draining. That is the TPU-serving analogue of the paper's Fig. 4
concurrent download/inference timeline.

Two engines share the precision machinery:

* :class:`ProgressiveServer` — the lock-stepped single stream (every
  slot at the same position). Kept for parity baselines, prefix tests
  and the Fig.-4 co-simulation.
* :class:`SlotPoolEngine` — continuous batching: a fixed pool of
  ``n_slots`` decode slots over ONE set of device caches in the flash
  kernel's native ``(B, Kh, S, hd)`` layout. Requests are admitted into
  free slots mid-flight (their prompt prefilled straight into the
  slot's cache region), finished requests are evicted, and every step
  is one batched ragged ``decode_step`` — per-slot ``(B,)`` positions,
  one compiled executable for the lifetime of the pool, upgrades
  applied between batched steps at zero recompiles.

Both engines dispatch **asynchronously**: the device is never host-
synced per token. Greedy sampling chains on device (argmax feeds the
next step), and the host only blocks on a bounded in-flight window
(``dispatch_window`` steps) before reading token values — so plane
ingest, admission bookkeeping and upgrade scheduling all overlap device
decode. ``sync=True`` restores the old block-per-token behavior (and
its per-token timing semantics) for comparable benchmarks.

The accumulators live in the shared PlaneStore: a stage upgrade is one
batched integer Pallas launch over the flat buffer. What the decode
step *sees* is governed by ``resident``:

* ``resident="fp"`` (paper): each upgrade re-dequantizes the dirty
  tensors into float leaves (incremental eq. 5) — a full fp copy of the
  model lives in HBM next to the accumulators.
* ``resident="quantized"`` (SLIDE-style): the live param pytree holds
  :class:`~repro.core.quantize.QuantizedTensor` *views* over the
  accumulators; eq. (5) runs fused into every matmul
  (``kernels/dequant_matmul``) and no fp weight buffer ever exists.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.progressive import ProgressiveModel, ReceiverState, rebuild_params
from repro.core.quantize import QuantizedTensor
from repro.models.common import quantized_resident_eligible
from repro.models.model import Model

RESIDENT_MODES = ("fp", "quantized")


@dataclasses.dataclass
class GenerationResult:
    tokens: Any           # (B, steps) generated token ids
    stage_at_step: list   # precision stage used for each decode step
    upgrades: list        # (step, stage) upgrade events
    per_step_s: list      # sync: measured per token; async: window_s/steps
    window_s: list = dataclasses.field(default_factory=list)
    #                    # (steps_in_window, wall_seconds) per flushed window
    ttft_s: float = 0.0   # wall time until the first token's value is on host
    tpot_s: float = 0.0   # total wall time / steps
    mode: str = "sync"    # "sync" (block per token) | "async" (windowed)


def resident_report(params) -> dict:
    """Leaf-type audit of a live param pytree: how many leaves are
    quantized-resident vs float, and the HBM bytes each side holds.
    ``quantized_bytes`` counts the uint accumulator views (what a
    quantized-resident server actually keeps for its weights);
    ``fp_bytes`` counts float leaves — for ``resident='quantized'``
    that is only the small non-matmul remainder (norms, gates, conv
    kernels), and the audit is exactly the acceptance check that no fp
    weight buffer exists.

    Buffers are counted ONCE per distinct array object: a speculative
    engine's draft view shares the target view's accumulators (and the
    fp remainder) verbatim, so auditing ``(target, draft)`` together
    shows zero extra resident weight bytes next to the target alone —
    ``aliased_leaves`` counts the shared ones. ``effective_bits`` maps
    each quantized leaf's path to its served precision
    ``min(received_bits, keep_bits)``, which is what tells a draft view
    apart from the full view (the buffers are identical)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    n_q = n_fp = q_bytes = fp_bytes = meta_bytes = aliased = 0
    eff_bits: dict[str, int] = {}
    seen: set[int] = set()
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        if isinstance(leaf, QuantizedTensor):
            n_q += 1
            if id(leaf.q) in seen:
                aliased += 1
            else:
                seen.add(id(leaf.q))
                q_bytes += leaf.q.size * leaf.q.dtype.itemsize
            for m in (leaf.lo, leaf.hi, leaf.scale, leaf.offset,
                      leaf.received_bits, leaf.keep_bits):
                if m is not None:
                    meta_bytes += np.size(m) * m.dtype.itemsize
            eff = leaf.bits
            if leaf.received_bits is not None:
                eff = int(np.max(np.asarray(leaf.received_bits)))
            if leaf.keep_bits is not None:
                eff = min(eff, int(np.max(np.asarray(leaf.keep_bits))))
            eff_bits[pstr] = eff
        else:
            n_fp += 1
            if id(leaf) in seen:
                aliased += 1
            else:
                seen.add(id(leaf))
                fp_bytes += np.size(leaf) * jnp.asarray(leaf).dtype.itemsize
    return {"quantized_leaves": n_q, "fp_leaves": n_fp,
            "quantized_bytes": q_bytes, "fp_bytes": fp_bytes,
            "metadata_bytes": meta_bytes, "aliased_leaves": aliased,
            "effective_bits": eff_bits}


class WireStoreReceiver:
    """Adapts a wire-fed :class:`~repro.transmission.client.ProgressiveClient`
    as a server's parameter source, so the *same* device-resident
    PlaneStore that the byte stream fills is the one the server decodes
    from — no second ingest, no second set of Pallas launches.

    ``materialize`` reads only *completed* stages: it goes straight to
    the store without flushing the client's pending partial-stage
    planes, so the served params are exactly the stage prefix
    (bit-identical to ``transmit_reconstruct`` at that stage) —
    mid-stage planes land with their stage's completion flush.
    """

    def __init__(self, client, prog: ProgressiveModel):
        self.client = client
        self.prog = prog

    @property
    def stages_complete(self) -> int:
        return self.client.stages_complete

    @property
    def store(self):
        return self.client.store

    def materialize(self):
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.materialize_leaves()
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)

    def materialize_resident(self, eligible=quantized_resident_eligible,
                             *, bits=None):
        """Quantized-resident view over the client's store: weight
        leaves stay QuantizedTensor accumulator views; this is the
        'metadata refresh' of an upgrade — no ``materialize()`` at
        all for the weights. ``bits=b`` yields the truncated-precision
        draft view (same accumulators, zero extra weight bytes)."""
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.quantized_leaves(eligible=eligible,
                                                    bits=bits)
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)


class PrecisionManagedEngine:
    """Shared precision machinery: plane accumulators (own ReceiverState
    or an external receiver's store), residency-aware param refresh, and
    the jit'd prefill/decode entry points. Both the single-stream
    server and the slot pool extend this."""

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp"):
        if resident not in RESIDENT_MODES:
            raise ValueError(
                f"resident must be one of {RESIDENT_MODES}, got {resident!r}")
        self.model = model
        self.prog = prog
        self.max_len = max_len
        self.resident = resident
        self._receiver = receiver
        self.state = None if receiver is not None else ReceiverState.init(prog)
        self._consumed = 0  # receiver mode: stages reflected in params
        self.params = None  # live param pytree at current precision
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    # -- precision management ------------------------------------------------
    @property
    def stage(self) -> int:
        if self._receiver is not None:
            return self._consumed
        return self.state.received_stages

    @property
    def stages_available(self) -> int:
        """Stages the server could upgrade to right now."""
        if self._receiver is not None:
            return self._receiver.stages_complete
        return self.prog.n_stages

    def decode_cache_size(self) -> int:
        """Compiled-executable count of the jitted decode step. The
        zero-recompile guarantee is exactly 'this stays 1 across every
        upgrade' — and for the slot pool, across every admission and
        eviction too."""
        return self._decode._cache_size()

    def _refresh_params(self) -> None:
        """Rebuild the live param pytree from the current accumulators
        at the current residency."""
        if self._receiver is not None:
            self.params = (self._receiver.materialize_resident()
                           if self.resident == "quantized"
                           else self._receiver.materialize())
        else:
            self.params = (self.state.materialize_resident(
                quantized_resident_eligible)
                if self.resident == "quantized"
                else self.state.materialize())

    def resident_report(self) -> dict:
        """Leaf-type audit of the *live* params (see
        :func:`resident_report`)."""
        if self.params is None:
            raise RuntimeError("no planes received yet")
        return resident_report(self.params)

    def receive_stage(self) -> None:
        """Pull the next stage's planes (server-push in a real
        deployment; here the planes live in ``self.prog``), or — in
        receiver mode — refresh params from the externally-fed store,
        catching up to every stage the receiver has completed.

        The OR is one batched ``plane_or_segments`` launch over the
        store's flat buffer. With ``resident="fp"`` the refresh is the
        store's incremental eq.-(5) materialize (only dirty tensors
        re-dequantize); with ``resident="quantized"`` it is a metadata
        refresh — new accumulator views + new traced scale/offset
        values, no weight dequantization anywhere."""
        if self._receiver is not None:
            avail = self._receiver.stages_complete
            if avail <= self._consumed:
                raise RuntimeError(
                    f"receiver has no new stage (at {avail}, "
                    f"served {self._consumed})")
            self._consumed = avail
            self._refresh_params()
            return
        s = self.state.received_stages + 1
        self.state = self.state.receive(self.prog.stage(s))
        self._refresh_params()


class ProgressiveServer(PrecisionManagedEngine):
    """Single lock-stepped request stream over device-resident plane
    accumulators + one jit'd decode step.

    Two feeding modes:

    * pull (default): ``receive_stage()`` ingests the next stage's
      planes from ``self.prog`` into the server's own ReceiverState
      (server-push in a real deployment).
    * receiver: constructed with ``receiver=`` (e.g.
      :class:`WireStoreReceiver` over the wire client's store) the
      server holds no accumulators of its own — ``receive_stage()``
      refreshes params from the externally-fed store. This is what the
      co-simulation :class:`~repro.transmission.session.Session` uses:
      bytes are ingested once, by the client.

    And two residency modes (``resident="fp" | "quantized"``), see the
    module docstring. Both serve the identical token stream — pinned by
    tests — but quantized residency allocates no fp weight buffers and
    upgrades without touching eq. (5) for the weights.
    """

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp"):
        super().__init__(model, prog, max_len, receiver=receiver,
                         resident=resident)
        self.caches = None
        self.pos = 0

    # -- serving ---------------------------------------------------------------
    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        last_logits, caches = self._prefill(self.params, batch)
        self.caches = self.model.grow_caches(caches, self.max_len)
        self.pos = batch["tokens"].shape[1]
        self.last_logits = last_logits

    def decode(self, steps: int, *,
               stage_arrival: Callable[[int], bool] | None = None,
               sync: bool = False,
               dispatch_window: int = 8) -> GenerationResult:
        """Greedy-decode ``steps`` tokens; before each step, consult
        ``stage_arrival(step)`` — True means the next plane landed and we
        upgrade in place (KV cache untouched; checking is host-side
        bookkeeping, so it costs no device sync).

        Dispatch is asynchronous by default: greedy sampling chains on
        device and the host blocks only every ``dispatch_window`` steps,
        so ingest and token reads overlap decode. ``per_step_s`` is then
        *derived* (window wall time / steps in window); ``window_s``
        holds the honest measurements and ``ttft_s``/``tpot_s`` the
        serving-level latencies. ``sync=True`` restores the old
        block-per-token behavior and its per-token timings."""
        if sync:
            dispatch_window = 1
        toks = []
        stage_at, upgrades, per_step = [], [], []
        window_s: list[tuple[int, float]] = []
        logits = self.last_logits
        t_start = time.perf_counter()
        ttft = None
        win_t0 = t_start
        win_steps = 0
        for i in range(steps):
            if stage_arrival and self.stage < self.prog.n_stages and stage_arrival(i):
                self.receive_stage()
                upgrades.append((i, self.stage))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, self.caches = self._decode(
                self.params, self.caches, nxt, jnp.int32(self.pos)
            )
            self.pos += 1
            toks.append(nxt[:, 0])
            stage_at.append(self.stage)
            win_steps += 1
            if win_steps >= dispatch_window or i == steps - 1:
                jax.block_until_ready(logits)
                now = time.perf_counter()
                if ttft is None:
                    ttft = now - t_start
                dt = now - win_t0
                window_s.append((win_steps, dt))
                per_step.extend([dt / win_steps] * win_steps)
                win_t0 = now
                win_steps = 0
        total = time.perf_counter() - t_start
        self.last_logits = logits
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            stage_at_step=stage_at,
            upgrades=upgrades,
            per_step_s=per_step,
            window_s=window_s,
            ttft_s=ttft or 0.0,
            tpot_s=total / max(steps, 1),
            mode="sync" if sync else "async",
        )


# ---------------------------------------------------------------------------
# Continuous batching: the slot pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolRequest:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: Any                  # (S,) int32 token ids
    max_new_tokens: int
    extras: dict = dataclasses.field(default_factory=dict)
    # per-request fixed-size side inputs (e.g. "vision_embeds",
    # (vision_tokens, d_vision)), each WITHOUT the leading batch dim.
    # Prompt-derived encoder inputs ("enc_input") are not poolable —
    # see SlotPoolEngine.__init__


@dataclasses.dataclass
class _Slot:
    rid: int | None = None       # None = free
    dispatched: int = 0          # decode steps issued for this request
    budget: int = 0

    @property
    def free(self) -> bool:
        return self.rid is None


@dataclasses.dataclass
class PoolStepStats:
    """Host-visible outcome of a flushed dispatch window."""

    steps: int
    wall_s: float
    tokens_emitted: int


class SlotPoolEngine(PrecisionManagedEngine):
    """Continuous-batching progressive serving.

    A fixed pool of ``n_slots`` decode slots shares ONE cache pytree in
    the flash kernel's native ``(B, Kh, S, hd)`` layout, one live param
    pytree over the PlaneStore accumulators, and one compiled ragged
    ``decode_step`` (per-slot ``(B,)`` positions). Admission prefills a
    request's prompt with batch 1 and writes the resulting caches into
    the slot's batch row (``dynamic_update_slice`` per leaf — packed
    prefill); eviction just frees the host-side slot record. Neither
    touches the decode executable.

    Decode is dispatched in bounded asynchronous windows: within a
    window, greedy sampling chains device-side with no host sync;
    between windows the host reads token values, completes/evicts
    finished requests, admits queued ones, and applies precision
    upgrades — "batch-step granularity", zero recompiles (the PR-3
    traced ``received_bits`` invariant holds: nothing static changes).

    Tokens emitted by a free slot are discarded on host; the kernel
    masks a free slot's whole cache row (``q_pos = -1``), so it costs
    one lane of the batched launch and never NaNs.

    One caveat: admission prefills at batch 1 through the jitted
    ``model.prefill``, which compiles once per DISTINCT prompt length —
    a novel length admitted mid-flight stalls dispatch for that
    compile. Production deployments should bucket prompts to a small
    set of lengths; the decode executable is unaffected (always exactly
    one).
    """

    def __init__(self, model: Model, prog: ProgressiveModel, *,
                 n_slots: int, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp",
                 dispatch_window: int = 8,
                 eos_id: int | None = None,
                 ring_margin: int = 0):
        super().__init__(model, prog, max_len, receiver=receiver,
                         resident=resident)
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if model.cfg.enc_layers:
            # audio enc-dec: the cross-cache length is prompt-derived
            # (enc frames = seq // divisor), so per-request caches don't
            # tile into one fixed pool cache without per-slot memory
            # masking — single-stream serving still covers these archs
            raise NotImplementedError(
                "SlotPoolEngine does not support encoder-decoder models "
                "with prompt-derived encoder lengths (cfg.enc_layers > 0); "
                "use ProgressiveServer")
        self.n_slots = n_slots
        self.dispatch_window = max(1, dispatch_window)
        # ring_margin over-allocates sliding-window ring caches for
        # speculative verify blocks (see serving/speculative.py)
        self.caches = model.init_caches(n_slots, max_len,
                                        ring_margin=ring_margin)
        self.pos = jnp.full((n_slots,), -1, jnp.int32)
        self.last_logits = jnp.full((n_slots, model.cfg.vocab), 0.0,
                                    jnp.float32)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: list[PoolRequest] = []       # FIFO admission backlog
        self.outputs: dict[int, list[int]] = {}  # rid -> generated tokens
        self.stage_log: dict[int, list[int]] = {}  # rid -> stage per token
        self.admit_stage: dict[int, int] = {}      # rid -> prefill stage
        self.admitted_order: list[int] = []        # rids, actual admission
        self.completed: set[int] = set()
        self._retired: set[int] = set()  # evicted, final window not yet flushed
        # in-flight dispatched steps awaiting a flush:
        # (tokens (B,1) device array, {slot: rid} snapshot, stage)
        self._pending: list[tuple[Any, dict[int, int], int]] = []
        self._win_t0: float | None = None
        self.window_stats: list[PoolStepStats] = []
        self.upgrade_stall_s: float = 0.0
        self.upgrades: list[tuple[int, int]] = []  # (global step, stage)
        self._step_count = 0
        # eos termination is checked at flush boundaries: a request may
        # decode up to dispatch_window - 1 tokens past its eos (the
        # standard async continuous-batching tradeoff); those trailing
        # tokens are dropped from its output
        self.eos_id = eos_id

    # -- admission / eviction ----------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_rids(self) -> dict[int, int]:
        return {i: s.rid for i, s in enumerate(self.slots) if not s.free}

    def submit(self, request: PoolRequest) -> None:
        """Queue a request; it is admitted into the next free slot at
        the next admission point (immediately if one is free)."""
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.queue.append(request)
        self._admit_from_queue()

    def _admit_from_queue(self) -> None:
        while self.queue and (free := self.free_slots()):
            self._admit(free[0], self.queue.pop(0))

    def _admit(self, slot: int, req: PoolRequest) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        prompt = jnp.asarray(req.prompt, jnp.int32)
        if prompt.ndim != 1:
            raise ValueError("PoolRequest.prompt must be (S,)")
        if prompt.shape[0] + req.max_new_tokens > self.max_len:
            # write positions reach prompt_len + budget - 1; past max_len
            # the cache write would silently clamp onto the last slot
            raise ValueError(
                f"request needs {prompt.shape[0]} prompt + "
                f"{req.max_new_tokens} new tokens > max_len {self.max_len}")
        batch = {"tokens": prompt[None, :]}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)[None]
        last_logits, caches = self._prefill(self.params, batch)
        caches = self.model.grow_caches(caches, self.max_len)
        self.caches = _write_slot_tree(self.caches, caches, slot,
                                       self.n_slots)
        self.pos = self.pos.at[slot].set(prompt.shape[0])
        self.last_logits = self.last_logits.at[slot].set(
            last_logits[0].astype(self.last_logits.dtype))
        self.slots[slot] = _Slot(rid=req.rid, dispatched=0,
                                 budget=req.max_new_tokens)
        self.outputs.setdefault(req.rid, [])
        self.stage_log.setdefault(req.rid, [])
        self.admit_stage[req.rid] = self.stage
        self.admitted_order.append(req.rid)

    def _evict(self, slot: int) -> int:
        rid = self.slots[slot].rid
        self.slots[slot] = _Slot()
        self.pos = self.pos.at[slot].set(-1)
        self._retired.add(rid)  # completed once its last window flushes
        return rid

    # -- batched ragged decode ---------------------------------------------
    def step(self) -> dict[int, int]:
        """Dispatch ONE batched decode step for every slot (free slots
        ride along masked). Returns the ``{slot: rid}`` snapshot of who
        the step decoded for. No host sync happens here."""
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        if self._win_t0 is None:
            self._win_t0 = time.perf_counter()
        snapshot = self.active_rids()
        nxt = jnp.argmax(self.last_logits, axis=-1).astype(jnp.int32)[:, None]
        logits, self.caches = self._decode(self.params, self.caches, nxt,
                                           self.pos)
        active = jnp.asarray(
            [not s.free for s in self.slots], dtype=bool)
        self.pos = jnp.where(active, self.pos + 1, self.pos)
        self.last_logits = logits
        self._pending.append((nxt, snapshot, self.stage))
        self._step_count += 1
        # dispatch-time bookkeeping: budgets decrement without reading
        # token values, so length-complete slots free immediately
        for slot, s in enumerate(self.slots):
            if not s.free:
                s.dispatched += 1
                if s.dispatched >= s.budget:
                    self._evict(slot)
        return snapshot

    def flush(self) -> PoolStepStats | None:
        """Block on the in-flight window, distribute token values to
        their requests, complete eos/budget-finished ones."""
        if not self._pending:
            return None
        jax.block_until_ready(self.last_logits)
        toks = np.asarray(jnp.concatenate([t for t, _, _ in self._pending],
                                          axis=1))  # (B, n_pending)
        wall = time.perf_counter() - (self._win_t0 or time.perf_counter())
        emitted = 0
        eos_hit: set[int] = set()
        for j, (_, snapshot, stage) in enumerate(self._pending):
            for slot, rid in snapshot.items():
                if rid in eos_hit:
                    continue
                tok = int(toks[slot, j])
                self.outputs[rid].append(tok)
                self.stage_log[rid].append(stage)
                emitted += 1
                if self.eos_id is not None and tok == self.eos_id:
                    eos_hit.add(rid)
                    # the slot may already be freed by budget bookkeeping
                    if not self.slots[slot].free and \
                            self.slots[slot].rid == rid:
                        self._evict(slot)
        # every retired request's final in-flight tokens just landed;
        # incremental, so a long-lived pool never rescans its history
        self.completed |= self._retired
        self._retired.clear()
        stats = PoolStepStats(steps=len(self._pending), wall_s=wall,
                              tokens_emitted=emitted)
        self.window_stats.append(stats)
        self._pending.clear()
        self._win_t0 = None
        return stats

    def upgrade_if_available(self) -> bool:
        """Apply newly-arrived precision: in receiver mode this catches
        up to every stage the externally-fed store has completed; in
        pull mode (no receiver) it advances ONE stage per call — the
        caller models the arrival cadence, exactly like
        ``ProgressiveServer.decode``'s ``stage_arrival``. Timed into
        ``upgrade_stall_s`` (the only serving-loop work allowed to
        stall dispatch)."""
        if self.stage >= self.prog.n_stages or \
                self.stages_available <= self.stage:
            return False
        t0 = time.perf_counter()
        self.receive_stage()
        jax.block_until_ready(jax.tree.leaves(self.params))
        self.upgrade_stall_s += time.perf_counter() - t0
        self.upgrades.append((self._step_count, self.stage))
        return True

    def run(self, *, max_steps: int = 100_000,
            on_window: Callable[[int], None] | None = None) -> dict[int, list[int]]:
        """Drive the pool until every submitted request completes.
        ``on_window(step_count)`` runs at every window boundary (the
        session uses it to feed bytes / admit staggered arrivals /
        upgrade)."""
        while (any(not s.free for s in self.slots) or self.queue):
            for _ in range(self.dispatch_window):
                if not any(not s.free for s in self.slots):
                    break
                self.step()
                if self._step_count >= max_steps:
                    break
            self.flush()
            self._admit_from_queue()
            if on_window is not None:
                on_window(self._step_count)
            if self._step_count >= max_steps:
                break
        self.flush()
        return {rid: list(v) for rid, v in self.outputs.items()}


def _write_slot_tree(pool, one, slot: int, n_slots: int):
    """Write a batch-1 cache pytree into batch row ``slot`` of the pool
    cache pytree. The batch axis of each leaf is located structurally:
    it is the one axis where the pool leaf is ``n_slots`` wide and the
    single-request leaf is 1 (leaves with identical shapes — n_slots ==
    1 — are replaced outright)."""

    def write(p, o):
        if p.shape == o.shape:
            return o.astype(p.dtype)
        cand = [d for d, (a, b) in enumerate(zip(p.shape, o.shape))
                if a != b]
        if len(cand) != 1 or o.shape[cand[0]] != 1 or \
                p.shape[cand[0]] != n_slots:
            raise ValueError(
                f"cannot locate batch axis: pool {p.shape} vs one {o.shape}")
        start = [0] * p.ndim
        start[cand[0]] = slot
        return jax.lax.dynamic_update_slice(p, o.astype(p.dtype), start)

    return jax.tree.map(write, pool, one)
