"""Progressive serving engine.

The deployment story of the paper, pod-side: a server starts with the
MSB planes of the weights, begins serving immediately, and upgrades
precision *in place* between decode steps as later planes arrive. The KV
cache and the compiled decode executable survive upgrades (weight
values change; shapes/dtypes don't), so an upgrade costs one integer
OR + dequantize — no recompilation, no cache invalidation, no request
draining. That is the TPU-serving analogue of the paper's Fig. 4
concurrent download/inference timeline.

The accumulators live in the shared PlaneStore: a stage upgrade is one
batched integer Pallas launch over the flat buffer. What the decode
step *sees* is governed by ``resident``:

* ``resident="fp"`` (paper): each upgrade re-dequantizes the dirty
  tensors into float leaves (incremental eq. 5) — a full fp copy of the
  model lives in HBM next to the accumulators.
* ``resident="quantized"`` (SLIDE-style): the live param pytree holds
  :class:`~repro.core.quantize.QuantizedTensor` *views* over the
  accumulators; eq. (5) runs fused into every matmul
  (``kernels/dequant_matmul``) and no fp weight buffer ever exists. An
  upgrade is the store ingest plus a metadata refresh (new traced
  scale/offset values) — the jitted ``decode_step`` keeps exactly one
  cache entry across every upgrade, because nothing static changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.progressive import ProgressiveModel, ReceiverState, rebuild_params
from repro.core.quantize import QuantizedTensor
from repro.models.common import quantized_resident_eligible
from repro.models.model import Model

RESIDENT_MODES = ("fp", "quantized")


@dataclasses.dataclass
class GenerationResult:
    tokens: Any           # (B, steps) generated token ids
    stage_at_step: list   # precision stage used for each decode step
    upgrades: list        # (step, stage) upgrade events
    per_step_s: list


def resident_report(params) -> dict:
    """Leaf-type audit of a live param pytree: how many leaves are
    quantized-resident vs float, and the HBM bytes each side holds.
    ``quantized_bytes`` counts the uint accumulator views (what a
    quantized-resident server actually keeps for its weights);
    ``fp_bytes`` counts float leaves — for ``resident='quantized'``
    that is only the small non-matmul remainder (norms, gates, conv
    kernels), and the audit is exactly the acceptance check that no fp
    weight buffer exists."""
    leaves = jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    n_q = n_fp = q_bytes = fp_bytes = meta_bytes = 0
    for leaf in leaves:
        if isinstance(leaf, QuantizedTensor):
            n_q += 1
            q_bytes += leaf.q.size * leaf.q.dtype.itemsize
            for m in (leaf.lo, leaf.hi, leaf.scale, leaf.offset,
                      leaf.received_bits):
                if m is not None:
                    meta_bytes += np.size(m) * m.dtype.itemsize
        else:
            n_fp += 1
            fp_bytes += np.size(leaf) * jnp.asarray(leaf).dtype.itemsize
    return {"quantized_leaves": n_q, "fp_leaves": n_fp,
            "quantized_bytes": q_bytes, "fp_bytes": fp_bytes,
            "metadata_bytes": meta_bytes}


class WireStoreReceiver:
    """Adapts a wire-fed :class:`~repro.transmission.client.ProgressiveClient`
    as a server's parameter source, so the *same* device-resident
    PlaneStore that the byte stream fills is the one the server decodes
    from — no second ingest, no second set of Pallas launches.

    ``materialize`` reads only *completed* stages: it goes straight to
    the store without flushing the client's pending partial-stage
    planes, so the served params are exactly the stage prefix
    (bit-identical to ``transmit_reconstruct`` at that stage) —
    mid-stage planes land with their stage's completion flush.
    """

    def __init__(self, client, prog: ProgressiveModel):
        self.client = client
        self.prog = prog

    @property
    def stages_complete(self) -> int:
        return self.client.stages_complete

    @property
    def store(self):
        return self.client.store

    def materialize(self):
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.materialize_leaves()
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)

    def materialize_resident(self, eligible=quantized_resident_eligible):
        """Quantized-resident view over the client's store: weight
        leaves stay QuantizedTensor accumulator views; this is the
        'metadata refresh' of an upgrade — no ``materialize()`` at
        all for the weights."""
        if self.client.store is None:
            raise RuntimeError("wire header not received yet")
        leaves = self.client.store.quantized_leaves(eligible=eligible)
        return rebuild_params(self.prog, leaves, key_fn=wire.path_str)


class ProgressiveServer:
    """Holds device-resident plane accumulators + a jit'd decode step.

    Two feeding modes:

    * pull (default): ``receive_stage()`` ingests the next stage's
      planes from ``self.prog`` into the server's own ReceiverState
      (server-push in a real deployment).
    * receiver: constructed with ``receiver=`` (e.g.
      :class:`WireStoreReceiver` over the wire client's store) the
      server holds no accumulators of its own — ``receive_stage()``
      refreshes params from the externally-fed store. This is what the
      co-simulation :class:`~repro.transmission.session.Session` uses:
      bytes are ingested once, by the client.

    And two residency modes (``resident="fp" | "quantized"``), see the
    module docstring. Both serve the identical token stream — pinned by
    tests — but quantized residency allocates no fp weight buffers and
    upgrades without touching eq. (5) for the weights.
    """

    def __init__(self, model: Model, prog: ProgressiveModel, max_len: int,
                 receiver: WireStoreReceiver | None = None,
                 resident: str = "fp"):
        if resident not in RESIDENT_MODES:
            raise ValueError(
                f"resident must be one of {RESIDENT_MODES}, got {resident!r}")
        self.model = model
        self.prog = prog
        self.max_len = max_len
        self.resident = resident
        self._receiver = receiver
        self.state = None if receiver is not None else ReceiverState.init(prog)
        self._consumed = 0  # receiver mode: stages reflected in params
        self.params = None  # live param pytree at current precision
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self.caches = None
        self.pos = 0

    # -- precision management ------------------------------------------------
    @property
    def stage(self) -> int:
        if self._receiver is not None:
            return self._consumed
        return self.state.received_stages

    @property
    def stages_available(self) -> int:
        """Stages the server could upgrade to right now."""
        if self._receiver is not None:
            return self._receiver.stages_complete
        return self.prog.n_stages

    def decode_cache_size(self) -> int:
        """Compiled-executable count of the jitted decode step. The
        zero-recompile guarantee of quantized residency is exactly
        'this stays 1 across every upgrade'."""
        return self._decode._cache_size()

    def _refresh_params(self) -> None:
        """Rebuild the live param pytree from the current accumulators
        at the current residency."""
        if self._receiver is not None:
            self.params = (self._receiver.materialize_resident()
                           if self.resident == "quantized"
                           else self._receiver.materialize())
        else:
            self.params = (self.state.materialize_resident(
                quantized_resident_eligible)
                if self.resident == "quantized"
                else self.state.materialize())

    def resident_report(self) -> dict:
        """Leaf-type audit of the *live* params (see
        :func:`resident_report`)."""
        if self.params is None:
            raise RuntimeError("no planes received yet")
        return resident_report(self.params)

    def receive_stage(self) -> None:
        """Pull the next stage's planes (server-push in a real
        deployment; here the planes live in ``self.prog``), or — in
        receiver mode — refresh params from the externally-fed store,
        catching up to every stage the receiver has completed.

        The OR is one batched ``plane_or_segments`` launch over the
        store's flat buffer. With ``resident="fp"`` the refresh is the
        store's incremental eq.-(5) materialize (only dirty tensors
        re-dequantize); with ``resident="quantized"`` it is a metadata
        refresh — new accumulator views + new traced scale/offset
        values, no weight dequantization anywhere."""
        if self._receiver is not None:
            avail = self._receiver.stages_complete
            if avail <= self._consumed:
                raise RuntimeError(
                    f"receiver has no new stage (at {avail}, "
                    f"served {self._consumed})")
            self._consumed = avail
            self._refresh_params()
            return
        s = self.state.received_stages + 1
        self.state = self.state.receive(self.prog.stage(s))
        self._refresh_params()

    # -- serving ---------------------------------------------------------------
    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        last_logits, caches = self._prefill(self.params, batch)
        self.caches = self.model.grow_caches(caches, self.max_len)
        self.pos = batch["tokens"].shape[1]
        self.last_logits = last_logits

    def decode(self, steps: int, *, stage_arrival: Callable[[int], bool] | None = None) -> GenerationResult:
        """Greedy-decode ``steps`` tokens; before each step, consult
        ``stage_arrival(step)`` — True means the next plane landed and we
        upgrade in place (KV cache untouched)."""
        toks = []
        stage_at, upgrades, per_step = [], [], []
        logits = self.last_logits
        for i in range(steps):
            if stage_arrival and self.stage < self.prog.n_stages and stage_arrival(i):
                self.receive_stage()
                upgrades.append((i, self.stage))
            t0 = time.perf_counter()
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            logits, self.caches = self._decode(
                self.params, self.caches, nxt, jnp.int32(self.pos)
            )
            jax.block_until_ready(logits)
            per_step.append(time.perf_counter() - t0)
            self.pos += 1
            toks.append(nxt[:, 0])
            stage_at.append(self.stage)
        self.last_logits = logits
        return GenerationResult(
            tokens=jnp.stack(toks, axis=1),
            stage_at_step=stage_at,
            upgrades=upgrades,
            per_step_s=per_step,
        )
