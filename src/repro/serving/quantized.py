"""Quantized-resident serving path (beyond-paper, TPU-native).

The paper's client materializes fp32 weights after each concatenation.
On a TPU pod that wastes HBM (16 GiB/chip) and bandwidth: a 90B-param
fp32 materialization is 360 GB, but the 16-bit accumulators are 180 GB
and an 8-bit prefix is 90 GB. This module keeps weights *quantized in
HBM* and fuses eq. (4)+(5) into the consumer matmul via the Pallas
kernel (`kernels/dequant_matmul`):

    y = x @ dequant(acc)      # dequant runs in VMEM, per tile

An upgrade is `plane_or` (pure integer VPU) on the resident accumulator;
no fp copy of the model ever exists. `QuantizedLinearState` is the
device-resident artifact; `QuantizedModelState` manages a pytree of
them + the upgrade schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.bitplanes import PlaneSchedule
from repro.core.progressive import ProgressiveModel
from repro.kernels import ops


@dataclasses.dataclass
class QuantizedLinearState:
    """One weight matrix, resident as a k-bit accumulator."""

    acc: jax.Array           # (d_in, d_out) uint container
    lo: jax.Array
    hi: jax.Array
    schedule: PlaneSchedule
    received: int = 0        # planes OR-ed in so far

    @property
    def received_bits(self) -> int:
        if self.received == 0:
            return 0
        return self.schedule.cumulative_bits[self.received - 1]

    def upgrade(self, plane: jax.Array) -> "QuantizedLinearState":
        """OR the next plane in place (eq. 4) — integer work only."""
        s = self.received + 1
        if s > self.schedule.n_planes:
            raise ValueError("all planes already received")
        shift = self.schedule.bits - self.schedule.cumulative_bits[s - 1]
        acc = ops.plane_or(self.acc, plane.astype(self.acc.dtype), shift=shift)
        return dataclasses.replace(self, acc=acc, received=s)

    def matmul(self, x: jax.Array, **kw) -> jax.Array:
        """x @ dequant(acc) without materializing the fp weight (eq. 5
        fused into the MXU feed)."""
        return ops.dequant_matmul(
            x, self.acc, self.lo, self.hi,
            bits=self.schedule.bits, received_bits=self.received_bits, **kw
        )

    @property
    def resident_bytes(self) -> int:
        return self.acc.size * self.acc.dtype.itemsize


def from_progressive(model: ProgressiveModel, tensor_idx: int,
                     planes_upto: int = 0) -> QuantizedLinearState:
    """Build a resident state for one 2-D tensor of a divided model."""
    t = model.tensors[tensor_idx]
    if len(t.shape) != 2:
        raise ValueError(f"dequant matmul path needs a 2-D weight, got {t.shape}")
    from repro.core.quantize import container_dtype

    st = QuantizedLinearState(
        acc=jnp.zeros(t.shape, container_dtype(t.bits)),
        lo=t.lo, hi=t.hi,
        schedule=t.plan.schedule,
    )
    for s in range(planes_upto):
        st = st.upgrade(t.planes[s])
    return st
