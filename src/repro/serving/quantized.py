"""Single-tensor view of the quantized-resident serving path.

Historically this module was the proof-of-concept fork: one weight
matrix held as a PlaneStore view, with its own upgrade/matmul plumbing.
The whole-model path now lives in the engine —
``ProgressiveServer(resident="quantized")`` decodes every matmul of the
transformer straight from the accumulators via the leaf dispatch in
``models/common`` — and this module is reduced to a thin *view* helper
kept for microbenchmarks and tensor-level tests.

Two deliberate changes from the old fork:

* ``upgrade()`` ingests **in place**. The old implementation snapshotted
  the *entire* flat store buffer (``store.copy()``) per single plane —
  on a shared whole-model store that pinned a second copy of every
  accumulator per upgrade. Shared-store deployments push planes through
  ``store.ingest`` once; every view sees them immediately.
* ``matmul`` feeds the kernel the traced eq.-(5) affine from the one
  shared :func:`~repro.core.quantize.dequant_affine` helper — the same
  numbers the engine's dispatch uses, so this view cannot drift from
  the serving path.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.bitplanes import PlaneSchedule
from repro.core.plane_store import PlaneStore
from repro.core.progressive import ProgressiveModel
from repro.core.quantize import dequant_affine
from repro.kernels import ops


@dataclasses.dataclass
class QuantizedLinearState:
    """One weight matrix, resident as a view into a PlaneStore segment."""

    store: PlaneStore
    idx: int = 0

    def __post_init__(self):
        if len(self.store.slots[self.idx].shape) != 2:
            raise ValueError(
                "dequant matmul path needs a 2-D weight, got "
                f"{self.store.slots[self.idx].shape}")

    @property
    def acc(self) -> jax.Array:
        return self.store.acc(self.idx)

    @property
    def lo(self) -> jax.Array:
        return self.store.slots[self.idx].lo

    @property
    def hi(self) -> jax.Array:
        return self.store.slots[self.idx].hi

    @property
    def schedule(self) -> PlaneSchedule:
        return self.store.slots[self.idx].schedule

    @property
    def received(self) -> int:
        return self.store.received[self.idx]

    @property
    def received_bits(self) -> int:
        return self.store.effective_bits(self.idx)

    def upgrade(self, plane: jax.Array) -> "QuantizedLinearState":
        """OR the next plane into the resident store (eq. 4), *in
        place*: shared-store deployments must see one ingest, not a
        forked snapshot — the old per-plane ``store.copy()`` pinned a
        second copy of the whole flat buffer. Returns ``self`` so
        chained call sites keep reading naturally."""
        self.store.ingest([(self.idx, plane)])
        return self

    def matmul(self, x: jax.Array, **kw) -> jax.Array:
        """x @ dequant(acc) without materializing the fp weight (eq. 5
        fused into the MXU feed, affine from the shared helper)."""
        scale, offset = dequant_affine(
            self.lo, self.hi, self.schedule.bits, self.received_bits)
        return ops.dequant_matmul(x, self.acc, scale, offset, **kw)

    @property
    def resident_bytes(self) -> int:
        """Device bytes of this tensor's segment, including the
        block-alignment padding it actually occupies."""
        t = self.store.slots[self.idx]
        return t.padded * np.dtype(t.container).itemsize


def from_progressive(model: ProgressiveModel, tensor_idx: int,
                     planes_upto: int = 0,
                     store: PlaneStore | None = None) -> QuantizedLinearState:
    """View one 2-D tensor of a divided model as a resident linear
    state. Pass an existing ``store`` to share residency with other
    consumers (engine, client); ``planes_upto`` planes are then ingested
    into that store (visible to every consumer — the view never forks).
    Without ``store``, a private single-tensor store is built (one
    tensor's buffer, not the whole model's)."""
    t = model.tensors[tensor_idx]
    if store is None:
        store = PlaneStore.from_model(model, indices=[tensor_idx])
        idx = 0
    else:
        # Resolve by identity, not position: subset stores (built with
        # from_model(indices=...)) have a compacted slot space.
        idx = next(
            (i for i, s in enumerate(store.slots)
             if s.key == t.path and s.slice_idx == t.slice_idx), None)
        if idx is None:
            raise ValueError(
                f"store holds no slot for tensor {tensor_idx} "
                f"(path {t.path})")
    # ``planes_upto`` means "at least this many planes resident": planes
    # the store already holds are never re-OR-ed (that would corrupt the
    # accumulator at a stale shift).
    for s in range(store.received[idx], planes_upto):
        store.ingest([(idx, t.planes[s])])
    return QuantizedLinearState(store=store, idx=idx)
