"""Quantized-resident serving path (beyond-paper, TPU-native).

The paper's client materializes fp32 weights after each concatenation.
On a TPU pod that wastes HBM (16 GiB/chip) and bandwidth: a 90B-param
fp32 materialization is 360 GB, but the 16-bit accumulators are 180 GB
and an 8-bit prefix is 90 GB. This module keeps weights *quantized in
HBM* and fuses eq. (4)+(5) into the consumer matmul via the Pallas
kernel (`kernels/dequant_matmul`):

    y = x @ dequant(acc)      # dequant runs in VMEM, per tile

The accumulators themselves live in a shared
:class:`~repro.core.plane_store.PlaneStore` — the same runtime the
pytree receiver and the byte-stream client use — so an upgrade is the
store's batched `plane_or_segments` (pure integer VPU) and a
`QuantizedLinearState` is a zero-copy *view* of one tensor's segment:
no fp copy of the model ever exists, and no OR/shift arithmetic is
re-derived here.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.bitplanes import PlaneSchedule
from repro.core.plane_store import PlaneStore
from repro.core.progressive import ProgressiveModel
from repro.kernels import ops


@dataclasses.dataclass
class QuantizedLinearState:
    """One weight matrix, resident as a view into a PlaneStore segment."""

    store: PlaneStore
    idx: int = 0

    def __post_init__(self):
        if len(self.store.slots[self.idx].shape) != 2:
            raise ValueError(
                "dequant matmul path needs a 2-D weight, got "
                f"{self.store.slots[self.idx].shape}")

    @property
    def acc(self) -> jax.Array:
        return self.store.acc(self.idx)

    @property
    def lo(self) -> jax.Array:
        return self.store.slots[self.idx].lo

    @property
    def hi(self) -> jax.Array:
        return self.store.slots[self.idx].hi

    @property
    def schedule(self) -> PlaneSchedule:
        return self.store.slots[self.idx].schedule

    @property
    def received(self) -> int:
        return self.store.received[self.idx]

    @property
    def received_bits(self) -> int:
        return self.store.effective_bits(self.idx)

    def upgrade(self, plane: jax.Array) -> "QuantizedLinearState":
        """OR the next plane into the resident store (eq. 4) — one
        batched integer launch, shift arithmetic owned by the store."""
        store = self.store.copy()
        store.ingest([(self.idx, plane)])
        return dataclasses.replace(self, store=store)

    def matmul(self, x: jax.Array, **kw) -> jax.Array:
        """x @ dequant(acc) without materializing the fp weight (eq. 5
        fused into the MXU feed)."""
        return ops.dequant_matmul(
            x, self.acc, self.lo, self.hi,
            bits=self.schedule.bits, received_bits=self.received_bits, **kw
        )

    @property
    def resident_bytes(self) -> int:
        """Device bytes of this tensor's segment, including the
        block-alignment padding it actually occupies."""
        t = self.store.slots[self.idx]
        return t.padded * np.dtype(t.container).itemsize


def from_progressive(model: ProgressiveModel, tensor_idx: int,
                     planes_upto: int = 0,
                     store: PlaneStore | None = None) -> QuantizedLinearState:
    """View one 2-D tensor of a divided model as a resident linear
    state. Pass an existing ``store`` to share residency with other
    consumers (engine, client); ``planes_upto`` planes are then ingested
    into that store (visible to every consumer — the view never forks).
    Note ``upgrade()`` on the returned state IS functional and snapshots
    the store, so shared-store deployments should keep pushing planes
    through ``store.ingest`` and treat the state as a read view. Without
    ``store``, a private single-tensor store is built (one tensor's
    buffer, not the whole model's)."""
    t = model.tensors[tensor_idx]
    if store is None:
        store = PlaneStore.from_model(model, indices=[tensor_idx])
        idx = 0
    else:
        # Resolve by identity, not position: subset stores (built with
        # from_model(indices=...)) have a compacted slot space.
        idx = next(
            (i for i, s in enumerate(store.slots)
             if s.key == t.path and s.slice_idx == t.slice_idx), None)
        if idx is None:
            raise ValueError(
                f"store holds no slot for tensor {tensor_idx} "
                f"(path {t.path})")
    # ``planes_upto`` means "at least this many planes resident": planes
    # the store already holds are never re-OR-ed (that would corrupt the
    # accumulator at a stale shift).
    for s in range(store.received[idx], planes_upto):
        store.ingest([(idx, t.planes[s])])
    return QuantizedLinearState(store=store, idx=idx)
