"""Self-speculative progressive decoding: the precision ladder as a
draft model.

The paper's core asset is that every prefix of the transmitted file is
itself a working approximate model. The quantized-resident engines
(PR 3/4) made those approximations live; this module makes them *pay
rent*: a truncated-precision view of the **same** PlaneStore
accumulators (``PlaneStore.quantized_leaves(bits=b)`` — a deferred
plane mask plus a recomputed eq.-(5) affine, sharing every uint buffer
with the target view) drafts k greedy tokens, and the full-received-
bits view verifies the whole draft in ONE chunked pass
(``model.verify_step`` -> ``ops.verify_attention``). Output is
token-identical to plain greedy decode at every precision stage: the
verify logits at a draft row equal what sequential target decode would
have produced there, so accepted drafts plus the correction token ARE
the plain greedy stream (losslessness, pinned by tests).

Speculation round (per slot; batched and ragged across slots):

    last ──draft──► d1..dk        (k decode_steps, draft view,
      │                            draft K/V written in place)
      └──[last,d1..dk]──verify──► g0..gk = target greedy per row
                                  (ONE verify_step; target K/V
                                   overwrites the draft's rows)
    accept a = longest prefix with d_{t+1} == g_t
    emit g0..ga  (a accepted drafts + 1 correction/bonus token)
    next round feeds g_a at pos + a + 1 — rejected rows are never
    rolled back, later rounds just overwrite them (zero cache copies;
    ring caches are over-allocated by ``k_max + 1`` slots so
    speculative writes can never clobber live window entries).

Cost shape: the draft pass reads the same accumulators as the target
(zero extra weight bytes is the point), so the win is *batching*: one
verify pass scores k+1 tokens in a single weight/cache sweep and the
host syncs once per round instead of once per token. On TPU the verify
kernel amortizes the whole KV cache read over the draft block; on this
CPU container the same effect shows up as round-level dispatch/sync
amortization (see ``benchmarks/speculative_decode.py`` for the honest
accounting).

Both serving shapes are covered: :class:`SpeculativeEngine` is the
lock-stepped single stream (slots start together, then run *ragged* —
each slot accepts a different number of drafts per round);
:class:`SpeculativeSlotPool` is the continuous-batching pool where
admissions, evictions and precision upgrades interleave with
speculation rounds. Upgrades refresh BOTH views from the same store
(metadata only) and change nothing static — zero recompiles
mid-speculation; exactly two decode executables exist (the draft's
``decode_step`` and the target's ``verify_step``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core.policy import SpeculationController
from repro.models.common import quantized_resident_eligible
from repro.serving.engine import (PoolStepStats, ProgressiveServer,
                                  SlotPoolEngine, resident_report)


@dataclasses.dataclass
class SpecConfig:
    """How to speculate. ``k=None`` hands draft-length control to an
    adaptive :class:`~repro.core.policy.SpeculationController` (k then
    moves on a power-of-two ladder with the observed acceptance rate,
    and collapses to 0 while the download hasn't passed ``draft_bits``
    yet); a fixed integer pins it (the benchmark sweeps do this —
    each distinct k compiles one draft/verify executable pair)."""

    draft_bits: int = 4
    k: int | None = None
    k_max: int = 8

    def __post_init__(self):
        if self.k is not None and self.k < 0:
            raise ValueError(f"k must be >= 0, got {self.k}")
        if self.k is not None and self.k > self.k_max:
            # honor the requested draft length: k_max sizes the ring
            # margins and max_len headroom, so it must cover k
            self.k_max = self.k

    def make_controller(self) -> SpeculationController:
        k0 = self.k if self.k is not None else min(4, self.k_max)
        return SpeculationController(draft_bits=self.draft_bits,
                                     k_max=self.k_max, k_init=max(k0, 1))


@dataclasses.dataclass
class SpeculativeResult:
    """Outcome of a speculative generation. ``tokens`` is the plain
    greedy stream (B, steps); speculation internals ride alongside."""

    tokens: Any
    stage_log: list          # per slot: stage at each emitted token
    upgrades: list           # (min emitted tokens, new stage)
    accept_rounds: list      # per round: dict(k, accepted, rate, stage)
    rounds: int = 0
    drafted: int = 0         # draft tokens proposed (active slots only)
    accepted: int = 0        # draft tokens accepted
    wall_s: float = 0.0
    ttft_s: float = 0.0

    @property
    def stage_at_step(self):
        """Lock-step view (slot 0's log) for plain-path compatibility."""
        return self.stage_log[0] if self.stage_log else []

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0


def _verify_and_accept(model, params, caches, tokens, pos):
    """One target verify pass + on-device acceptance.

    tokens: (B, T) = last accepted token ++ k drafts; pos: (B,) base
    positions (negative = inactive slot). Returns ``(g, acc, nxt,
    caches)``: ``g[:, t]`` is the target's greedy token after consuming
    ``tokens[:, :t+1]`` (the plain-greedy continuation), ``acc`` the
    per-slot count of accepted drafts (longest matching prefix), and
    ``nxt = g[:, acc]`` the correction/bonus token that seeds the next
    round. Everything stays on device; the host reads g/acc once per
    round."""
    logits, caches = model.verify_step(params, caches, tokens, pos)
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)          # (B, T)
    if tokens.shape[1] > 1:
        match = (tokens[:, 1:] == g[:, :-1]).astype(jnp.int32)  # (B, k)
        acc = jnp.cumprod(match, axis=1).sum(axis=1)            # (B,)
    else:
        acc = jnp.zeros(tokens.shape[:1], jnp.int32)
    nxt = jnp.take_along_axis(g, acc[:, None], axis=1)          # (B, 1)
    return g, acc, nxt, caches


class _SpeculativeMixin:
    """Draft-view plumbing shared by the single-stream engine and the
    slot pool: refresh both precision views from ONE store, count both
    executables, audit the zero-extra-bytes invariant."""

    _SSM_KINDS = frozenset({"mamba2", "mlstm", "slstm"})

    def _init_spec(self, spec: SpecConfig | None):
        cfg = self.model.cfg
        ssm = set(cfg.cycle + cfg.tail) & self._SSM_KINDS
        if ssm:
            raise NotImplementedError(
                f"speculative decoding is not supported for recurrent "
                f"blocks {sorted(ssm)}: their cumulative state has no "
                f"overwrite-only rollback (a rejected draft would need a "
                f"state snapshot per token — the opposite of the "
                f"zero-copy KV story)")
        self.spec = spec or SpecConfig()
        # The contiguous verify block writes T = k + 1 rows starting at
        # the base position, and `write_kv_slot` clamps at the cache
        # end: a cache without k_max + 1 rows of headroom past the last
        # decodable position would silently overwrite live KV instead
        # of failing. Enforce the floor at construction (mirroring
        # SlotPoolEngine.submit's prompt+budget check); start()/
        # submit() validate the per-prompt / per-request form.
        min_len = self.spec.k_max + 2
        if self.max_len < min_len:
            raise ValueError(
                f"max_len {self.max_len} < k_max + 2 = {min_len}: the "
                f"T-wide verify write needs k_max + 1 rows of headroom "
                f"past the base position, or it clamps onto live KV "
                f"rows")
        self.controller = self.spec.make_controller()
        self.draft_params = None
        self._verify = jax.jit(self._meshed(
            lambda p, c, t, pos: _verify_and_accept(self.model, p, c, t, pos)))
        self.accept_log: list[dict] = []
        if self.params is not None:
            self._refresh_params()

    # -- both views, one store --------------------------------------------
    # The TARGET view is also built in masked form (bits clamped per
    # leaf to its full width — a value-level no-op) so draft and target
    # pytrees share one treedef: a degenerate k = 0 round then runs the
    # target through the SAME decode executable the draft steps use,
    # and the engine holds exactly two executables for a fixed k.
    _FULL_BITS = 1 << 10

    def current_draft_bits(self) -> int:
        """Fixed-k engines pin the draft precision; adaptive engines
        follow the controller, which climbs the precision ladder when
        rejection persists at the shortest drafts."""
        return (self.spec.draft_bits if self.spec.k is not None
                else self.controller.draft_bits)

    def _refresh_params(self) -> None:
        b = self.current_draft_bits()
        self._draft_bits_live = b
        if self._receiver is not None:
            self.params = self._receiver.materialize_resident(
                bits=self._FULL_BITS)
            self.draft_params = self._receiver.materialize_resident(bits=b)
        else:
            self.params = self.state.materialize_resident(
                quantized_resident_eligible, bits=self._FULL_BITS)
            self.draft_params = self.state.materialize_resident(
                quantized_resident_eligible, bits=b)

    def _sync_draft_view(self) -> None:
        """Re-point the draft view when the controller moved draft_bits
        — a metadata-only refresh of the SAME accumulators (traced
        keep_bits/affine), so it never recompiles anything."""
        if self.current_draft_bits() != getattr(self, "_draft_bits_live",
                                                None):
            self._refresh_params()

    def receive_stage(self) -> None:
        """A stage upgrade changes the draft/target gap, so acceptance
        evidence gathered against the old gap is stale — relax the
        controller's EWMA toward its prior (both serving shapes route
        their upgrades through here)."""
        super().receive_stage()
        self.controller.on_upgrade()

    def _record_accept(self, rec: dict) -> dict:
        """Accept-round chokepoint: the legacy ``accept_log`` record
        plus registry views over the same values (round counter,
        accepted-per-slot histogram, controller-rate gauge)."""
        self.accept_log.append(rec)
        if _obs.enabled():
            engine = type(self).__name__
            reg = _obs.get_registry()
            reg.counter("spec_rounds_total",
                        "speculative accept rounds").inc(engine=engine)
            acc = rec["accepted"]
            for a in (acc if isinstance(acc, list) else [acc]):
                reg.histogram("spec_accepted_per_round",
                              "accepted drafts per slot per round").observe(
                                  a, engine=engine)
            reg.gauge("spec_accept_rate",
                      "controller acceptance EWMA").set(
                          rec["rate"], engine=engine)
        return rec

    def received_bits_now(self) -> int:
        """Min effective precision across the store's tensors — what the
        controller compares against draft_bits."""
        store = (self._receiver.store if self._receiver is not None
                 else self.state.store)
        if store is None or store.n_tensors == 0:
            return 0
        return min(store.effective_bits(i) for i in range(store.n_tensors))

    def choose_k(self) -> int:
        if self.spec.k is not None:
            if self.received_bits_now() <= self.spec.draft_bits:
                return 0  # no precision gap: drafting buys nothing
            return min(self.spec.k, self.spec.k_max)
        return self.controller.choose_k(self.received_bits_now())

    # -- one speculation round (shared by both serving shapes) -------------
    def _run_round(self, caches, last_tok, pos, k_eff: int):
        """Draft k_eff tokens from the truncated view, then verify the
        whole block with the target view — or, degenerate (k_eff == 0),
        one plain decode step through the SAME executable the draft
        uses. Returns ``(g, acc, nxt, caches)`` with everything still
        on device. This is the single home of the round protocol: draft
        step j feeds block token j at position pos + j, and the verify
        overwrites every drafted slot with target K/V."""
        if k_eff == 0:
            logits, caches = self._decode(self.params, caches, last_tok, pos)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return g, jnp.zeros(g.shape[:1], jnp.int32), g, caches
        toks = [last_tok]
        cur = last_tok
        for j in range(k_eff):
            # keep inactive slots' sentinel negative: -1 + j would walk
            # back into valid range and write garbage K/V into a row
            # the invariant says stays untouched
            pj = jnp.where(pos >= 0, pos + j, jnp.int32(-1))
            logits, caches = self._decode(self.draft_params, caches, cur, pj)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            toks.append(cur)
        return self._verify(self.params, caches,
                            jnp.concatenate(toks, axis=1), pos)

    # -- audits ------------------------------------------------------------
    def decode_cache_size(self) -> int:
        """Draft ``decode_step`` entries + target ``verify_step``
        entries. Exactly 2 for a fixed k: ONE decode executable —
        shared by every draft step AND by degenerate k = 0 rounds,
        because the target view is built with the same treedef as the
        draft view — plus ONE verify executable; both survive every
        precision upgrade. Adaptive k adds one verify entry per
        distinct ladder value (T is a static shape)."""
        return self._decode._cache_size() + self._verify._cache_size()

    def resident_report(self) -> dict:
        """Audit target + draft views TOGETHER: the draft shares every
        weight buffer with the target (``aliased_leaves``), so
        ``extra_draft_bytes`` — resident weight bytes beyond the target
        view alone — must be 0. ``effective_bits`` tells the two views
        apart per leaf."""
        if self.params is None or self.draft_params is None:
            raise RuntimeError("no planes received yet")
        target = resident_report(self.params)
        both = resident_report({"target": self.params,
                                "draft": self.draft_params})
        both["extra_draft_bytes"] = (
            both["quantized_bytes"] + both["fp_bytes"]
            - target["quantized_bytes"] - target["fp_bytes"])
        return both


class SpeculativeEngine(_SpeculativeMixin, ProgressiveServer):
    """Single-stream self-speculative server (quantized-resident only:
    the draft IS a second metadata view over the resident accumulators).

    Slots start lock-stepped at the prompt and immediately go *ragged*:
    each slot accepts a different number of drafts per round, so
    positions are per-slot ``(B,)`` from round one — the same ragged
    machinery the continuous-batching kernels already speak. A slot
    that has emitted ``steps`` tokens is masked out (``pos = -1``)
    while the rest finish."""

    def __init__(self, model, prog, max_len: int, receiver=None,
                 spec: SpecConfig | None = None, mesh=None):
        super().__init__(model, prog, max_len, receiver=receiver,
                         resident="quantized", mesh=mesh)
        self._init_spec(spec)

    def start(self, batch: dict) -> None:
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        prompt_len = int(batch["tokens"].shape[1])
        if prompt_len + self.spec.k_max + 1 > self.max_len:
            raise ValueError(
                f"prompt of {prompt_len} tokens leaves no verify "
                f"headroom: needs prompt + k_max + 1 = "
                f"{prompt_len + self.spec.k_max + 1} <= max_len "
                f"{self.max_len}")
        last_logits, caches = self._prefill(self.params, batch)
        # ring caches over-allocated by the max draft block so verify
        # writes never clobber live window entries
        self.caches = self.model.grow_caches(
            caches, self.max_len, ring_margin=self.spec.k_max + 1,
            pos=prompt_len)
        self.pos = prompt_len
        self.last_logits = last_logits
        self._pos_np = np.full((last_logits.shape[0],), prompt_len, np.int64)
        self._first_tok = jnp.argmax(last_logits, axis=-1).astype(
            jnp.int32)[:, None]
        self._decoded = False

    def decode(self, steps: int, *,
               stage_arrival: Callable[[int], bool] | None = None,
               on_round: Callable[[dict], None] | None = None,
               **_ignored) -> SpeculativeResult:
        """Greedy-decode ``steps`` tokens per slot through speculation
        rounds. ``stage_arrival(emitted)`` is consulted between rounds
        (the speculative analogue of the plain path's between-steps
        check); ``on_round`` sees each round's accept record — the
        Session uses it to stamp accept-rate events on the byte clock.

        One-shot per :meth:`start`: slots finish ragged and fast slots'
        surplus tokens are discarded, so there is no coherent state to
        resume a second ``decode`` from (unlike the lock-stepped plain
        path, which chains on ``last_logits``)."""
        if getattr(self, "_decoded", True):
            raise RuntimeError(
                "speculative decode is one-shot per start(): surplus "
                "tokens of fast slots are discarded at the end of a "
                "run, so continuing would skip them — call start() "
                "again to begin a new generation")
        # validate BEFORE consuming the one-shot: a rejected call must
        # leave the started generation decodable with a legal step count
        need = int(self._pos_np.max()) + steps + self.spec.k_max - 1
        if need > self.max_len:
            raise ValueError(
                f"decoding {steps} steps needs max_len >= prompt + "
                f"steps + k_max - 1 = {need}, got {self.max_len} (the "
                f"final rounds' verify blocks would clamp at the cache "
                f"end)")
        self._decoded = True
        B = int(self._first_tok.shape[0])
        emitted: list[list[int]] = [[] for _ in range(B)]
        stage_log: list[list[int]] = [[] for _ in range(B)]
        upgrades: list[tuple[int, int]] = []
        t_start = time.perf_counter()
        # the prefill's argmax is the first plain-greedy token
        first = np.asarray(self._first_tok)[:, 0]
        ttft = time.perf_counter() - t_start
        for b in range(B):
            emitted[b].append(int(first[b]))
            stage_log[b].append(self.stage)
        last_tok = self._first_tok
        rounds = drafted = accepted_total = 0
        n_rounds_guard = steps * (B + 1) + 8
        while min(len(e) for e in emitted) < steps:
            if rounds > n_rounds_guard:
                raise AssertionError("speculative decode did not converge")
            done = min(len(e) for e in emitted)
            if stage_arrival and self.stage < self.prog.n_stages \
                    and stage_arrival(done):
                self.receive_stage()  # relaxes the controller EWMA too
                upgrades.append((done, self.stage))
            self._sync_draft_view()
            active = np.array([len(e) < steps for e in emitted])
            pos_masked = np.where(active, self._pos_np, -1)
            # headroom was validated at start()/decode(): every active
            # slot can take a full k_max-draft verify block, so k never
            # shrinks at the end of generation and no extra verify
            # shape ever compiles (the 2-executable invariant holds
            # for the whole session)
            k_eff = self.choose_k()
            pos_dev = jnp.asarray(pos_masked, jnp.int32)
            g, acc, nxt, self.caches = self._run_round(
                self.caches, last_tok, pos_dev, k_eff)
            acc_np = np.asarray(acc)
            g_np = np.asarray(g)                   # host sync, once/round
            for b in range(B):
                if not active[b]:
                    continue
                take = int(acc_np[b]) + 1
                emitted[b].extend(int(t) for t in g_np[b, :take])
                stage_log[b].extend([self.stage] * take)
                self._pos_np[b] += take
            last_tok = nxt
            n_active = int(active.sum())
            drafted += k_eff * n_active
            accepted_total += int(acc_np[active].sum())
            self.controller.update(int(acc_np[active].sum()),
                                   k_eff * n_active)
            rec = self._record_accept(
                {"round": rounds, "k": k_eff,
                 "accepted": [int(a) for a in acc_np[active]],
                 "rate": self.controller.rate, "stage": self.stage,
                 "emitted": [len(e) for e in emitted]})
            if on_round is not None:
                on_round(rec)
            rounds += 1
        wall = time.perf_counter() - t_start
        self.last_logits = None  # the plain path's handle is stale now
        if _obs.enabled():
            reg = _obs.get_registry()
            reg.histogram("engine_ttft_s",
                          "wall seconds to first token value").observe(
                              ttft, engine="SpeculativeEngine")
            reg.counter("engine_tokens_total",
                        "tokens emitted by serving engines").inc(
                            steps * B, engine="SpeculativeEngine")
            _obs.get_tracer().record("decode_window", wall_s=wall,
                                     engine="SpeculativeEngine")
        return SpeculativeResult(
            tokens=jnp.asarray(np.array([e[:steps] for e in emitted],
                                        np.int32)),
            stage_log=[s[:steps] for s in stage_log],
            upgrades=upgrades,
            accept_rounds=list(self.accept_log[-rounds:] if rounds else []),
            rounds=rounds, drafted=drafted, accepted=accepted_total,
            wall_s=wall, ttft_s=ttft)


class SpeculativeSlotPool(_SpeculativeMixin, SlotPoolEngine):
    """Continuous-batching speculation: one draft chain + one verify
    pass serve EVERY occupied slot per round, ragged positions and all.
    Admission follows the base pool (chunked by default: the prompt
    streams into the pooled caches block by block, the slot joining
    draft rounds once its last chunk lands and its first greedy token —
    captured device-side — being emitted at the next flush; batch-1
    fallback prefills at admission, ring caches grown by the
    speculative margin, first token emitted immediately). Budget/eos
    eviction happens at flush, where the per-round acceptance counts
    become host-visible. One draft executable + one verify executable
    across every admission, eviction and precision upgrade."""

    def __init__(self, model, prog, *, n_slots: int, max_len: int,
                 receiver=None, spec: SpecConfig | None = None,
                 dispatch_window: int = 4, eos_id: int | None = None,
                 chunked_prefill: bool | None = None,
                 prefill_chunk: int = 8,
                 prefill_buckets: bool = True,
                 double_buffer: bool = True,
                 mesh=None):
        spec = spec or SpecConfig()
        super().__init__(model, prog, n_slots=n_slots, max_len=max_len,
                         receiver=receiver, resident="quantized",
                         dispatch_window=dispatch_window, eos_id=eos_id,
                         ring_margin=spec.k_max + 1,
                         chunked_prefill=chunked_prefill,
                         prefill_chunk=prefill_chunk,
                         prefill_buckets=prefill_buckets,
                         double_buffer=double_buffer,
                         mesh=mesh)
        self._init_spec(spec)
        # per-slot position ceiling (prompt + budget - 1): a slot whose
        # budget is met keeps riding rounds until flush evicts it, but
        # its pos freezes here — otherwise it would keep advancing past
        # the verify headroom `submit` validated for it
        self._pos_bound = jnp.full((n_slots,), max_len, jnp.int32)
        # chunked admissions whose first token awaits host emission:
        # (slot, rid, stage at prefill completion)
        self._deferred_first: list[tuple[int, int, int]] = []

    # -- admission ----------------------------------------------------------
    def _validate_request(self, req) -> None:
        super()._validate_request(req)
        prompt = np.asarray(req.prompt)
        if prompt.shape[0] + req.max_new_tokens + self.spec.k_max \
                > self.max_len:
            # the last round at pos = prompt + budget - 1 verify-writes
            # k_max more rows; past max_len the write would clamp onto
            # live KV (and a shrunken k would compile a second verify
            # shape)
            raise ValueError(
                f"request needs {prompt.shape[0]} prompt + "
                f"{req.max_new_tokens} new tokens + {self.spec.k_max} "
                f"verify headroom > max_len {self.max_len}")

    def _post_admit(self, slot: int, req, prompt_len: int) -> None:
        self._pos_bound = self._pos_bound.at[slot].set(
            prompt_len + req.max_new_tokens - 1)

    def _grow_admitted(self, caches, prompt_len: int):
        return self.model.grow_caches(
            caches, self.max_len, ring_margin=self._ring_margin,
            pos=prompt_len)

    def _post_admit_batch1(self, slot: int, req, last_logits,
                           prompt_len: int) -> None:
        first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        self._last_tok = self._last_tok.at[slot].set(first)
        # the prefill argmax is the request's first greedy token,
        # emitted right at admission (the plain pool emits it on the
        # request's first batched step instead — same token)
        self._note_first_token(req.rid)
        self.outputs[req.rid].append(int(first[0]))
        self.stage_log[req.rid].append(self.stage)
        self.slots[slot].dispatched = 1
        if req.max_new_tokens == 1:
            self._evict(slot)

    def _on_prefill_complete(self, slot: int) -> None:
        # the chunk step captured the first greedy token in _first_cap
        # device-side; emission waits for the next flush (no host sync
        # mid-window), chronologically before any round that includes
        # this slot — rounds only snapshot it from here on
        self._deferred_first.append((slot, self.slots[slot].rid,
                                     self.stage))

    # -- one speculation round for the whole pool ---------------------------
    def step(self) -> dict[int, int]:
        """One scheduling tick: advance chunked prefills by one block,
        then run one batched speculation round — k draft decode_steps +
        one verify pass over every decoding slot. Free and mid-prefill
        slots ride along masked (``pos = -1``). Token values stay on
        device until :meth:`flush`."""
        if self.params is None:
            raise RuntimeError("no planes received yet — call receive_stage()")
        if self._win_t0 is None:
            self._win_t0 = time.perf_counter()
        self._prefill_tick()
        snapshot = self.active_rids()
        active = np.array([i in snapshot for i in range(self.n_slots)])
        if not active.any():
            return snapshot
        self._sync_draft_view()
        # submit() validated prompt + budget + k_max <= max_len for
        # every admitted request, so a full k-draft verify block always
        # fits — k never shrinks at the end of a request's budget and
        # the 2-executable invariant holds across the pool's lifetime
        k_eff = self.choose_k()
        g, acc, nxt, self.caches = self._run_round(
            self.caches, self._last_tok, self.pos, k_eff)
        act_dev = jnp.asarray(active)
        self.pos = jnp.where(act_dev,
                             jnp.minimum(self.pos + acc + 1,
                                         self._pos_bound),
                             self.pos)
        self._last_tok = jnp.where(act_dev[:, None], nxt, self._last_tok)
        self._pending.append((g, acc, snapshot, self.stage, k_eff))
        self._step_count += 1
        return snapshot

    def _flush_deferred_first(self) -> int:
        """Emit the captured first token of every chunk-admitted
        request whose prefill completed since the last flush. Runs
        BEFORE round distribution: the first token chronologically
        precedes every round that snapshots the slot."""
        if not self._deferred_first:
            return 0
        first_np = np.asarray(self._first_cap)  # host sync (flush-time)
        emitted = 0
        for slot, rid, stage in self._deferred_first:
            s = self.slots[slot]
            if s.free or s.rid != rid:
                continue
            tok = int(first_np[slot])
            self._note_first_token(rid)
            self.outputs[rid].append(tok)
            self.stage_log[rid].append(stage)
            s.dispatched += 1
            emitted += 1
            if s.dispatched >= s.budget or \
                    (self.eos_id is not None and tok == self.eos_id):
                self._evict(slot)
        self._deferred_first.clear()
        return emitted

    def flush(self) -> PoolStepStats | None:
        """Read the in-flight rounds' tokens + acceptance, distribute
        them, and do the budget/eos bookkeeping that the plain pool
        does at dispatch time (speculation only learns how many tokens
        a round produced when the acceptance counts land)."""
        emitted = self._flush_deferred_first()
        if not self._pending:
            # budget-1 admissions can retire a request without any
            # in-flight round; still surface them as completed
            self.completed |= self._retired
            self._retired.clear()
            return None
        jax.block_until_ready(self._last_tok)
        wall = time.perf_counter() - (self._win_t0 or time.perf_counter())
        for g, acc, snapshot, stage, k_eff in self._pending:
            g_np = np.asarray(g)
            acc_np = np.asarray(acc)
            self._record_accept({
                "k": k_eff, "accepted": [int(acc_np[s]) for s in snapshot],
                "rate": self.controller.rate, "stage": stage})
            self.controller.update(
                int(sum(acc_np[s] for s in snapshot)),
                k_eff * len(snapshot))
            for slot, rid in snapshot.items():
                if rid in self.completed or rid in self._retired:
                    continue  # evicted while this round was in flight
                s = self.slots[slot]
                take = min(int(acc_np[slot]) + 1,
                           max(s.budget - s.dispatched, 0))
                s.dispatched += take
                for tok in g_np[slot, :take]:
                    self.outputs[rid].append(int(tok))
                    self.stage_log[rid].append(stage)
                    emitted += 1
                    if self.eos_id is not None and int(tok) == self.eos_id:
                        self._evict(slot)
                        break
                if not s.free and s.rid == rid and \
                        s.dispatched >= s.budget:
                    self._evict(slot)
        self.completed |= self._retired
        self._retired.clear()
        stats = PoolStepStats(steps=len(self._pending), wall_s=wall,
                              tokens_emitted=emitted,
                              upgrades=self._win_upgrades,
                              upgrade_enqueue_s=self._win_upgrade_enqueue_s,
                              prefill_ticks=self._win_prefill_ticks)
        return self._record_window(stats)
