from repro.train.optimizer import OptConfig
from repro.train.data import DataConfig, MarkovMotifDataset
from repro.train.loop import train, make_train_step

__all__ = ["OptConfig", "DataConfig", "MarkovMotifDataset", "train", "make_train_step"]
