"""Progressive checkpointing — the paper's technique applied to the
checkpoint store -> accelerator path.

A checkpoint directory contains::

    header.bin           wire header (tensor metadata, schedule)
    stage_01.bin ...     bit-packed planes, MSB stage first
    passthrough.npz      non-float leaves (step counters etc.)

``load(dir, stages=m)`` restores an approximate model from only the
first m stage files — a cold-starting server begins serving after
stage_01 arrives (2 bits/weight = 1/8 of the bytes under the paper's
default schedule) and upgrades in place as later stages land.
"""
from __future__ import annotations

import json
import os

import numpy as np
import jax

from repro.core import wire
from repro.core.progressive import divide, ProgressiveModel, ReceiverState
from repro.core.policy import DivisionPolicy
from repro.transmission.client import ProgressiveClient


def save(params, ckpt_dir: str, policy: DivisionPolicy | None = None) -> ProgressiveModel:
    os.makedirs(ckpt_dir, exist_ok=True)
    model = divide(params, policy)
    with open(os.path.join(ckpt_dir, "header.bin"), "wb") as f:
        f.write(wire.encode_header(model))
    for s in range(1, model.n_stages + 1):
        with open(os.path.join(ckpt_dir, f"stage_{s:02d}.bin"), "wb") as f:
            f.write(wire.encode_stage(model, s))
    passthrough = {
        wire.path_str(p): np.asarray(leaf) for p, leaf in model.passthrough
    }
    np.savez(os.path.join(ckpt_dir, "passthrough.npz"), **passthrough)
    return model


def load_flat(ckpt_dir: str, stages: int | None = None) -> dict:
    """Restore as flat {path: array}; ``stages`` limits precision."""
    client = ProgressiveClient()
    with open(os.path.join(ckpt_dir, "header.bin"), "rb") as f:
        client.feed(f.read())
    s = 1
    while True:
        p = os.path.join(ckpt_dir, f"stage_{s:02d}.bin")
        if not os.path.exists(p) or (stages is not None and s > stages):
            break
        with open(p, "rb") as f:
            client.feed(f.read())
        s += 1
    flat = client.materialize()
    pt = np.load(os.path.join(ckpt_dir, "passthrough.npz"))
    for k in pt.files:
        flat[k] = pt[k]
    return flat


def load_into(ckpt_dir: str, params_like, stages: int | None = None):
    """Restore into the structure of ``params_like`` (a pytree or its
    eval_shape skeleton)."""
    flat = load_flat(ckpt_dir, stages)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(params_like)
    out = []
    for path, leaf in leaves_with_paths:
        key = wire.path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key}")
        arr = np.asarray(flat[key]).reshape(leaf.shape)
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def manifest(ckpt_dir: str) -> dict:
    """Stage sizes — what a transfer scheduler needs."""
    with open(os.path.join(ckpt_dir, "header.bin"), "rb") as f:
        meta, hdr = wire.decode_header(f.read())
    sizes = {}
    s = 1
    while os.path.exists(os.path.join(ckpt_dir, f"stage_{s:02d}.bin")):
        sizes[s] = os.path.getsize(os.path.join(ckpt_dir, f"stage_{s:02d}.bin"))
        s += 1
    return {"header_bytes": hdr, "stage_bytes": sizes, "n_tensors": len(meta["tensors"])}
