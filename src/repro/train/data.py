"""Synthetic-but-learnable data pipeline.

A deterministic token stream with real structure (an order-2 Markov chain
plus copy motifs) so small models visibly learn (loss drops well below
ln(V)) in a few hundred CPU steps — the end-to-end training example and
the Table-II accuracy reproduction need a learnable task, not noise.

The pipeline is sharded: each data-parallel host slices its own batch
rows by process index (multi-host layout), double-buffers via a
background thread, and is fully deterministic given (seed, step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64


class MarkovMotifDataset:
    """Order-2 Markov chain over a small state set, interleaved with
    repeated motifs: next-token prediction has both local (bigram) and
    copy (motif) structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k = min(cfg.vocab, 256)
        self._k = k
        # sparse row-stochastic transitions: each (a,b) allows 4 successors
        self._succ = rng.integers(0, k, size=(k, k, 4))
        self._motifs = rng.integers(0, k, size=(cfg.n_motifs, cfg.motif_len))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        out = np.empty((B, S + 1), np.int64)
        a = rng.integers(0, self._k, size=B)
        b = rng.integers(0, self._k, size=B)
        out[:, 0] = a
        out[:, 1] = b
        t = 2
        while t < S + 1:
            if rng.random() < 0.15:  # motif insertion
                m = self._motifs[rng.integers(0, cfg.n_motifs, size=B)]
                L = min(cfg.motif_len, S + 1 - t)
                out[:, t : t + L] = m[:, :L]
                t += L
                a, b = out[:, t - 2], out[:, t - 1]
            else:
                c = self._succ[a, b, rng.integers(0, 4, size=B)]
                out[:, t] = c
                a, b = b, c
                t += 1
        return {
            "tokens": out[:, :S].astype(np.int32),
            "labels": out[:, 1:].astype(np.int32),
        }


class Prefetcher:
    """Background-thread double buffering."""

    def __init__(self, dataset: MarkovMotifDataset, start_step: int = 0, depth: int = 2):
        self._ds = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self._ds.batch(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
