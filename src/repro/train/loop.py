"""Training loop: jit'd train_step + metrics + periodic progressive
checkpointing. Mesh-aware: under a Mesh context the step is pjit'd with
the sharding rules; on one device it runs as plain jit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train import optimizer as opt
from repro.train.data import MarkovMotifDataset, DataConfig, Prefetcher


def make_train_step(model: Model, ocfg: opt.OptConfig) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = opt.update(ocfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: list[dict]


def train(
    model: Model,
    *,
    steps: int,
    data_cfg: DataConfig,
    opt_cfg: opt.OptConfig | None = None,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    seed: int = 0,
    extra_batch: Callable[[dict], dict] | None = None,
) -> TrainResult:
    opt_cfg = opt_cfg or opt.OptConfig(total_steps=steps)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    ds = MarkovMotifDataset(data_cfg)
    pf = Prefetcher(ds)
    history = []
    t0 = time.time()
    try:
        for step in range(steps):
            batch = {k: jnp.asarray(v) for k, v in pf.next().items()}
            if extra_batch:
                batch = extra_batch(batch)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                history.append(m)
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                from repro.train import checkpoint

                checkpoint.save(params, ckpt_dir)
    finally:
        pf.close()
    return TrainResult(params=params, opt_state=opt_state, history=history)
