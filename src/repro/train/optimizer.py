"""AdamW with warmup-cosine schedule. Pure pytree implementation (no
optax dependency); state shards exactly like the params (same
PartitionSpecs), which the sharding rules rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step.astype(jnp.float32))

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * clip, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
