"""Transmission substrate: bandwidth traces and simulation, the Fig.-4
concurrent transmission/inference scheduler, the progressive client,
named network scenarios, and the deterministic co-simulation Session."""
from repro.transmission.simulator import (
    BandwidthTrace,
    ChunkDelivery,
    FaultInjector,
    FaultTrace,
    Link,
    TransferEvent,
    as_trace,
    simulate_transfer,
)
from repro.transmission.scheduler import (
    StageCost,
    Timeline,
    overhead_pct,
    progressive_timeline,
    singleton_timeline,
)
from repro.transmission.client import ProgressiveClient
from repro.transmission.scenarios import (SCENARIOS, Scenario,
                                          flash_crowd_arrivals, get_scenario,
                                          list_scenarios)
from repro.transmission.session import (FaultPolicy, Session, SessionEvent,
                                        SessionResult, TransportError)

__all__ = [
    "BandwidthTrace",
    "ChunkDelivery",
    "FaultInjector",
    "FaultPolicy",
    "FaultTrace",
    "Link",
    "TransportError",
    "TransferEvent",
    "as_trace",
    "simulate_transfer",
    "StageCost",
    "Timeline",
    "overhead_pct",
    "progressive_timeline",
    "singleton_timeline",
    "ProgressiveClient",
    "SCENARIOS",
    "Scenario",
    "flash_crowd_arrivals",
    "get_scenario",
    "list_scenarios",
    "Session",
    "SessionEvent",
    "SessionResult",
]
