"""Transmission substrate: bandwidth simulation, the Fig.-4 concurrent
transmission/inference scheduler, and the progressive client."""
from repro.transmission.simulator import Link, TransferEvent, simulate_transfer
from repro.transmission.scheduler import (
    Timeline,
    singleton_timeline,
    progressive_timeline,
)
from repro.transmission.client import ProgressiveClient

__all__ = [
    "Link",
    "TransferEvent",
    "simulate_transfer",
    "Timeline",
    "singleton_timeline",
    "progressive_timeline",
    "ProgressiveClient",
]
