"""Progressive client: byte stream -> ReceiverState.

Consumes the wire format produced by :mod:`repro.core.wire` incrementally
(arbitrary chunk boundaries — a transport delivers bytes, not planes),
OR-accumulates planes as they complete (eq. 4), and exposes
``materialize()`` for inference at the current precision.

This is the framework's equivalent of the paper's browser client; the
serving engine drives the same state machine with device-resident
accumulators.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np
import jax.numpy as jnp

from repro.core import wire, bitplanes
from repro.core.quantize import QuantizedTensor, dequantize, container_dtype


@dataclasses.dataclass
class _TensorState:
    meta: dict
    acc: np.ndarray
    planes_received: int = 0

    @property
    def effective_bits(self) -> int:
        return sum(self.meta["widths"][: self.planes_received])


class ProgressiveClient:
    """Incremental decoder of the progressive wire format."""

    def __init__(self, on_stage_complete: Callable[[int], None] | None = None):
        self._buf = bytearray()
        self._meta = None
        self._layout: wire.StageLayout | None = None
        self._tensors: list[_TensorState] = []
        self._cursor = 0          # absolute offset of next undecoded byte
        self._stage = 0           # completed stages
        self._entry = 0           # next entry within current stage
        self._on_stage_complete = on_stage_complete

    # -- feeding -----------------------------------------------------------
    def feed(self, chunk: bytes) -> None:
        self._buf.extend(chunk)
        self._advance()

    @property
    def stages_complete(self) -> int:
        return self._stage

    @property
    def header_ready(self) -> bool:
        return self._meta is not None

    @property
    def expected_total_bytes(self) -> int | None:
        return self._layout.total_bytes if self._layout else None

    def _advance(self) -> None:
        if self._meta is None:
            if len(self._buf) < 12:
                return
            import struct

            _, n = struct.unpack("<II", bytes(self._buf[4:12]))
            if len(self._buf) < 12 + n:
                return
            self._meta, hdr = wire.decode_header(bytes(self._buf))
            self._layout = wire.layout_from_header(self._meta, hdr)
            self._cursor = hdr
            for t in self._meta["tensors"]:
                n_el = int(np.prod(t["shape"])) if t["shape"] else 1
                self._tensors.append(
                    _TensorState(
                        meta=t,
                        acc=np.zeros(n_el, dtype=np.uint32),
                    )
                )
        # Decode completed planes.
        assert self._layout is not None
        while self._stage < len(self._layout.stages):
            entries = self._layout.stages[self._stage]
            while self._entry < len(entries):
                idx, w, nbytes, n_el = entries[self._entry]
                if len(self._buf) - self._cursor < nbytes:
                    return
                payload = bytes(self._buf[self._cursor : self._cursor + nbytes])
                vals = wire.decode_plane(payload, w, n_el)
                ts = self._tensors[idx]
                cum_before = sum(ts.meta["widths"][: ts.planes_received])
                shift = ts.meta["bits"] - cum_before - w
                ts.acc |= vals.astype(np.uint32) << shift
                ts.planes_received += 1
                self._cursor += nbytes
                self._entry += 1
            self._stage += 1
            self._entry = 0
            if self._on_stage_complete:
                self._on_stage_complete(self._stage)

    # -- inference-side view -------------------------------------------------
    def materialize(self):
        """Current approximate params as a flat {path: array} dict (eq. 5;
        sliced tensors are stacked back along their slice axis)."""
        if self._meta is None:
            raise RuntimeError("header not received yet")
        pieces: dict[str, list] = {}
        for ts in self._tensors:
            m = ts.meta
            qt = QuantizedTensor(
                q=jnp.asarray(ts.acc.astype(container_dtype(m["bits"]))).reshape(m["shape"]),
                lo=jnp.float32(m["lo"]),
                hi=jnp.float32(m["hi"]),
                bits=m["bits"],
                orig_dtype=np.dtype(m["dtype"]),
            )
            val = dequantize(qt, received_bits=ts.effective_bits)
            pieces.setdefault(m["path"], []).append(
                (m.get("slice_idx", 0), m.get("slice_axis"), val))
        out = {}
        for path, parts in pieces.items():
            if len(parts) == 1 and parts[0][1] is None:
                out[path] = parts[0][2]
            else:
                axis = parts[0][1]
                parts.sort(key=lambda x: x[0])
                out[path] = jnp.stack([v for _, _, v in parts], axis=axis)
        return out
