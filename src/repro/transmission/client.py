"""Progressive client: byte stream -> device-resident PlaneStore.

Consumes the wire format produced by :mod:`repro.core.wire` incrementally
(arbitrary chunk boundaries — a transport delivers bytes, not planes).
Decoded planes are fed straight into a shared
:class:`~repro.core.plane_store.PlaneStore`: completed planes are
buffered and flushed as one *batched* OR launch per stage completion
(eq. 4), and ``materialize()`` is the store's incremental eq. (5) —
tensors untouched since the last call are served from cache.

Fault tolerance (wire v3)
-------------------------
The PlaneStore OR is irreversible: one corrupt plane poisons its
accumulator for the rest of the session. On a v3 (integrity-framed)
stream the client therefore *verifies before it ingests*:

* every unit's CRC32 + sequence number is checked the moment its bytes
  are complete — BEFORE any decode or ``plane_or_segments`` launch;
* a unit that fails verification is **quarantined**: its bytes are
  consumed (lengths come from the header, so stream sync survives) but
  nothing reaches the store, and a NACK entry is recorded for the
  transport to re-request (:meth:`ProgressiveClient.feed_repair`);
* verified units are OR-ed strictly in sequence order — a verified
  unit behind an unrepaired gap is *held* (never OR-ed early), which
  preserves both the per-tensor MSB-first prefix invariant and
  bit-identity with the clean stream at every checkpoint;
* the client exposes a durable resume cursor ``(unit_seq,
  byte_offset)``: everything before it has arrived (good or NACKed), so
  a dropped connection resumes there without re-shipping verified
  units; quarantined units behind the cursor are repaired per-unit.

v1/v2 streams have no integrity frames and keep their original
byte-identical decode path.

This is the framework's equivalent of the paper's browser client; the
serving engine drives the same store with its pytree receiver.
"""
from __future__ import annotations

import struct
from typing import Callable

import numpy as np

from repro import obs as _obs
from repro.core import wire
from repro.core.plane_store import PlaneStore


class ProgressiveClient:
    """Incremental decoder of the progressive wire format."""

    def __init__(self, on_stage_complete: Callable[[int], None] | None = None,
                 *, mesh=None):
        # mesh=None: single-device flat-buffer store. With a serving
        # mesh, decoded planes route shard-local into a
        # ShardedPlaneStore (each model shard ORs only its own segment
        # of the plane — no host gather, no replicated OR).
        self._mesh = mesh
        self._buf = bytearray()
        self._meta = None
        self._layout: wire.StageLayout | None = None
        self.store: PlaneStore | None = None
        self._pending: list[tuple[int, np.ndarray]] = []  # decoded, un-OR-ed
        self._cursor = 0          # absolute offset of next undecoded byte
        self._stage = 0           # completed stages
        self._entry = 0           # next entry within current stage
        self._on_stage_complete = on_stage_complete
        # -- v3 integrity state (inert for v1/v2 streams) ------------------
        self.header_failed = False      # header CRC mismatch: resend from 0
        self._units: list[tuple[int, int, int, int]] = []  # flat entries
        self._unit_offsets: list[int] = []
        self._checkpoints: list[int] = []
        self._next_unit = 0             # stream position, in units
        self._ready: dict[int, tuple[int, np.ndarray]] = {}  # seq -> (t, plane)
        self._verified: set[int] = set()
        self._nacks: dict[int, str] = {}          # seq -> quarantine reason
        self._contig = 0                # all seq < _contig verified
        self._ingested_upto = 0         # all seq < this OR-ed (or queued)
        self.quarantine_log: list[dict] = []
        self.duplicate_units = 0

    # -- feeding -----------------------------------------------------------
    def feed(self, chunk: bytes) -> None:
        if self.header_failed:
            # the transport is expected to restart the stream from byte
            # 0 (see resume_cursor); accept the fresh bytes
            self.header_failed = False
        self._buf.extend(chunk)
        self._advance()
        if _obs.enabled():
            reg = _obs.get_registry()
            reg.counter("client_bytes_fed_total",
                        "bytes fed to the progressive client").inc(
                            len(chunk))
            seq, off = self.resume_cursor
            reg.gauge("client_resume_cursor_unit",
                      "first unit not fully arrived").set(seq)
            reg.gauge("client_resume_cursor_byte",
                      "wire offset of the resume cursor").set(off)

    @property
    def stages_complete(self) -> int:
        return self._stage

    @property
    def bytes_fed(self) -> int:
        return len(self._buf)

    @property
    def complete(self) -> bool:
        if self._layout is None:
            return False
        if self.integrity:
            return self._stage == len(self._checkpoints)
        return self._stage == len(self._layout.stages)

    @property
    def header_ready(self) -> bool:
        return self._meta is not None

    @property
    def expected_total_bytes(self) -> int | None:
        return self._layout.total_bytes if self._layout else None

    @property
    def integrity(self) -> bool:
        """True once a v3 (integrity-framed) header has been decoded."""
        return bool(self._layout is not None and self._layout.integrity)

    # -- v3 transport interface --------------------------------------------
    @property
    def nacks(self) -> dict[int, str]:
        """Quarantined units awaiting re-request: ``{seq: reason}``."""
        return dict(self._nacks)

    @property
    def resume_cursor(self) -> tuple[int, int]:
        """Durable resume point ``(unit_seq, byte_offset)``: the first
        unit whose bytes have not fully arrived on the stream, and its
        absolute wire offset. Everything before it arrived (verified or
        NACKed — NACKs are repaired per-unit, not by replay), so a
        reconnect replays from here without re-shipping verified
        units. ``(0, 0)`` until the header verifies."""
        if not self.integrity:
            if self._layout is None:
                return (0, 0)
            done = sum(len(s) for s in self._layout.stages[:self._stage])
            return (done + self._entry, self._cursor)
        if self._next_unit >= len(self._units):
            return (len(self._units), self._layout.total_bytes)
        return (self._next_unit, self._unit_offsets[self._next_unit])

    @property
    def verified_units(self) -> int:
        return len(self._verified)

    def drop_unconsumed(self) -> int:
        """Discard buffered bytes past the last complete unit (a
        partial frame cut off by a disconnect). The transport replays
        from :attr:`resume_cursor` after this; returns the number of
        bytes dropped."""
        dropped = len(self._buf) - self._cursor
        if dropped > 0:
            del self._buf[self._cursor:]
        return dropped

    def rewind_to_gap(self) -> tuple[int, int]:
        """Connection-level resync after the transport detects a
        desynchronized stream (length-changing faults: truncation,
        duplication, reordering). Drops unconsumed buffered bytes,
        rewinds the stream position to the first *unverified* unit and
        clears quarantine entries at/after it (they re-arrive
        in-stream); already-verified units past the gap are kept and
        simply skipped as duplicates on replay. Returns the new
        ``(unit_seq, byte_offset)`` cursor the transport must replay
        from."""
        if not self.integrity:
            raise RuntimeError("rewind_to_gap requires a v3 integrity stream")
        self.drop_unconsumed()
        gap = self._contig
        for seq in [s for s in self._nacks if s >= gap]:
            del self._nacks[seq]
        self._next_unit = gap
        if gap >= len(self._units):
            return (gap, self._layout.total_bytes)
        return (gap, self._unit_offsets[gap])

    def feed_repair(self, seq: int, payload: bytes) -> bool:
        """Deliver a re-requested unit out of band. ``payload`` is the
        unit's full on-wire bytes (integrity frame included) and is
        verified exactly like stream bytes — a corrupt repair stays
        quarantined (returns False) and the NACK entry survives for the
        next retry. Repairing an already-verified unit is a duplicate:
        dropped, counted, returns True."""
        if not self.integrity:
            raise RuntimeError("feed_repair requires a v3 integrity stream")
        if seq < 0 or seq >= len(self._units):
            raise ValueError(f"repair seq {seq} out of range")
        if seq in self._verified:
            self.duplicate_units += 1
            _obs.get_registry().counter(
                "client_duplicate_units_total",
                "duplicate unit deliveries dropped").inc()
            return True
        ok = self._verify_and_stash(seq, bytes(payload), origin="repair")
        if ok:
            self._nacks.pop(seq, None)
            self._advance_contig()
        _obs.get_registry().counter(
            "client_repairs_total",
            "out-of-band unit repairs").inc(ok=ok)
        return ok

    # -- internal machinery --------------------------------------------------
    def _advance(self) -> None:
        if self._meta is None:
            if not self._try_header():
                return
        if self._layout.integrity:
            self._advance_v3()
        else:
            self._advance_stream()

    def _try_header(self) -> bool:
        if len(self._buf) < 12:
            return False
        version, n = struct.unpack("<II", bytes(self._buf[4:12]))
        if version == wire.VERSION_INTEGRITY and n > wire.MAX_HEADER_BYTES:
            # corrupted length field would stall the stream forever;
            # flag it so the transport restarts from byte 0
            self._quarantine_header(
                f"header declares {n} body bytes (cap "
                f"{wire.MAX_HEADER_BYTES})")
            return False
        hdr_len = 12 + n
        if version == wire.VERSION_INTEGRITY:
            hdr_len += wire.HEADER_CRC_BYTES
        if len(self._buf) < hdr_len:
            return False
        try:
            self._meta, hdr = wire.decode_header(bytes(self._buf))
        except wire.WireFormatError as e:
            # only a v3 stream can *recover* from a bad header (the
            # caller knows to restart); v1/v2 keeps the old hard error
            if version == wire.VERSION_INTEGRITY:
                self._quarantine_header(str(e))
                return False
            raise
        self._layout = wire.layout_from_header(self._meta, hdr)
        self._cursor = hdr
        if self._mesh is not None:
            from repro.core.plane_store import ShardedPlaneStore
            self.store = ShardedPlaneStore.from_wire_meta(
                self._meta, self._mesh)
        else:
            self.store = PlaneStore.from_wire_meta(self._meta)
        if self._layout.integrity:
            self._units = [e for st in self._layout.stages for e in st]
            self._unit_offsets = self._layout.unit_offsets()
            cps, acc = [], 0
            for st in self._layout.stages:
                acc += len(st)
                cps.append(acc)
            self._checkpoints = cps
        return True

    def _quarantine_header(self, reason: str) -> None:
        self.header_failed = True
        self._meta = None
        self._buf.clear()
        self._cursor = 0
        self.quarantine_log.append({"seq": None, "target": "header",
                                    "reason": reason})

    # -- v1/v2: trusted in-order stream -------------------------------------
    def _advance_stream(self) -> None:
        # Decode completed planes; the eq. (4) OR happens in batched
        # flushes, not per plane.
        assert self._layout is not None
        while self._stage < len(self._layout.stages):
            entries = self._layout.stages[self._stage]
            while self._entry < len(entries):
                idx, w, nbytes, n_el = entries[self._entry]
                if len(self._buf) - self._cursor < nbytes:
                    return
                payload = bytes(self._buf[self._cursor : self._cursor + nbytes])
                self._pending.append((idx, wire.decode_plane(
                    payload, w, n_el, framed=self._layout.framed)))
                self._cursor += nbytes
                self._entry += 1
            self._stage += 1
            self._entry = 0
            self._flush()
            if self._on_stage_complete:
                self._on_stage_complete(self._stage)

    # -- v3: verify-before-ingest --------------------------------------------
    def _advance_v3(self) -> None:
        while self._next_unit < len(self._units):
            seq = self._next_unit
            nbytes = self._units[seq][2]
            if len(self._buf) - self._cursor < nbytes:
                break
            payload = bytes(self._buf[self._cursor:self._cursor + nbytes])
            self._cursor += nbytes
            self._next_unit += 1
            if seq in self._verified:
                # duplicated bytes on the stream (e.g. an injected
                # repeat already repaired out of band)
                self.duplicate_units += 1
                continue
            if self._verify_and_stash(seq, payload, origin="stream"):
                self._nacks.pop(seq, None)
        self._advance_contig()

    def _verify_and_stash(self, seq: int, payload: bytes,
                          origin: str) -> bool:
        """CRC/seq-check one on-wire unit; decode and stage it for
        in-order ingest on success, quarantine on failure. Decode
        errors after a *passing* CRC (possible only for malformed
        repair lengths) quarantine too — nothing unverified can reach
        the store."""
        idx, w, nbytes, n_el = self._units[seq]
        reason = None
        try:
            got_seq, body = wire.verify_unit(payload)
            if got_seq != seq:
                reason = f"sequence mismatch: frame says {got_seq}, " \
                         f"stream position says {seq}"
            elif len(payload) != nbytes:
                reason = (f"unit is {len(payload)} bytes on the wire, "
                          f"header says {nbytes}")
        except wire.WireFormatError as e:
            reason = str(e)
        if reason is None:
            try:
                plane = wire.decode_plane(body, w, n_el, framed=True)
            except wire.WireFormatError as e:
                reason = f"verified frame but undecodable body: {e}"
        if reason is not None:
            self._nacks[seq] = reason
            self.quarantine_log.append({"seq": seq, "origin": origin,
                                        "reason": reason})
            _obs.get_registry().counter(
                "client_quarantined_total",
                "units quarantined before ingest").inc(origin=origin)
            return False
        self._ready[seq] = (idx, plane)
        self._verified.add(seq)
        _obs.get_registry().counter(
            "client_units_verified_total",
            "integrity-verified units").inc(origin=origin)
        return True

    def _advance_contig(self) -> None:
        """Advance the verified-prefix pointer, and OR ready units in
        strict sequence order whenever it crosses a checkpoint —
        mirroring the v1/v2 per-stage flush so the store's state at
        each stage completion is bit-identical to the clean stream."""
        while self._contig in self._verified:
            self._contig += 1
        while (self._stage < len(self._checkpoints)
               and self._checkpoints[self._stage] <= self._contig):
            cp = self._checkpoints[self._stage]
            self._ingest_ready_below(cp)
            self._flush()
            self._stage += 1
            if self._on_stage_complete:
                self._on_stage_complete(self._stage)

    def _ingest_ready_below(self, bound: int) -> None:
        """Queue verified units with seq in [_ingested_upto, bound) for
        the batched OR. Strict seq order keeps each tensor's planes
        MSB-first; callers guarantee the range is fully verified."""
        for seq in range(self._ingested_upto, bound):
            self._pending.append(self._ready.pop(seq))
        self._ingested_upto = max(self._ingested_upto, bound)

    def _flush(self) -> None:
        """Push buffered planes into the store: one batched Pallas
        launch per container dtype (per plane round)."""
        if self._pending:
            if _obs.enabled():
                reg = _obs.get_registry()
                reg.counter("client_planes_ored_total",
                            "planes OR-ed into the store").inc(
                                len(self._pending))
                reg.histogram("client_flush_planes",
                              "planes per batched flush").observe(
                                  len(self._pending))
            self.store.ingest(self._pending)
            self._pending = []
            if _obs.enabled():
                _obs.get_registry().gauge(
                    "store_resident_bytes",
                    "accumulator bytes resident on device").set(
                        self.store.resident_bytes())

    # -- inference-side view -------------------------------------------------
    def materialize(self):
        """Current approximate params as a flat {path: array} dict (eq. 5;
        sliced tensors are stacked back along their slice axis). Planes
        of a partially-received stage are flushed first, so mid-stage
        precision is never left on the floor. On a v3 stream only the
        *verified contiguous prefix* flushes — units behind a
        quarantined gap never reach the accumulators early."""
        if self.store is None:
            raise RuntimeError("header not received yet")
        if self.integrity:
            self._ingest_ready_below(self._contig)
        self._flush()
        return dict(self.store.materialize_leaves())
