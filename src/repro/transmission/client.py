"""Progressive client: byte stream -> device-resident PlaneStore.

Consumes the wire format produced by :mod:`repro.core.wire` incrementally
(arbitrary chunk boundaries — a transport delivers bytes, not planes).
Decoded planes are fed straight into a shared
:class:`~repro.core.plane_store.PlaneStore`: completed planes are
buffered and flushed as one *batched* OR launch per stage completion
(eq. 4), and ``materialize()`` is the store's incremental eq. (5) —
tensors untouched since the last call are served from cache.

This is the framework's equivalent of the paper's browser client; the
serving engine drives the same store with its pytree receiver.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core import wire
from repro.core.plane_store import PlaneStore


class ProgressiveClient:
    """Incremental decoder of the progressive wire format."""

    def __init__(self, on_stage_complete: Callable[[int], None] | None = None,
                 *, mesh=None):
        # mesh=None: single-device flat-buffer store. With a serving
        # mesh, decoded planes route shard-local into a
        # ShardedPlaneStore (each model shard ORs only its own segment
        # of the plane — no host gather, no replicated OR).
        self._mesh = mesh
        self._buf = bytearray()
        self._meta = None
        self._layout: wire.StageLayout | None = None
        self.store: PlaneStore | None = None
        self._pending: list[tuple[int, np.ndarray]] = []  # decoded, un-OR-ed
        self._cursor = 0          # absolute offset of next undecoded byte
        self._stage = 0           # completed stages
        self._entry = 0           # next entry within current stage
        self._on_stage_complete = on_stage_complete

    # -- feeding -----------------------------------------------------------
    def feed(self, chunk: bytes) -> None:
        self._buf.extend(chunk)
        self._advance()

    @property
    def stages_complete(self) -> int:
        return self._stage

    @property
    def bytes_fed(self) -> int:
        return len(self._buf)

    @property
    def complete(self) -> bool:
        return (self._layout is not None
                and self._stage == len(self._layout.stages))

    @property
    def header_ready(self) -> bool:
        return self._meta is not None

    @property
    def expected_total_bytes(self) -> int | None:
        return self._layout.total_bytes if self._layout else None

    def _advance(self) -> None:
        if self._meta is None:
            if len(self._buf) < 12:
                return
            import struct

            _, n = struct.unpack("<II", bytes(self._buf[4:12]))
            if len(self._buf) < 12 + n:
                return
            self._meta, hdr = wire.decode_header(bytes(self._buf))
            self._layout = wire.layout_from_header(self._meta, hdr)
            self._cursor = hdr
            if self._mesh is not None:
                from repro.core.plane_store import ShardedPlaneStore
                self.store = ShardedPlaneStore.from_wire_meta(
                    self._meta, self._mesh)
            else:
                self.store = PlaneStore.from_wire_meta(self._meta)
        # Decode completed planes; the eq. (4) OR happens in batched
        # flushes, not per plane.
        assert self._layout is not None
        while self._stage < len(self._layout.stages):
            entries = self._layout.stages[self._stage]
            while self._entry < len(entries):
                idx, w, nbytes, n_el = entries[self._entry]
                if len(self._buf) - self._cursor < nbytes:
                    return
                payload = bytes(self._buf[self._cursor : self._cursor + nbytes])
                self._pending.append((idx, wire.decode_plane(
                    payload, w, n_el, framed=self._layout.framed)))
                self._cursor += nbytes
                self._entry += 1
            self._stage += 1
            self._entry = 0
            self._flush()
            if self._on_stage_complete:
                self._on_stage_complete(self._stage)

    def _flush(self) -> None:
        """Push buffered planes into the store: one batched Pallas
        launch per container dtype (per plane round)."""
        if self._pending:
            self.store.ingest(self._pending)
            self._pending = []

    # -- inference-side view -------------------------------------------------
    def materialize(self):
        """Current approximate params as a flat {path: array} dict (eq. 5;
        sliced tensors are stacked back along their slice axis). Planes
        of a partially-received stage are flushed first, so mid-stage
        precision is never left on the floor."""
        if self.store is None:
            raise RuntimeError("header not received yet")
        self._flush()
        return dict(self.store.materialize_leaves())
