"""Named network scenarios: the catalog benchmarks, examples and tests
all draw from.

Each scenario is a seeded trace factory plus the transport parameters a
:class:`~repro.transmission.session.Session` needs (latency, chunk
size). ``make_trace(seed)`` is deterministic in the seed — the same
seed reproduces the same bandwidth profile, event log and tokens on any
machine — while different seeds give independent draws of the same
scenario family (jitter realizations).

The four canonical entries map to the paper's deployment stories:

==================== ====================================================
``browser-3g``        the paper's user-study regime: a slow cellular
                      link (~0.2 MB/s) with heavy multiplicative jitter
``browser-lte-handoff`` fast LTE that degrades through a cell handoff:
                      ramp down, a dead gap, ramp back up
``edge-stall``        a decent fixed link that suffers a mid-download
                      outage (elevator/tunnel) — the stall scenario
``pod-coldstart``     checkpoint-store -> TPU-pod link: very fast,
                      near-zero latency; stresses the compute side
``flash-crowd``       a solid edge link whose *demand* spikes: N
                      clients join mid-download and the slot-pool
                      engine admits them staggered (see
                      :func:`flash_crowd_arrivals`)
==================== ====================================================

Two *lossy* entries additionally carry a seeded
:class:`~repro.transmission.simulator.FaultTrace` factory
(``make_faults``) — they require the v3 integrity wire and exercise the
quarantine/repair/resume machinery:

==================== ====================================================
``browser-3g-lossy``  the 3G link plus last-mile damage: ~1% bit-flip
                      corruption and occasional mid-chunk disconnects
``edge-flaky``        the edge-stall link on a flaky path: corruption,
                      truncation, duplication, reordering and
                      disconnects all at low rates
==================== ====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.transmission.simulator import BandwidthTrace, FaultTrace


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    make_trace: Callable[[int], BandwidthTrace]  # seed -> trace
    latency_s: float
    chunk_bytes: int
    # lossy scenarios: seed -> channel fault profile (None = trusted
    # channel, the default for the original catalog entries)
    make_faults: Callable[[int], FaultTrace] | None = None

    @property
    def lossy(self) -> bool:
        return self.make_faults is not None


def _browser_3g(seed: int) -> BandwidthTrace:
    return BandwidthTrace.jittered(
        0.2e6, 0.5, seed=seed, interval_s=0.5, n_intervals=256,
        name=f"browser-3g@{seed}")


def _browser_lte_handoff(seed: int) -> BandwidthTrace:
    """LTE plateau -> handoff dip -> dead gap -> recovery. The plateau
    rates get a small seeded jitter so distinct seeds are distinct
    traces of the same family."""
    rng = np.random.default_rng(seed)
    lte = 2.5e6 * (1.0 + 0.1 * (2.0 * rng.random() - 1.0))
    recovered = 1.2e6 * (1.0 + 0.1 * (2.0 * rng.random() - 1.0))
    segs = [(1.5, lte)]
    segs += BandwidthTrace.ramp(lte, 0.15e6, 1.0, steps=5).segments
    segs += [(0.8, 0.0)]  # the handoff gap
    segs += BandwidthTrace.ramp(0.15e6, recovered, 0.5, steps=4).segments
    segs += [(1.0, recovered)]
    return BandwidthTrace(segs, name=f"browser-lte-handoff@{seed}")


def _edge_stall(seed: int) -> BandwidthTrace:
    # The outage starts 0.35 s in so even the reduced smoke models
    # (~0.7 MB at ~1 MB/s) are still mid-download when the link dies —
    # the scenario must actually exercise the stall path at every scale.
    base = BandwidthTrace.jittered(
        1.0e6, 0.15, seed=seed, interval_s=1.0, n_intervals=128)
    out = base.with_outage(0.35, 1.5)
    return BandwidthTrace(out.segments, name=f"edge-stall@{seed}")


def _pod_coldstart(seed: int) -> BandwidthTrace:
    del seed  # the storage fabric doesn't jitter at this granularity
    return BandwidthTrace.constant(200e6, name="pod-coldstart")


def _flash_crowd(seed: int) -> BandwidthTrace:
    """The link itself is a decent lightly-jittered edge connection —
    the scenario's stress is the *request* side (staggered admissions
    into the slot pool), not the byte clock."""
    return BandwidthTrace.jittered(
        1.5e6, 0.1, seed=seed, interval_s=0.5, n_intervals=128,
        name=f"flash-crowd@{seed}")


def _browser_3g_faults(seed: int) -> FaultTrace:
    """Last-mile cellular damage: ~1% of chunks take a bit flip, an
    occasional chunk loses its connection mid-flight."""
    return FaultTrace(seed=seed, p_corrupt=0.01, p_disconnect=0.002,
                      flips_per_corruption=1)


def _edge_flaky_faults(seed: int) -> FaultTrace:
    """Every fault kind at a low rate — the kitchen-sink reliability
    profile (desync recovery included via truncation/duplication)."""
    return FaultTrace(seed=seed, p_corrupt=0.01, p_truncate=0.004,
                      p_duplicate=0.004, p_reorder=0.004,
                      p_disconnect=0.002)


def flash_crowd_arrivals(seed: int, n_clients: int,
                         span_s: float) -> list[float]:
    """Deterministic staggered arrival offsets for a flash crowd:
    ``n_clients`` requests land within ``span_s`` seconds of the cold
    start, sorted, seed-reproducible. The first client arrives at 0 so
    the pool always cold-starts with work."""
    rng = np.random.default_rng(seed)
    offs = np.sort(rng.uniform(0.0, span_s, size=n_clients))
    offs[0] = 0.0
    return [float(o) for o in offs]


SCENARIOS: dict[str, Scenario] = {
    s.name: s
    for s in (
        Scenario(
            name="browser-3g",
            description="slow cellular link with heavy jitter "
                        "(paper user-study regime)",
            make_trace=_browser_3g,
            latency_s=0.08,
            chunk_bytes=16 * 1024,
        ),
        Scenario(
            name="browser-lte-handoff",
            description="fast LTE degrading through a cell handoff: "
                        "ramp down, dead gap, recovery",
            make_trace=_browser_lte_handoff,
            latency_s=0.05,
            chunk_bytes=32 * 1024,
        ),
        Scenario(
            name="edge-stall",
            description="1 MB/s edge link that dies for 1.5 s, "
                        "0.35 s into the download",
            make_trace=_edge_stall,
            latency_s=0.02,
            chunk_bytes=32 * 1024,
        ),
        Scenario(
            name="pod-coldstart",
            description="checkpoint-store to pod: 200 MB/s, "
                        "near-zero latency",
            make_trace=_pod_coldstart,
            latency_s=0.005,
            chunk_bytes=1024 * 1024,
        ),
        Scenario(
            name="flash-crowd",
            description="1.5 MB/s edge link; N clients join "
                        "mid-download and share one slot pool",
            make_trace=_flash_crowd,
            latency_s=0.03,
            chunk_bytes=32 * 1024,
        ),
        Scenario(
            name="browser-3g-lossy",
            description="the browser-3g link with ~1% chunk corruption "
                        "and rare mid-chunk disconnects (needs wire v3)",
            make_trace=_browser_3g,
            latency_s=0.08,
            chunk_bytes=16 * 1024,
            make_faults=_browser_3g_faults,
        ),
        Scenario(
            name="edge-flaky",
            description="the edge-stall link on a flaky path: "
                        "corruption, truncation, duplication, "
                        "reordering and disconnects (needs wire v3)",
            make_trace=_edge_stall,
            latency_s=0.02,
            chunk_bytes=32 * 1024,
            make_faults=_edge_flaky_faults,
        ),
    )
}


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {list_scenarios()}"
        ) from None
