"""The paper's Fig.-4 timeline algebra: concurrent transmission and
inference.

Three schedules are modelled:

* ``singleton``      — download everything, then concat+dequant+infer once.
* ``progressive, w/o concurrency`` — stages download and are processed
  *serially*: stage s+1's download starts only after stage s's
  concat+dequant+inference finished (the naive implementation the paper
  measures at +20..80%).
* ``progressive, w/ concurrency`` — stage s+1 downloads in the
  background while stage s is processed; total time is
  ``max(download_total, download_1 + Σ process) ≈ download_total``
  whenever per-stage processing fits inside the next stage's download
  window — the paper's headline claim (Table I, +0%).

The schedule is pure algebra over byte counts and per-step costs: every
download milestone is a :meth:`BandwidthTrace.time_to_deliver` query, so
it works unchanged for constant links *and* fluctuating traces, and
times are derived, never measured. The same byte->time mapping drives
the co-simulation harness (:mod:`repro.transmission.session`), which
executes the real client/server against the same clock — a test pins
the two to <1e-9 s. Latency is a one-time shift of the byte clock, paid
exactly once per connection in every branch (including
``header_bytes=0``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.transmission.simulator import TraceLike, as_trace


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Client-side processing cost of one stage (seconds)."""

    concat_s: float
    dequant_s: float
    inference_s: float

    @property
    def total(self) -> float:
        return self.concat_s + self.dequant_s + self.inference_s


@dataclasses.dataclass
class Timeline:
    """Per-stage milestones: when its bytes landed, when its (approx)
    inference result became visible, plus the grand total."""

    download_done: list[float]
    result_ready: list[float]

    @property
    def total_s(self) -> float:
        return self.result_ready[-1]

    @property
    def first_result_s(self) -> float:
        return self.result_ready[0]


def singleton_timeline(total_bytes: int, link: TraceLike, cost: StageCost) -> Timeline:
    """Download whole file, process once."""
    trace, latency = as_trace(link)
    dl = latency + trace.time_to_deliver(total_bytes)
    return Timeline(download_done=[dl], result_ready=[dl + cost.total])


def progressive_timeline(
    stage_bytes: Sequence[int],
    link: TraceLike,
    stage_costs: Sequence[StageCost],
    concurrent: bool,
    header_bytes: int = 0,
) -> Timeline:
    """Timeline of an n-stage progressive transfer.

    w/ concurrency: downloads proceed back-to-back on the link
    (the link never idles); processing of stage s runs as soon as both
    (a) its bytes are in and (b) the previous stage's processing is done
    (single compute queue, like the paper's JS main thread + WebGL).

    w/o concurrency: the link idles while the client processes; stage
    s+1's download starts only after stage s's result is shown. With a
    trace-driven link the idle window consumes *wall* time, so the
    resumed download sees whatever bandwidth the trace has then.
    """
    if len(stage_bytes) != len(stage_costs):
        raise ValueError("stage_bytes and stage_costs length mismatch")
    trace, latency = as_trace(link)
    n = len(stage_bytes)
    download_done: list[float] = []
    result_ready: list[float] = []
    # trace-clock time of the last delivered byte (wall = latency + tt)
    tt = trace.time_to_deliver(header_bytes)
    if concurrent:
        proc_free = 0.0
        for s in range(n):
            tt = trace.time_to_deliver(stage_bytes[s], start_s=tt)
            dl = latency + tt
            download_done.append(dl)
            start = max(dl, proc_free)
            proc_free = start + stage_costs[s].total
            result_ready.append(proc_free)
    else:
        for s in range(n):
            tt = trace.time_to_deliver(stage_bytes[s], start_s=tt)
            dl = latency + tt
            download_done.append(dl)
            ready = dl + stage_costs[s].total
            result_ready.append(ready)
            # link idles until this stage's result is shown
            tt = ready - latency
    return Timeline(download_done=download_done, result_ready=result_ready)


def overhead_pct(progressive: Timeline, singleton: Timeline) -> float:
    """Paper Table-I metric: (progressive_total - singleton_total) / singleton_total."""
    return 100.0 * (progressive.total_s - singleton.total_s) / singleton.total_s


def time_to_first_useful(
    timeline: Timeline, useful_stage: int
) -> float:
    """Table-III proxy: when the first *useful* (non-garbage) approximate
    result appears. ``useful_stage`` is 1-indexed (the paper finds 6-bit,
    i.e. stage 3 of the 2-bit schedule, is the first useful one)."""
    return timeline.result_ready[useful_stage - 1]
