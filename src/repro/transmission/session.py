"""Deterministic co-simulation: real bytes, simulated clock.

A :class:`Session` couples the byte clock of a
:class:`~repro.transmission.simulator.BandwidthTrace` to the *real*
receive path: the serialized ``wire`` stream is cut into
transport-sized chunks, each chunk is fed to a real
:class:`~repro.transmission.client.ProgressiveClient` (which ingests
planes into the device-resident PlaneStore), and every milestone is
stamped with the exact time the trace says those bytes landed
(``time_to_deliver`` — derived, never measured). Processing costs come
from a supplied cost model, so runs are bit- and time-deterministic on
any machine.

Two run modes:

* :meth:`Session.run_timeline` — the Fig.-4 schedules *executed*: the
  real client decodes the stream while a single simulated compute queue
  charges per-stage costs. Its Timeline must agree with the pure
  algebra in :mod:`~repro.transmission.scheduler` to <1e-9 s (pinned by
  tests) — the algebra and the execution can no longer silently
  diverge.
* :meth:`Session.run_serving` — the operational path: a real
  :class:`~repro.serving.engine.ProgressiveServer` sits on the *same*
  store the client fills (no second ingest) and greedy-decodes real
  tokens, upgrading precision between steps exactly when the trace
  delivered each stage.

Every run produces a single auditable event log (bytes fed, header,
stage completions, upgrades, decode steps, per-step stage) that can be
dumped as JSONL for CI artifacts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Sequence

import numpy as np

from repro import obs as _obs
from repro.core import wire
from repro.transmission.client import ProgressiveClient
from repro.transmission.scheduler import StageCost, Timeline
from repro.transmission.simulator import BandwidthTrace, FaultTrace

DEFAULT_CHUNK_BYTES = 64 * 1024


class TransportError(RuntimeError):
    """The fault policy's retry budget is exhausted: a unit (or the
    stream itself) could not be delivered intact within
    ``max_retries`` attempts. Clean, typed failure — never a silent
    partial model."""


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Retry/timeout/backoff policy for a faulty transport.

    * ``chunk_timeout_s`` — a delivery whose trace time exceeds this is
      abandoned (connection presumed dead) and retried after backoff.
    * ``max_retries`` — per-target attempt budget (each quarantined
      unit, and each stream reconnect burst, counts its own attempts);
      exceeding it raises :class:`TransportError`.
    * backoff — capped exponential ``min(cap, base * 2**attempt)``
      with seeded multiplicative jitter, so retry schedules are
      deterministic for a fixed seed yet decorrelated across targets.
    """

    chunk_timeout_s: float = 30.0
    max_retries: int = 8
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    jitter_frac: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.chunk_timeout_s <= 0:
            raise ValueError("chunk_timeout_s must be > 0")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not (0.0 <= self.jitter_frac < 1.0):
            raise ValueError("jitter_frac must be in [0, 1)")

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        d = min(self.backoff_cap_s, self.backoff_base_s * (2.0 ** attempt))
        if self.jitter_frac:
            d *= 1.0 + self.jitter_frac * (2.0 * float(rng.random()) - 1.0)
        return d


@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """One entry of the audit log. ``data`` is JSON-able. ``seq`` is
    the emission-order tiebreak: events are sorted by ``(t_s, seq)``,
    so logs with equal timestamps are reproducibly ordered."""

    t_s: float
    kind: str   # see repro.obs.schema.EVENT_SCHEMAS for the registry
    data: dict
    seq: int = 0


class _EventRecorder(list):
    """Event sink for a session run: stamps each appended
    :class:`SessionEvent` with a monotonic ``seq`` and, when the global
    telemetry registry is enabled, mirrors the event into it (counters
    for chunks/faults/retries, byte-clock spans for stage arrivals).
    A plain ``list`` still works wherever events are collected
    (``seq`` stays 0) — this class only adds bookkeeping."""

    def __init__(self, iterable=()):
        super().__init__()
        self._seq = 0
        for e in iterable:
            self.append(e)

    def append(self, event: SessionEvent) -> None:
        event = dataclasses.replace(event, seq=self._seq)
        self._seq += 1
        super().append(event)
        if _obs.enabled():
            _observe_event(event)


def _observe_event(event: SessionEvent) -> None:
    """Mirror one session event into the metrics registry. Runs only
    when telemetry is enabled; observes values the event already
    carries — it never touches the byte clock or the client."""
    reg, tracer = _obs.get_registry(), _obs.get_tracer()
    kind, d, t = event.kind, event.data, event.t_s
    if kind == "chunk":
        reg.counter("session_chunks_total", "transport chunks fed").inc()
        reg.counter("session_bytes_total",
                    "wire bytes delivered").inc(d["bytes"])
    elif kind == "stage_complete":
        reg.counter("session_stage_completions_total",
                    "stage arrivals").inc(stage=d["stage"])
        tracer.record("stage_arrival", sim_t0=0.0, sim_t1=t,
                      stage=d["stage"])
    elif kind == "result_ready":
        tracer.record("stage_process", sim_t0=d["process_start_s"],
                      sim_t1=t, stage=d["stage"])
    elif kind == "upgrade":
        reg.counter("session_upgrades_total",
                    "precision upgrades applied").inc(stage=d["stage"])
    elif kind == "decode_step":
        reg.counter("session_decode_steps_total", "decode steps").inc()
    elif kind == "fault":
        reg.counter("transport_faults_total",
                    "injected faults observed").inc(fault=d["fault"])
    elif kind == "retry":
        reg.counter("transport_retries_total", "delivery retries").inc()
        reg.histogram("transport_backoff_s",
                      "byte-clock backoff waits").observe(
                          d["backoff_s"], cause="retry")
    elif kind == "nack":
        reg.counter("transport_nacks_total", "unit NACKs sent").inc()
        reg.histogram("transport_backoff_s",
                      "byte-clock backoff waits").observe(
                          d["rerequest_backoff_s"], cause="nack")
    elif kind == "reconnect":
        reg.counter("transport_reconnects_total",
                    "stream reconnects").inc(reason=d["reason"])
        reg.histogram("transport_backoff_s",
                      "byte-clock backoff waits").observe(
                          d["backoff_s"], cause="reconnect")
    elif kind == "quarantine":
        reg.counter("transport_quarantined_total",
                    "units quarantined").inc()
    elif kind == "repair":
        reg.counter("transport_repairs_total",
                    "repair deliveries").inc(ok=d["ok"])
    elif kind == "accept_round":
        reg.counter("speculation_rounds_total",
                    "speculative accept rounds").inc()
    elif kind == "pool_window":
        reg.histogram("pool_window_tokens",
                      "tokens emitted per pool window").observe(
                          d["tokens"])


@dataclasses.dataclass
class SessionResult:
    """Outcome of a session run: milestones + the audit log + the live
    endpoints (client always; server in serving mode)."""

    events: list[SessionEvent]
    client: ProgressiveClient
    timeline: Timeline | None = None
    server: Any = None
    tokens: Any = None                # serving: (B, steps) array;
                                      # pool: {rid: [token, ...]}
    upgrades: list | None = None      # (decode step, new stage)
    stage_at_step: list | None = None
    admissions: list | None = None    # pool: (wall_s, rid) admission log
    transport: dict | None = None     # fault runs: injected/repaired stats

    def to_jsonl(self) -> str:
        return "\n".join(
            json.dumps({"t_s": e.t_s, "kind": e.kind, "seq": e.seq,
                        **e.data}, sort_keys=True)
            for e in self.events) + "\n"

    def events_of(self, kind: str) -> list[SessionEvent]:
        return [e for e in self.events if e.kind == kind]

    def speculation_summary(self) -> dict:
        """Aggregate the run's ``accept_round`` events (empty-safe):
        rounds, drafts proposed/accepted, overall acceptance rate."""
        rounds = self.events_of("accept_round")
        drafted = sum(e.data["k"] * len(e.data["accepted"]) for e in rounds)
        accepted = sum(sum(e.data["accepted"]) for e in rounds)
        return {"rounds": len(rounds), "drafted": drafted,
                "accepted": accepted,
                "rate": accepted / drafted if drafted else 0.0}


class Session:
    """Streams a serialized progressive model through a bandwidth trace
    into the real client, on a deterministic discrete-event clock.

    The stream is cut at transport-chunk boundaries (``chunk_bytes``
    grid) *and* at header/stage ends, so stage completions are stamped
    with the exact byte-clock time of their final byte while the client
    still sees arbitrary mid-plane chunk boundaries in between.
    """

    def __init__(self, blob: bytes, trace: BandwidthTrace, *,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 latency_s: float = 0.0, name: str = ""):
        if chunk_bytes < 1:
            raise ValueError("chunk_bytes must be >= 1")
        self.blob = bytes(blob)
        self.trace = trace
        self.chunk_bytes = chunk_bytes
        self.latency_s = latency_s
        self.name = name or getattr(trace, "name", "")
        meta, hdr = wire.decode_header(self.blob)
        self.meta = meta
        self.layout = wire.layout_from_header(meta, hdr)
        if self.layout.total_bytes != len(self.blob):
            raise ValueError(
                f"blob is {len(self.blob)} bytes but header declares "
                f"{self.layout.total_bytes}")
        ends = []
        off = hdr
        for sb in self.layout.stage_bytes:
            off += sb
            ends.append(off)
        self._stage_ends = ends           # wire offset at each stage's end
        self._header_end = hdr
        self._feed_plan_cache: list[tuple[int, int, float]] | None = None

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_model(cls, prog, trace: BandwidthTrace, *, schedule=None,
                   entropy_coded: bool = False, **kw) -> "Session":
        """Serialize a server-side ProgressiveModel and stream it.
        ``schedule``/``entropy_coded`` select the v2 accuracy-per-byte
        wire (see :mod:`repro.core.calibrate`); stage semantics carry
        over — v2 checkpoints play the role of stage ends."""
        return cls(wire.encode(prog, schedule=schedule,
                               entropy_coded=entropy_coded), trace, **kw)

    @classmethod
    def from_scenario(cls, blob: bytes, scenario, *, seed: int = 0,
                      **overrides) -> "Session":
        """Build from a named scenario (see
        :mod:`repro.transmission.scenarios`): trace, latency and chunk
        size come from the catalog entry; ``overrides`` win."""
        kw = dict(chunk_bytes=scenario.chunk_bytes,
                  latency_s=scenario.latency_s,
                  name=f"{scenario.name}@{seed}")
        kw.update(overrides)
        return cls(blob, scenario.make_trace(seed), **kw)

    @property
    def n_stages(self) -> int:
        return len(self._stage_ends)

    # -- byte plan ---------------------------------------------------------
    def _pieces(self) -> list[tuple[int, int]]:
        """(start, end) byte ranges: the chunk grid split additionally at
        the header end and every stage end."""
        total = len(self.blob)
        cuts = set(range(self.chunk_bytes, total, self.chunk_bytes))
        cuts.add(self._header_end)
        cuts.update(self._stage_ends)
        cuts.add(total)
        bounds = sorted(c for c in cuts if 0 < c <= total)
        pieces, prev = [], 0
        for b in bounds:
            if b > prev:
                pieces.append((prev, b))
                prev = b
        return pieces

    def _feed_plan(self) -> list[tuple[int, int, float]]:
        """(start, end, wall_arrival_s) per piece for a link that never
        idles (concurrent / serving mode). Chained
        ``time_to_deliver`` queries, so milestones are exact."""
        if self._feed_plan_cache is None:
            tt = 0.0
            plan = []
            for a, b in self._pieces():
                tt = self.trace.time_to_deliver(b - a, start_s=tt)
                plan.append((a, b, self.latency_s + tt))
            self._feed_plan_cache = plan
        return self._feed_plan_cache

    def stage_arrival_times(self) -> list[float]:
        """Wall time each stage's last byte lands (link never idling) —
        the same floats the serving run uses for its upgrades."""
        ends = set(self._stage_ends)
        return [w for _, b, w in self._feed_plan() if b in ends]

    # -- mode 1: the Fig.-4 schedules, executed ----------------------------
    def run_timeline(self, stage_costs: Sequence[StageCost], *,
                     concurrent: bool = True) -> SessionResult:
        """Execute a progressive transfer end to end: real bytes through
        the real client, processing charged on a single simulated
        compute queue (the paper's JS main thread + WebGL).

        w/ concurrency: the link never idles. w/o: the link idles while
        the compute queue drains, so the next stage's bytes are queried
        against the trace from the moment processing finished.
        """
        if len(stage_costs) != self.n_stages:
            raise ValueError(
                f"{len(stage_costs)} costs for {self.n_stages} stages")
        client = ProgressiveClient()
        events: list[SessionEvent] = _EventRecorder()
        download_done: list[float] = []
        result_ready: list[float] = []
        tt = 0.0          # trace-clock time of last delivered byte
        proc_free = 0.0   # wall time the compute queue frees up
        for a, b in self._pieces():
            if not concurrent and result_ready:
                # link idles until the previous stage's result is shown
                tt = max(tt, result_ready[-1] - self.latency_s)
            tt = self.trace.time_to_deliver(b - a, start_s=tt)
            wall = self.latency_s + tt
            before = client.stages_complete
            had_header = client.header_ready
            client.feed(self.blob[a:b])
            events.append(SessionEvent(wall, "chunk",
                                       {"bytes": b - a, "through": b}))
            if not had_header and client.header_ready:
                events.append(SessionEvent(wall, "header",
                                           {"bytes": self._header_end}))
            for s in range(before + 1, client.stages_complete + 1):
                # the co-simulation audit: the real decoder must complete
                # stage s exactly at the byte the header algebra predicts
                if b != self._stage_ends[s - 1]:
                    raise AssertionError(
                        f"client completed stage {s} at byte {b}, header "
                        f"layout says {self._stage_ends[s - 1]}")
                download_done.append(wall)
                events.append(SessionEvent(
                    wall, "stage_complete",
                    {"stage": s, "through": b}))
                start = max(wall, proc_free)
                proc_free = start + stage_costs[s - 1].total
                result_ready.append(proc_free)
                events.append(SessionEvent(
                    proc_free, "result_ready",
                    {"stage": s, "process_start_s": start}))
        if client.stages_complete != self.n_stages:
            raise AssertionError(
                f"stream exhausted at stage {client.stages_complete} "
                f"of {self.n_stages}")
        events.sort(key=lambda e: (e.t_s, e.seq))
        return SessionResult(
            events=events, client=client,
            timeline=Timeline(download_done=download_done,
                              result_ready=result_ready))

    def _make_feeder(self, client, events: list) -> "Callable[[float], None]":
        """Closure feeding wire bytes to ``client`` up to a wall time,
        appending chunk/header/stage_complete events as they land."""
        plan = self._feed_plan()
        state = {"idx": 0}

        def feed_until(t_wall: float) -> None:
            while state["idx"] < len(plan) and plan[state["idx"]][2] <= t_wall:
                a, b, w = plan[state["idx"]]
                before = client.stages_complete
                had_header = client.header_ready
                client.feed(self.blob[a:b])
                events.append(SessionEvent(w, "chunk",
                                           {"bytes": b - a, "through": b}))
                if not had_header and client.header_ready:
                    events.append(SessionEvent(
                        w, "header", {"bytes": self._header_end}))
                for s in range(before + 1, client.stages_complete + 1):
                    events.append(SessionEvent(
                        w, "stage_complete", {"stage": s, "through": b}))
                state["idx"] += 1

        return feed_until

    def _make_transport(self, client, events: list,
                        faults: FaultTrace | None,
                        fault_policy: FaultPolicy | None):
        """Pick the byte-delivery engine for a serving run: the plain
        precomputed feed plan when the channel is trusted, or a
        :class:`_FaultRunner` when a fault trace / fault policy is in
        play. Returns ``(feed_until, runner_or_None)``."""
        if faults is None and fault_policy is None:
            return self._make_feeder(client, events), None
        if faults is not None and not self.layout.integrity:
            raise ValueError(
                "fault injection requires the v3 integrity wire — "
                "encode the stream with wire.encode(model, integrity=True) "
                "so corrupt units can be detected and quarantined")
        runner = _FaultRunner(self, client, events,
                              faults, fault_policy or FaultPolicy())
        return runner.feed_until, runner

    # -- mode 2: the operational serve path --------------------------------
    def run_serving(self, model, prog, *, decode_steps: int, batch: dict,
                    step_time_s: float | None = None,
                    max_len: int | None = None,
                    resident: str | None = None,
                    speculative=None, mesh=None,
                    faults: FaultTrace | None = None,
                    fault_policy: FaultPolicy | None = None) -> SessionResult:
        """Drive a real ProgressiveServer from the byte stream: the
        server sits on the client's PlaneStore (one ingest per stage,
        one batched Pallas launch per container dtype) and decodes real
        tokens; the simulated decode clock ticks ``step_time_s`` per
        step, and upgrades happen between steps exactly when the trace
        delivered each stage. Tokens, upgrade steps and the event log
        are bit-deterministic for a fixed (blob, trace, seed).

        ``resident`` selects the server's weight residency (default
        ``"fp"``): ``"fp"`` re-materializes float weights per upgrade
        (the paper's client); ``"quantized"`` decodes straight from the
        client's uint accumulators (no fp weight copy, upgrades are
        metadata-only — see
        :class:`~repro.serving.engine.ProgressiveServer`).

        ``speculative`` (a :class:`~repro.serving.speculative.SpecConfig`
        or truthy for defaults) swaps the server for the
        self-speculative engine: a truncated-bits view of the same
        store drafts, the full view verifies, and per-round accept-rate
        events join the audit log on the byte clock. Speculation
        implies quantized residency (the draft IS a second metadata
        view over the resident accumulators), so passing ``resident``
        together with ``speculative`` is a contradiction and raises
        ``ValueError`` instead of being silently ignored.
        """
        from repro.serving.engine import ProgressiveServer, WireStoreReceiver
        from repro.serving.speculative import SpecConfig, SpeculativeEngine

        # mesh=None: single device. With a serving mesh the client's
        # store shards across its model axis (shard-local ingest) and
        # the engine decodes through sharded dispatch — token-identical
        # to the single-device session at every precision stage.
        client = ProgressiveClient(mesh=mesh)
        receiver = WireStoreReceiver(client, prog)
        if speculative:
            if resident is not None:
                raise ValueError(
                    f"resident={resident!r} conflicts with speculative "
                    f"serving: the draft is a metadata view over the "
                    f"quantized-resident accumulators, so residency is "
                    f"fixed at 'quantized' — drop the resident argument")
            spec = (speculative if isinstance(speculative, SpecConfig)
                    else SpecConfig())
            if max_len is None:
                # headroom so end-of-generation verify blocks keep full
                # k (the engine validates it and would raise otherwise)
                max_len = (batch["tokens"].shape[1] + decode_steps
                           + spec.k_max + 1)
            server = SpeculativeEngine(model, prog, max_len=max_len,
                                       receiver=receiver, spec=spec,
                                       mesh=mesh)
        else:
            if max_len is None:
                max_len = batch["tokens"].shape[1] + decode_steps
            server = ProgressiveServer(model, prog, max_len=max_len,
                                       receiver=receiver,
                                       resident=resident or "fp",
                                       mesh=mesh)
        events: list[SessionEvent] = _EventRecorder()
        arrivals = self.stage_arrival_times()
        feed_until, runner = self._make_transport(client, events,
                                                  faults, fault_policy)

        # cold start: serve as soon as stage 1 is in. On a faulty
        # channel stage 1 lands whenever its units verify, not at the
        # clean-trace arrival time — ask the runner.
        if runner is not None:
            t_cold = runner.run_until_stage(1)
        else:
            t_cold = arrivals[0]
            feed_until(t_cold)
        if client.stages_complete < 1:
            raise AssertionError("stage 1 not complete at its arrival time")
        server.receive_stage()
        server.start(batch)
        events.append(SessionEvent(
            t_cold, "cold_start",
            {"stage": server.stage, "prompt_len": int(batch["tokens"].shape[1])}))

        if step_time_s is None:
            # fixed decode cadence spanning the rest of the download
            step_time_s = max((arrivals[-1] - t_cold) / max(decode_steps, 1),
                              1e-6)

        def step_wall(i: int) -> float:
            return t_cold + (i + 1) * step_time_s

        def stage_arrival(i: int) -> bool:
            feed_until(step_wall(i))
            return receiver.stages_complete > server.stage

        if speculative:
            def on_round(rec: dict) -> None:
                # stamp the round where its last emitted token lands on
                # the byte clock; min() because slots emit raggedly
                t = step_wall(max(min(rec["emitted"]) - 1, 0))
                events.append(SessionEvent(t, "accept_round", {
                    "round": rec["round"], "k": rec["k"],
                    "accepted": rec["accepted"], "rate": rec["rate"],
                    "stage": rec["stage"],
                    "effective_bits": {
                        "draft": min(server.current_draft_bits(),
                                     server.received_bits_now()),
                        "target": server.received_bits_now()}}))

            res = server.decode(decode_steps, stage_arrival=stage_arrival,
                                on_round=on_round)
        else:
            res = server.decode(decode_steps, stage_arrival=stage_arrival)
        for i, stage in enumerate(res.stage_at_step):
            events.append(SessionEvent(
                step_wall(i), "decode_step", {"step": i, "stage": stage}))
        for step, stage in res.upgrades:
            events.append(SessionEvent(
                step_wall(step), "upgrade", {"step": step, "stage": stage}))
        transport = None
        if runner is not None:
            # converge the transport: every quarantined unit repaired,
            # every stage verified — the acceptance bar is that the
            # final store is bit-identical to a clean stream's
            runner.pump_all()
            transport = runner.summary()
            events.append(SessionEvent(
                runner.wall(), "transport_summary", transport))
        events.sort(key=lambda e: (e.t_s, e.seq))
        return SessionResult(
            events=events, client=client, server=server,
            tokens=res.tokens, upgrades=res.upgrades,
            stage_at_step=res.stage_at_step, transport=transport)

    # -- mode 3: continuous batching under a flash crowd -------------------
    def run_serving_pool(self, model, prog, *, prompts: Sequence,
                         arrival_offsets_s: Sequence[float] | None = None,
                         max_new_tokens: int = 8,
                         n_slots: int = 4,
                         max_len: int | None = None,
                         resident: str | None = None,
                         step_time_s: float | None = None,
                         dispatch_window: int = 4,
                         chunked_prefill: bool | None = None,
                         speculative=None, mesh=None,
                         faults: FaultTrace | None = None,
                         fault_policy: FaultPolicy | None = None,
                         ) -> SessionResult:
        """Flash-crowd serving: N requests join mid-download over ONE
        shared byte stream, and a :class:`~repro.serving.engine.
        SlotPoolEngine` serves them all from the client's PlaneStore —
        staggered admissions into free slots, evictions on completion,
        precision upgrades between batched windows, one decode
        executable throughout.

        ``prompts[i]`` becomes admissible ``arrival_offsets_s[i]``
        seconds after the cold start (default: all at cold start). The
        simulated decode clock ticks ``step_time_s`` per batched step;
        idle rounds (pool empty, crowd not yet arrived) advance the
        clock without dispatching. Deterministic for a fixed
        (blob, trace, prompts, offsets).

        ``chunked_prefill`` is forwarded to the engine (None = auto:
        on for every arch without cross-attention): admissions stream
        prompt KV into pooled cache rows in ``prefill_chunk``-token
        blocks interleaved with decode steps, instead of a batch-1
        prefill + cache copy per admit.

        ``speculative`` (a SpecConfig or truthy) swaps the engine for
        :class:`~repro.serving.speculative.SpeculativeSlotPool`: every
        pool 'step' becomes a draft+verify round, acceptance records
        join the audit log at flush boundaries, and passing
        ``resident`` alongside raises ``ValueError`` (speculation
        implies quantized residency).

        Note: this drives the engine step/flush primitives directly
        rather than ``SlotPoolEngine.run`` because admissions and byte
        feeding are gated on the *simulated wall clock*, which only
        this session knows — keep the two loops' flush/evict
        bookkeeping in sync when changing either."""
        from repro.serving.engine import (PoolRequest, SlotPoolEngine,
                                          WireStoreReceiver)

        n_req = len(prompts)
        if arrival_offsets_s is None:
            arrival_offsets_s = [0.0] * n_req
        if len(arrival_offsets_s) != n_req:
            raise ValueError("one arrival offset per prompt")

        client = ProgressiveClient(mesh=mesh)
        receiver = WireStoreReceiver(client, prog)
        if speculative:
            from repro.serving.speculative import (SpecConfig,
                                                   SpeculativeSlotPool)

            if resident is not None:
                raise ValueError(
                    f"resident={resident!r} conflicts with speculative "
                    f"serving: the draft is a metadata view over the "
                    f"quantized-resident accumulators, so residency is "
                    f"fixed at 'quantized' — drop the resident argument")
            spec = (speculative if isinstance(speculative, SpecConfig)
                    else SpecConfig())
            if max_len is None:
                # headroom so end-of-budget verify blocks keep full k
                # (submit validates it per request and raises otherwise)
                max_len = (max(len(p) for p in prompts) + max_new_tokens
                           + spec.k_max + 1)
            engine = SpeculativeSlotPool(model, prog, n_slots=n_slots,
                                         max_len=max_len, receiver=receiver,
                                         spec=spec,
                                         dispatch_window=dispatch_window,
                                         chunked_prefill=chunked_prefill,
                                         mesh=mesh)
        else:
            if max_len is None:
                max_len = max(len(p) for p in prompts) + max_new_tokens
            engine = SlotPoolEngine(model, prog, n_slots=n_slots,
                                    max_len=max_len, receiver=receiver,
                                    resident=resident or "fp",
                                    dispatch_window=dispatch_window,
                                    chunked_prefill=chunked_prefill,
                                    mesh=mesh)
        events: list[SessionEvent] = _EventRecorder()
        arrivals = self.stage_arrival_times()
        feed_until, runner = self._make_transport(client, events,
                                                  faults, fault_policy)

        if runner is not None:
            t_cold = runner.run_until_stage(1)
        else:
            t_cold = arrivals[0]
            feed_until(t_cold)
        if client.stages_complete < 1:
            raise AssertionError("stage 1 not complete at its arrival time")
        engine.receive_stage()
        events.append(SessionEvent(
            t_cold, "cold_start",
            {"stage": engine.stage, "n_slots": n_slots, "clients": n_req}))

        total_budget = n_req * max_new_tokens
        if step_time_s is None:
            step_time_s = max(
                (arrivals[-1] - t_cold) / max(total_budget, 1), 1e-6)

        order = sorted(range(n_req), key=lambda i: (arrival_offsets_s[i], i))
        next_req = 0
        admissions: list[tuple[float, int]] = []  # actual slot admissions
        seen_admits = 0
        rounds = 0
        # every request decodes max_new_tokens steps; idle rounds are
        # bounded by the crowd span, so this cap is never the exit path
        max_rounds = total_budget + n_req + int(
            max(arrival_offsets_s) / step_time_s) + 8
        if engine.chunked_prefill:
            # chunked admission consumes prompts one block per round;
            # worst case (no decode overlap) that adds a round per chunk
            c = engine.prefill_chunk
            max_rounds += sum((len(p) + c - 1) // c for p in prompts)

        def wall() -> float:
            return t_cold + (rounds + 1) * step_time_s

        def admit_due(t: float) -> None:
            nonlocal next_req
            while next_req < n_req and \
                    t_cold + arrival_offsets_s[order[next_req]] <= t:
                rid = order[next_req]
                engine.submit(PoolRequest(
                    rid=rid, prompt=prompts[rid],
                    max_new_tokens=max_new_tokens))
                events.append(SessionEvent(t, "submit", {"rid": rid}))
                next_req += 1

        def log_admissions(t: float) -> None:
            # the 'admit' event stamps when a request actually took a
            # slot (engine._admit), not when it was submitted — a full
            # pool queues submissions until an eviction frees a slot
            nonlocal seen_admits
            for rid in engine.admitted_order[seen_admits:]:
                admissions.append((t, rid))
                events.append(SessionEvent(t, "admit", {"rid": rid}))
            seen_admits = len(engine.admitted_order)

        admit_due(t_cold)
        log_admissions(t_cold)
        evicted_logged: set[int] = set()
        accepts_logged = 0

        def log_evictions(t: float) -> None:
            for rid in sorted(engine.completed - evicted_logged):
                events.append(SessionEvent(t, "evict", {"rid": rid}))
                evicted_logged.add(rid)

        def log_accepts(t: float) -> None:
            # speculative pool: per-round acceptance records become
            # host-visible at flush; stamp them on the byte clock
            nonlocal accepts_logged
            if not speculative:
                return
            for rec in engine.accept_log[accepts_logged:]:
                events.append(SessionEvent(t, "accept_round", dict(rec)))
            accepts_logged = len(engine.accept_log)

        while (next_req < n_req or engine.queue or
               any(not s.free for s in engine.slots)):
            if rounds >= max_rounds:
                raise AssertionError("slot-pool run did not converge")
            t = wall()
            feed_until(t)
            if engine.upgrade_if_available():
                events.append(SessionEvent(
                    t, "upgrade",
                    {"step": engine._step_count, "stage": engine.stage}))
            admit_due(t)
            log_admissions(t)
            if any(not s.free for s in engine.slots):
                snapshot = engine.step()
                if len(engine._pending) >= dispatch_window:
                    stats = engine.flush()
                    events.append(SessionEvent(
                        t, "pool_window",
                        {"steps": stats.steps,
                         "tokens": stats.tokens_emitted,
                         "active": len(snapshot),
                         "stage": engine.stage}))
                    log_accepts(t)
                    engine._admit_from_queue()
                    log_admissions(t)
                    log_evictions(t)
                rounds += 1
            elif engine.queue:
                # every active slot budget-evicted mid-window: flush the
                # in-flight tail so the queue can take the freed slots
                stats = engine.flush()
                if stats is not None:
                    events.append(SessionEvent(
                        t, "pool_window",
                        {"steps": stats.steps,
                         "tokens": stats.tokens_emitted,
                         "active": 0, "stage": engine.stage}))
                log_accepts(t)
                engine._admit_from_queue()
                log_admissions(t)
                log_evictions(t)
                rounds += 1
            else:
                # idle pool, crowd still to come (queue empty + no active
                # slot implies next_req < n_req by the loop condition):
                # fast-forward the clock to the next arrival instead of
                # spinning one round per step_time_s tick (a fast link
                # makes that microscopic)
                nxt = t_cold + arrival_offsets_s[order[next_req]]
                skip = int((nxt - t_cold) / step_time_s) - 1
                rounds = max(rounds + 1, min(skip, max_rounds - 1))
        stats = engine.flush()
        t_end = wall()
        if stats is not None:
            events.append(SessionEvent(
                t_end, "pool_window",
                {"steps": stats.steps, "tokens": stats.tokens_emitted,
                 "active": 0, "stage": engine.stage}))
        log_accepts(t_end)
        log_evictions(t_end)
        transport = None
        if runner is not None:
            runner.pump_all()
            transport = runner.summary()
            events.append(SessionEvent(
                runner.wall(), "transport_summary", transport))
        events.sort(key=lambda e: (e.t_s, e.seq))
        return SessionResult(
            events=events, client=client, server=engine,
            tokens={rid: list(v) for rid, v in engine.outputs.items()},
            upgrades=list(engine.upgrades),
            admissions=admissions, transport=transport)


class _FaultRunner:
    """Stateful byte-delivery engine for a faulty channel.

    Couples three clocks/queues deterministically:

    * the stream queue — undelivered ``(a, b)`` wire ranges on the
      chunk grid (rebuilt from the client's resume cursor after a
      disconnect or desync);
    * the repair queue — quarantined units awaiting re-request, each
      with its own attempt counter and backoff-derived ready time;
    * the trace clock ``clock`` (plus ``lat``, the accumulated
      per-connection latency) — every delivery advances it via
      ``time_to_deliver`` so all fault/retry/repair events land on the
      byte clock and the whole run is replayable from
      (blob, trace, faults, policy).

    Recovery routing: isolated CRC failures -> per-unit NACK/repair;
    two consecutive stream-unit failures, any disconnect, or a dead
    header -> reconnect and replay from the client's cursor; a
    delivery exceeding ``chunk_timeout_s`` -> abandon + backoff +
    retry. Any target exceeding ``max_retries`` raises
    :class:`TransportError`.
    """

    DESYNC_AFTER = 2  # consecutive stream-unit failures -> assume desync

    def __init__(self, session: "Session", client: ProgressiveClient,
                 events: list, faults: FaultTrace | None,
                 policy: FaultPolicy):
        self.session = session
        self.client = client
        self.events = events
        self.policy = policy
        self.injector = faults.start() if faults is not None else None
        self.rng = np.random.default_rng(policy.seed)
        self.queue: list[tuple[int, int]] = list(session._pieces())
        self.clock = 0.0            # trace-clock time of last delivery
        self.lat = session.latency_s
        self.not_before = 0.0       # trace-clock floor (backoff idles)
        self.repairs: list[dict] = []
        self.known_nacks: set[int] = set()
        self.stream_attempt = 0
        self.reconnects = 0
        self.repaired_units = 0
        self.consec_stream_nacks = 0
        self.done = False
        self._last_verified = 0
        # unit seq -> absolute (a, b) wire range, for re-requests
        if session.layout.integrity:
            offs = session.layout.unit_offsets()
            sizes = [e[2] for st in session.layout.stages for e in st]
            self._unit_ranges = [(o, o + n) for o, n in zip(offs, sizes)]
        else:
            self._unit_ranges = []

    # -- clocks ------------------------------------------------------------
    def wall(self) -> float:
        return self.lat + self.clock

    def _log(self, t: float, kind: str, data: dict) -> None:
        self.events.append(SessionEvent(t, kind, data))

    # -- candidate selection -------------------------------------------------
    def _next_repair(self) -> dict | None:
        if not self.repairs:
            return None
        return min(self.repairs, key=lambda r: (r["ready_wall"], r["seq"]))

    def _peek(self):
        """Earliest deliverable item: ('repair'|'stream', item,
        start_trace, end_trace). Repairs win ties — the server is
        stalled at the last verified stage until they land."""
        trace = self.session.trace
        cands = []
        r = self._next_repair()
        if r is not None:
            a, b = self._unit_ranges[r["seq"]]
            start = max(self.clock, self.not_before,
                        r["ready_wall"] - self.lat)
            end = trace.time_to_deliver(b - a, start_s=start)
            cands.append((end, 0, "repair", r, start))
        if self.queue:
            a, b = self.queue[0]
            start = max(self.clock, self.not_before)
            end = trace.time_to_deliver(b - a, start_s=start)
            cands.append((end, 1, "stream", (a, b), start))
        if not cands:
            return None
        end, _, kind, item, start = min(cands)
        return kind, item, start, end

    def next_wall(self) -> float | None:
        """Wall time of the next event (delivery or timeout), without
        committing it."""
        got = self._peek()
        if got is None:
            if self.done or self._reconcile_end_of_stream(dry=True):
                return None
            return self.wall()  # recovery bookkeeping is due now
        _, _, start, end = got
        if end - start > self.policy.chunk_timeout_s:
            return self.lat + start + self.policy.chunk_timeout_s
        return self.lat + end

    # -- the drivers ---------------------------------------------------------
    def feed_until(self, t_wall: float) -> None:
        while True:
            nxt = self.next_wall()
            if nxt is None or nxt > t_wall:
                return
            self.step()

    def pump_all(self) -> None:
        """Drive the transport to completion (or TransportError)."""
        cap = 20_000 + len(self._unit_ranges) * (self.policy.max_retries + 2) * 4
        n = 0
        while self.step():
            n += 1
            if n > cap:
                raise AssertionError(
                    "fault transport did not converge (internal bug: "
                    f"{n} steps, cursor {self.client.resume_cursor})")

    def run_until_stage(self, k: int) -> float:
        while self.client.stages_complete < k:
            if not self.step():
                raise AssertionError(
                    f"stream ended at stage {self.client.stages_complete} "
                    f"before reaching stage {k}")
        return self.wall()

    def step(self) -> bool:
        """Perform the next transport event. Returns False when the
        stream is fully delivered and every quarantined unit repaired."""
        if self.done:
            return False
        got = self._peek()
        if got is None:
            if self._reconcile_end_of_stream(dry=False):
                self.done = True
                return False
            return True  # recovery scheduled new work
        kind, item, start, end = got
        if end - start > self.policy.chunk_timeout_s:
            self._on_timeout(kind, item, start)
            return True
        if kind == "repair":
            self._do_repair(item, end)
        else:
            self._do_stream(item, end)
        return True

    # -- timeout / reconnect --------------------------------------------------
    def _on_timeout(self, kind: str, item, start: float) -> None:
        p = self.policy
        self.clock = start + p.chunk_timeout_s
        if kind == "repair":
            item["attempt"] += 1
            attempt, target = item["attempt"], f"unit:{item['seq']}"
            if attempt > p.max_retries:
                raise TransportError(
                    f"unit {item['seq']} timed out after {p.max_retries} "
                    f"retries ({p.chunk_timeout_s}s each)")
            back = p.backoff_s(attempt, self.rng)
            item["ready_wall"] = self.wall() + back + self.session.latency_s
        else:
            self.stream_attempt += 1
            attempt, target = self.stream_attempt, "stream"
            if attempt > p.max_retries:
                raise TransportError(
                    f"stream chunk {item} timed out after {p.max_retries} "
                    f"retries ({p.chunk_timeout_s}s each)")
            back = p.backoff_s(attempt, self.rng)
            self.not_before = self.clock + back
            self.lat += self.session.latency_s  # new connection
            self.reconnects += 1
        self._log(self.wall(), "fault",
                  {"fault": "timeout", "target": target,
                   "waited_s": p.chunk_timeout_s})
        self._log(self.wall(), "retry",
                  {"target": target, "attempt": attempt,
                   "backoff_s": round(back, 6)})

    def _reconnect_from_cursor(self, reason: str, *, resync: bool) -> None:
        """Drop the dead connection and replay the stream from the
        client's durable cursor. ``resync=True`` additionally rewinds
        the client to its first unverified unit (desync recovery) and
        cancels scheduled repairs the replay will cover."""
        if resync:
            seq, off = self.client.rewind_to_gap()
            self.repairs = [r for r in self.repairs if r["seq"] < seq]
            self.known_nacks = {s for s in self.known_nacks if s < seq}
        else:
            self.client.drop_unconsumed()
            seq, off = self.client.resume_cursor
        self.stream_attempt += 1
        if self.stream_attempt > self.policy.max_retries:
            raise TransportError(
                f"stream recovery ({reason}) exhausted "
                f"{self.policy.max_retries} retries at cursor "
                f"({seq}, {off})")
        back = self.policy.backoff_s(self.stream_attempt, self.rng)
        self.not_before = self.clock + back
        self.lat += self.session.latency_s
        self.reconnects += 1
        total = len(self.session.blob)
        self.queue = [(max(a, off), b)
                      for a, b in self.session._pieces()
                      if b > off] if off < total else []
        self.consec_stream_nacks = 0
        self._log(self.wall(), "reconnect",
                  {"reason": reason, "cursor": [seq, off],
                   "attempt": self.stream_attempt,
                   "backoff_s": round(back, 6)})
        self._log(self.wall(), "resume", {"offset": off, "unit_seq": seq})

    # -- deliveries ------------------------------------------------------------
    def _feed(self, data: bytes, through: int, t: float) -> None:
        client = self.client
        before = client.stages_complete
        had_header = client.header_ready
        client.feed(data)
        self._log(t, "chunk", {"bytes": len(data), "through": through})
        if not had_header and client.header_ready:
            self._log(t, "header", {"bytes": self.session._header_end})
        for s in range(before + 1, client.stages_complete + 1):
            self._log(t, "stage_complete", {"stage": s, "through": through})

    def _do_stream(self, piece: tuple[int, int], end: float) -> None:
        a, b = piece
        data = self.session.blob[a:b]
        if self.injector is not None:
            d = self.injector.deliver(data)
        else:
            from repro.transmission.simulator import ChunkDelivery
            d = ChunkDelivery(data=data)
        if d.reorder and len(self.queue) > 1:
            self.queue[0], self.queue[1] = self.queue[1], self.queue[0]
            self._log(self.wall(), "fault",
                      {"fault": "reorder", "chunk": [a, b]})
            return
        self.clock = end
        if d.kind is not None and not d.reorder:
            detail = dict(d.detail or {})
            # NB "fault", not "kind": the payload is flattened next to
            # the envelope in to_jsonl, so a payload "kind" would
            # silently overwrite the event kind in the exported log
            detail.update({"fault": d.kind, "chunk": [a, b]})
            self._log(self.wall(), "fault", detail)
        self._feed(d.data, b, self.wall())
        self.queue.pop(0)
        if d.duplicate:
            self.clock = self.session.trace.time_to_deliver(
                len(d.data), start_s=self.clock)
            self._feed(d.data, b, self.wall())
        self._after_feed(disconnected=d.disconnect)

    def _do_repair(self, r: dict, end: float) -> None:
        seq = r["seq"]
        a, b = self._unit_ranges[seq]
        data = self.session.blob[a:b]
        if self.injector is not None:
            d = self.injector.deliver(data)
            data = d.data
            if d.kind is not None:
                self._log(self.lat + end, "fault",
                          {"fault": d.kind, "target": f"unit:{seq}"})
        self.clock = end
        before = self.client.stages_complete
        ok = self.client.feed_repair(seq, data)
        t = self.wall()
        self._log(t, "repair", {"unit": seq, "attempt": r["attempt"],
                                "ok": bool(ok)})
        for s in range(before + 1, self.client.stages_complete + 1):
            self._log(t, "stage_complete", {"stage": s, "repair": seq})
        if ok:
            self.repairs.remove(r)
            self.repaired_units += 1
        else:
            r["attempt"] += 1
            if r["attempt"] > self.policy.max_retries:
                raise TransportError(
                    f"unit {seq} still corrupt after "
                    f"{self.policy.max_retries} repair attempts: "
                    f"{self.client.nacks.get(seq, 'unknown reason')}")
            back = self.policy.backoff_s(r["attempt"], self.rng)
            r["ready_wall"] = t + back + self.session.latency_s
            self._log(t, "retry", {"target": f"unit:{seq}",
                                   "attempt": r["attempt"],
                                   "backoff_s": round(back, 6)})

    # -- post-delivery bookkeeping ----------------------------------------------
    def _after_feed(self, *, disconnected: bool) -> None:
        client, t = self.client, self.wall()
        if client.header_failed:
            self._log(t, "quarantine",
                      {"target": "header",
                       "reason": client.quarantine_log[-1]["reason"]})
            self._reconnect_from_cursor("header_corrupt", resync=False)
            return
        new_nacks = [(s, r) for s, r in sorted(client.nacks.items())
                     if s not in self.known_nacks]
        for seq, reason in new_nacks:
            self.known_nacks.add(seq)
            # payload field is "unit" (not "seq"): to_jsonl flattens the
            # payload next to the envelope, where "seq" is the event
            # sequence number
            self._log(t, "quarantine", {"unit": seq, "reason": reason})
        if client.verified_units > self._last_verified:
            self._last_verified = client.verified_units
            self.consec_stream_nacks = 0
            self.stream_attempt = 0
        self.consec_stream_nacks += len(new_nacks)
        if disconnected:
            self._log(t, "fault", {"fault": "disconnect",
                                   "cursor": list(client.resume_cursor)})
            self._reconnect_from_cursor("disconnect", resync=False)
            return
        if (self.consec_stream_nacks >= self.DESYNC_AFTER
                and not client.complete):
            self._log(t, "fault",
                      {"fault": "desync",
                       "consecutive_failures": self.consec_stream_nacks})
            self._reconnect_from_cursor("desync", resync=True)
            return
        for seq, _ in new_nacks:
            back = self.policy.backoff_s(0, self.rng)
            self.repairs.append({
                "seq": seq, "attempt": 0,
                "ready_wall": t + back + self.session.latency_s})
            self._log(t, "nack", {"unit": seq,
                                  "rerequest_backoff_s": round(back, 6)})

    # -- end-of-stream reconciliation ---------------------------------------------
    def _reconcile_end_of_stream(self, *, dry: bool) -> bool:
        """Called when both queues are empty. True -> fully delivered;
        False -> scheduled recovery work (never in ``dry`` mode)."""
        client = self.client
        if client.complete:
            return True
        if dry:
            return False
        if not client.header_ready:
            # header truncated or its length field corrupted: the only
            # cure is a fresh stream from byte 0
            client._buf.clear()
            client._cursor = 0
            self._reconnect_from_cursor("header_incomplete", resync=False)
            return False
        if client.integrity:
            seq, off = client.resume_cursor
            if off < len(self.session.blob) or client.nacks:
                self._reconnect_from_cursor("tail_missing", resync=True)
                return False
        raise AssertionError(
            "stream exhausted but client incomplete at stage "
            f"{client.stages_complete} (no recovery path — is the blob "
            "truncated at the source?)")

    def summary(self) -> dict:
        inj = self.injector
        return {
            "injected": dict(inj.counts) if inj else {},
            "deliveries": inj.deliveries if inj else 0,
            "quarantined": len(self.client.quarantine_log),
            "repaired_units": self.repaired_units,
            "duplicate_units": self.client.duplicate_units,
            "reconnects": self.reconnects,
            "pending_nacks": len(self.client.nacks),
            "verified_units": self.client.verified_units,
            "framing_overhead": (
                wire.framing_overhead(self.session.meta)
                if self.session.layout.integrity else None),
        }
