"""Deterministic co-simulation: real bytes, simulated clock.

A :class:`Session` couples the byte clock of a
:class:`~repro.transmission.simulator.BandwidthTrace` to the *real*
receive path: the serialized ``wire`` stream is cut into
transport-sized chunks, each chunk is fed to a real
:class:`~repro.transmission.client.ProgressiveClient` (which ingests
planes into the device-resident PlaneStore), and every milestone is
stamped with the exact time the trace says those bytes landed
(``time_to_deliver`` — derived, never measured). Processing costs come
from a supplied cost model, so runs are bit- and time-deterministic on
any machine.

Two run modes:

* :meth:`Session.run_timeline` — the Fig.-4 schedules *executed*: the
  real client decodes the stream while a single simulated compute queue
  charges per-stage costs. Its Timeline must agree with the pure
  algebra in :mod:`~repro.transmission.scheduler` to <1e-9 s (pinned by
  tests) — the algebra and the execution can no longer silently
  diverge.
* :meth:`Session.run_serving` — the operational path: a real
  :class:`~repro.serving.engine.ProgressiveServer` sits on the *same*
  store the client fills (no second ingest) and greedy-decodes real
  tokens, upgrading precision between steps exactly when the trace
  delivered each stage.

Every run produces a single auditable event log (bytes fed, header,
stage completions, upgrades, decode steps, per-step stage) that can be
dumped as JSONL for CI artifacts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Sequence

from repro.core import wire
from repro.transmission.client import ProgressiveClient
from repro.transmission.scheduler import StageCost, Timeline
from repro.transmission.simulator import BandwidthTrace

DEFAULT_CHUNK_BYTES = 64 * 1024


@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """One entry of the audit log. ``data`` is JSON-able."""

    t_s: float
    kind: str   # chunk | header | stage_complete | result_ready |
                # cold_start | upgrade | decode_step
    data: dict


@dataclasses.dataclass
class SessionResult:
    """Outcome of a session run: milestones + the audit log + the live
    endpoints (client always; server in serving mode)."""

    events: list[SessionEvent]
    client: ProgressiveClient
    timeline: Timeline | None = None
    server: Any = None
    tokens: Any = None                # serving: (B, steps) array;
                                      # pool: {rid: [token, ...]}
    upgrades: list | None = None      # (decode step, new stage)
    stage_at_step: list | None = None
    admissions: list | None = None    # pool: (wall_s, rid) admission log

    def to_jsonl(self) -> str:
        return "\n".join(
            json.dumps({"t_s": e.t_s, "kind": e.kind, **e.data},
                       sort_keys=True)
            for e in self.events) + "\n"

    def events_of(self, kind: str) -> list[SessionEvent]:
        return [e for e in self.events if e.kind == kind]

    def speculation_summary(self) -> dict:
        """Aggregate the run's ``accept_round`` events (empty-safe):
        rounds, drafts proposed/accepted, overall acceptance rate."""
        rounds = self.events_of("accept_round")
        drafted = sum(e.data["k"] * len(e.data["accepted"]) for e in rounds)
        accepted = sum(sum(e.data["accepted"]) for e in rounds)
        return {"rounds": len(rounds), "drafted": drafted,
                "accepted": accepted,
                "rate": accepted / drafted if drafted else 0.0}


class Session:
    """Streams a serialized progressive model through a bandwidth trace
    into the real client, on a deterministic discrete-event clock.

    The stream is cut at transport-chunk boundaries (``chunk_bytes``
    grid) *and* at header/stage ends, so stage completions are stamped
    with the exact byte-clock time of their final byte while the client
    still sees arbitrary mid-plane chunk boundaries in between.
    """

    def __init__(self, blob: bytes, trace: BandwidthTrace, *,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 latency_s: float = 0.0, name: str = ""):
        if chunk_bytes < 1:
            raise ValueError("chunk_bytes must be >= 1")
        self.blob = bytes(blob)
        self.trace = trace
        self.chunk_bytes = chunk_bytes
        self.latency_s = latency_s
        self.name = name or getattr(trace, "name", "")
        meta, hdr = wire.decode_header(self.blob)
        self.layout = wire.layout_from_header(meta, hdr)
        if self.layout.total_bytes != len(self.blob):
            raise ValueError(
                f"blob is {len(self.blob)} bytes but header declares "
                f"{self.layout.total_bytes}")
        ends = []
        off = hdr
        for sb in self.layout.stage_bytes:
            off += sb
            ends.append(off)
        self._stage_ends = ends           # wire offset at each stage's end
        self._header_end = hdr
        self._feed_plan_cache: list[tuple[int, int, float]] | None = None

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_model(cls, prog, trace: BandwidthTrace, *, schedule=None,
                   entropy_coded: bool = False, **kw) -> "Session":
        """Serialize a server-side ProgressiveModel and stream it.
        ``schedule``/``entropy_coded`` select the v2 accuracy-per-byte
        wire (see :mod:`repro.core.calibrate`); stage semantics carry
        over — v2 checkpoints play the role of stage ends."""
        return cls(wire.encode(prog, schedule=schedule,
                               entropy_coded=entropy_coded), trace, **kw)

    @classmethod
    def from_scenario(cls, blob: bytes, scenario, *, seed: int = 0,
                      **overrides) -> "Session":
        """Build from a named scenario (see
        :mod:`repro.transmission.scenarios`): trace, latency and chunk
        size come from the catalog entry; ``overrides`` win."""
        kw = dict(chunk_bytes=scenario.chunk_bytes,
                  latency_s=scenario.latency_s,
                  name=f"{scenario.name}@{seed}")
        kw.update(overrides)
        return cls(blob, scenario.make_trace(seed), **kw)

    @property
    def n_stages(self) -> int:
        return len(self._stage_ends)

    # -- byte plan ---------------------------------------------------------
    def _pieces(self) -> list[tuple[int, int]]:
        """(start, end) byte ranges: the chunk grid split additionally at
        the header end and every stage end."""
        total = len(self.blob)
        cuts = set(range(self.chunk_bytes, total, self.chunk_bytes))
        cuts.add(self._header_end)
        cuts.update(self._stage_ends)
        cuts.add(total)
        bounds = sorted(c for c in cuts if 0 < c <= total)
        pieces, prev = [], 0
        for b in bounds:
            if b > prev:
                pieces.append((prev, b))
                prev = b
        return pieces

    def _feed_plan(self) -> list[tuple[int, int, float]]:
        """(start, end, wall_arrival_s) per piece for a link that never
        idles (concurrent / serving mode). Chained
        ``time_to_deliver`` queries, so milestones are exact."""
        if self._feed_plan_cache is None:
            tt = 0.0
            plan = []
            for a, b in self._pieces():
                tt = self.trace.time_to_deliver(b - a, start_s=tt)
                plan.append((a, b, self.latency_s + tt))
            self._feed_plan_cache = plan
        return self._feed_plan_cache

    def stage_arrival_times(self) -> list[float]:
        """Wall time each stage's last byte lands (link never idling) —
        the same floats the serving run uses for its upgrades."""
        ends = set(self._stage_ends)
        return [w for _, b, w in self._feed_plan() if b in ends]

    # -- mode 1: the Fig.-4 schedules, executed ----------------------------
    def run_timeline(self, stage_costs: Sequence[StageCost], *,
                     concurrent: bool = True) -> SessionResult:
        """Execute a progressive transfer end to end: real bytes through
        the real client, processing charged on a single simulated
        compute queue (the paper's JS main thread + WebGL).

        w/ concurrency: the link never idles. w/o: the link idles while
        the compute queue drains, so the next stage's bytes are queried
        against the trace from the moment processing finished.
        """
        if len(stage_costs) != self.n_stages:
            raise ValueError(
                f"{len(stage_costs)} costs for {self.n_stages} stages")
        client = ProgressiveClient()
        events: list[SessionEvent] = []
        download_done: list[float] = []
        result_ready: list[float] = []
        tt = 0.0          # trace-clock time of last delivered byte
        proc_free = 0.0   # wall time the compute queue frees up
        for a, b in self._pieces():
            if not concurrent and result_ready:
                # link idles until the previous stage's result is shown
                tt = max(tt, result_ready[-1] - self.latency_s)
            tt = self.trace.time_to_deliver(b - a, start_s=tt)
            wall = self.latency_s + tt
            before = client.stages_complete
            had_header = client.header_ready
            client.feed(self.blob[a:b])
            events.append(SessionEvent(wall, "chunk",
                                       {"bytes": b - a, "through": b}))
            if not had_header and client.header_ready:
                events.append(SessionEvent(wall, "header",
                                           {"bytes": self._header_end}))
            for s in range(before + 1, client.stages_complete + 1):
                # the co-simulation audit: the real decoder must complete
                # stage s exactly at the byte the header algebra predicts
                if b != self._stage_ends[s - 1]:
                    raise AssertionError(
                        f"client completed stage {s} at byte {b}, header "
                        f"layout says {self._stage_ends[s - 1]}")
                download_done.append(wall)
                events.append(SessionEvent(
                    wall, "stage_complete",
                    {"stage": s, "through": b}))
                start = max(wall, proc_free)
                proc_free = start + stage_costs[s - 1].total
                result_ready.append(proc_free)
                events.append(SessionEvent(
                    proc_free, "result_ready",
                    {"stage": s, "process_start_s": start}))
        if client.stages_complete != self.n_stages:
            raise AssertionError(
                f"stream exhausted at stage {client.stages_complete} "
                f"of {self.n_stages}")
        events.sort(key=lambda e: e.t_s)
        return SessionResult(
            events=events, client=client,
            timeline=Timeline(download_done=download_done,
                              result_ready=result_ready))

    def _make_feeder(self, client, events: list) -> "Callable[[float], None]":
        """Closure feeding wire bytes to ``client`` up to a wall time,
        appending chunk/header/stage_complete events as they land."""
        plan = self._feed_plan()
        state = {"idx": 0}

        def feed_until(t_wall: float) -> None:
            while state["idx"] < len(plan) and plan[state["idx"]][2] <= t_wall:
                a, b, w = plan[state["idx"]]
                before = client.stages_complete
                had_header = client.header_ready
                client.feed(self.blob[a:b])
                events.append(SessionEvent(w, "chunk",
                                           {"bytes": b - a, "through": b}))
                if not had_header and client.header_ready:
                    events.append(SessionEvent(
                        w, "header", {"bytes": self._header_end}))
                for s in range(before + 1, client.stages_complete + 1):
                    events.append(SessionEvent(
                        w, "stage_complete", {"stage": s, "through": b}))
                state["idx"] += 1

        return feed_until

    # -- mode 2: the operational serve path --------------------------------
    def run_serving(self, model, prog, *, decode_steps: int, batch: dict,
                    step_time_s: float | None = None,
                    max_len: int | None = None,
                    resident: str | None = None,
                    speculative=None, mesh=None) -> SessionResult:
        """Drive a real ProgressiveServer from the byte stream: the
        server sits on the client's PlaneStore (one ingest per stage,
        one batched Pallas launch per container dtype) and decodes real
        tokens; the simulated decode clock ticks ``step_time_s`` per
        step, and upgrades happen between steps exactly when the trace
        delivered each stage. Tokens, upgrade steps and the event log
        are bit-deterministic for a fixed (blob, trace, seed).

        ``resident`` selects the server's weight residency (default
        ``"fp"``): ``"fp"`` re-materializes float weights per upgrade
        (the paper's client); ``"quantized"`` decodes straight from the
        client's uint accumulators (no fp weight copy, upgrades are
        metadata-only — see
        :class:`~repro.serving.engine.ProgressiveServer`).

        ``speculative`` (a :class:`~repro.serving.speculative.SpecConfig`
        or truthy for defaults) swaps the server for the
        self-speculative engine: a truncated-bits view of the same
        store drafts, the full view verifies, and per-round accept-rate
        events join the audit log on the byte clock. Speculation
        implies quantized residency (the draft IS a second metadata
        view over the resident accumulators), so passing ``resident``
        together with ``speculative`` is a contradiction and raises
        ``ValueError`` instead of being silently ignored.
        """
        from repro.serving.engine import ProgressiveServer, WireStoreReceiver
        from repro.serving.speculative import SpecConfig, SpeculativeEngine

        # mesh=None: single device. With a serving mesh the client's
        # store shards across its model axis (shard-local ingest) and
        # the engine decodes through sharded dispatch — token-identical
        # to the single-device session at every precision stage.
        client = ProgressiveClient(mesh=mesh)
        receiver = WireStoreReceiver(client, prog)
        if speculative:
            if resident is not None:
                raise ValueError(
                    f"resident={resident!r} conflicts with speculative "
                    f"serving: the draft is a metadata view over the "
                    f"quantized-resident accumulators, so residency is "
                    f"fixed at 'quantized' — drop the resident argument")
            spec = (speculative if isinstance(speculative, SpecConfig)
                    else SpecConfig())
            if max_len is None:
                # headroom so end-of-generation verify blocks keep full
                # k (the engine validates it and would raise otherwise)
                max_len = (batch["tokens"].shape[1] + decode_steps
                           + spec.k_max + 1)
            server = SpeculativeEngine(model, prog, max_len=max_len,
                                       receiver=receiver, spec=spec,
                                       mesh=mesh)
        else:
            if max_len is None:
                max_len = batch["tokens"].shape[1] + decode_steps
            server = ProgressiveServer(model, prog, max_len=max_len,
                                       receiver=receiver,
                                       resident=resident or "fp",
                                       mesh=mesh)
        events: list[SessionEvent] = []
        arrivals = self.stage_arrival_times()
        feed_until = self._make_feeder(client, events)

        # cold start: serve as soon as stage 1 is in
        t_cold = arrivals[0]
        feed_until(t_cold)
        if client.stages_complete < 1:
            raise AssertionError("stage 1 not complete at its arrival time")
        server.receive_stage()
        server.start(batch)
        events.append(SessionEvent(
            t_cold, "cold_start",
            {"stage": server.stage, "prompt_len": int(batch["tokens"].shape[1])}))

        if step_time_s is None:
            # fixed decode cadence spanning the rest of the download
            step_time_s = max((arrivals[-1] - t_cold) / max(decode_steps, 1),
                              1e-6)

        def step_wall(i: int) -> float:
            return t_cold + (i + 1) * step_time_s

        def stage_arrival(i: int) -> bool:
            feed_until(step_wall(i))
            return receiver.stages_complete > server.stage

        if speculative:
            def on_round(rec: dict) -> None:
                # stamp the round where its last emitted token lands on
                # the byte clock; min() because slots emit raggedly
                t = step_wall(max(min(rec["emitted"]) - 1, 0))
                events.append(SessionEvent(t, "accept_round", {
                    "round": rec["round"], "k": rec["k"],
                    "accepted": rec["accepted"], "rate": rec["rate"],
                    "stage": rec["stage"],
                    "effective_bits": {
                        "draft": min(server.current_draft_bits(),
                                     server.received_bits_now()),
                        "target": server.received_bits_now()}}))

            res = server.decode(decode_steps, stage_arrival=stage_arrival,
                                on_round=on_round)
        else:
            res = server.decode(decode_steps, stage_arrival=stage_arrival)
        for i, stage in enumerate(res.stage_at_step):
            events.append(SessionEvent(
                step_wall(i), "decode_step", {"step": i, "stage": stage}))
        for step, stage in res.upgrades:
            events.append(SessionEvent(
                step_wall(step), "upgrade", {"step": step, "stage": stage}))
        events.sort(key=lambda e: e.t_s)
        return SessionResult(
            events=events, client=client, server=server,
            tokens=res.tokens, upgrades=res.upgrades,
            stage_at_step=res.stage_at_step)

    # -- mode 3: continuous batching under a flash crowd -------------------
    def run_serving_pool(self, model, prog, *, prompts: Sequence,
                         arrival_offsets_s: Sequence[float] | None = None,
                         max_new_tokens: int = 8,
                         n_slots: int = 4,
                         max_len: int | None = None,
                         resident: str | None = None,
                         step_time_s: float | None = None,
                         dispatch_window: int = 4,
                         chunked_prefill: bool | None = None,
                         speculative=None, mesh=None) -> SessionResult:
        """Flash-crowd serving: N requests join mid-download over ONE
        shared byte stream, and a :class:`~repro.serving.engine.
        SlotPoolEngine` serves them all from the client's PlaneStore —
        staggered admissions into free slots, evictions on completion,
        precision upgrades between batched windows, one decode
        executable throughout.

        ``prompts[i]`` becomes admissible ``arrival_offsets_s[i]``
        seconds after the cold start (default: all at cold start). The
        simulated decode clock ticks ``step_time_s`` per batched step;
        idle rounds (pool empty, crowd not yet arrived) advance the
        clock without dispatching. Deterministic for a fixed
        (blob, trace, prompts, offsets).

        ``chunked_prefill`` is forwarded to the engine (None = auto:
        on for every arch without cross-attention): admissions stream
        prompt KV into pooled cache rows in ``prefill_chunk``-token
        blocks interleaved with decode steps, instead of a batch-1
        prefill + cache copy per admit.

        ``speculative`` (a SpecConfig or truthy) swaps the engine for
        :class:`~repro.serving.speculative.SpeculativeSlotPool`: every
        pool 'step' becomes a draft+verify round, acceptance records
        join the audit log at flush boundaries, and passing
        ``resident`` alongside raises ``ValueError`` (speculation
        implies quantized residency).

        Note: this drives the engine step/flush primitives directly
        rather than ``SlotPoolEngine.run`` because admissions and byte
        feeding are gated on the *simulated wall clock*, which only
        this session knows — keep the two loops' flush/evict
        bookkeeping in sync when changing either."""
        from repro.serving.engine import (PoolRequest, SlotPoolEngine,
                                          WireStoreReceiver)

        n_req = len(prompts)
        if arrival_offsets_s is None:
            arrival_offsets_s = [0.0] * n_req
        if len(arrival_offsets_s) != n_req:
            raise ValueError("one arrival offset per prompt")

        client = ProgressiveClient(mesh=mesh)
        receiver = WireStoreReceiver(client, prog)
        if speculative:
            from repro.serving.speculative import (SpecConfig,
                                                   SpeculativeSlotPool)

            if resident is not None:
                raise ValueError(
                    f"resident={resident!r} conflicts with speculative "
                    f"serving: the draft is a metadata view over the "
                    f"quantized-resident accumulators, so residency is "
                    f"fixed at 'quantized' — drop the resident argument")
            spec = (speculative if isinstance(speculative, SpecConfig)
                    else SpecConfig())
            if max_len is None:
                # headroom so end-of-budget verify blocks keep full k
                # (submit validates it per request and raises otherwise)
                max_len = (max(len(p) for p in prompts) + max_new_tokens
                           + spec.k_max + 1)
            engine = SpeculativeSlotPool(model, prog, n_slots=n_slots,
                                         max_len=max_len, receiver=receiver,
                                         spec=spec,
                                         dispatch_window=dispatch_window,
                                         chunked_prefill=chunked_prefill,
                                         mesh=mesh)
        else:
            if max_len is None:
                max_len = max(len(p) for p in prompts) + max_new_tokens
            engine = SlotPoolEngine(model, prog, n_slots=n_slots,
                                    max_len=max_len, receiver=receiver,
                                    resident=resident or "fp",
                                    dispatch_window=dispatch_window,
                                    chunked_prefill=chunked_prefill,
                                    mesh=mesh)
        events: list[SessionEvent] = []
        arrivals = self.stage_arrival_times()
        feed_until = self._make_feeder(client, events)

        t_cold = arrivals[0]
        feed_until(t_cold)
        if client.stages_complete < 1:
            raise AssertionError("stage 1 not complete at its arrival time")
        engine.receive_stage()
        events.append(SessionEvent(
            t_cold, "cold_start",
            {"stage": engine.stage, "n_slots": n_slots, "clients": n_req}))

        total_budget = n_req * max_new_tokens
        if step_time_s is None:
            step_time_s = max(
                (arrivals[-1] - t_cold) / max(total_budget, 1), 1e-6)

        order = sorted(range(n_req), key=lambda i: (arrival_offsets_s[i], i))
        next_req = 0
        admissions: list[tuple[float, int]] = []  # actual slot admissions
        seen_admits = 0
        rounds = 0
        # every request decodes max_new_tokens steps; idle rounds are
        # bounded by the crowd span, so this cap is never the exit path
        max_rounds = total_budget + n_req + int(
            max(arrival_offsets_s) / step_time_s) + 8
        if engine.chunked_prefill:
            # chunked admission consumes prompts one block per round;
            # worst case (no decode overlap) that adds a round per chunk
            c = engine.prefill_chunk
            max_rounds += sum((len(p) + c - 1) // c for p in prompts)

        def wall() -> float:
            return t_cold + (rounds + 1) * step_time_s

        def admit_due(t: float) -> None:
            nonlocal next_req
            while next_req < n_req and \
                    t_cold + arrival_offsets_s[order[next_req]] <= t:
                rid = order[next_req]
                engine.submit(PoolRequest(
                    rid=rid, prompt=prompts[rid],
                    max_new_tokens=max_new_tokens))
                events.append(SessionEvent(t, "submit", {"rid": rid}))
                next_req += 1

        def log_admissions(t: float) -> None:
            # the 'admit' event stamps when a request actually took a
            # slot (engine._admit), not when it was submitted — a full
            # pool queues submissions until an eviction frees a slot
            nonlocal seen_admits
            for rid in engine.admitted_order[seen_admits:]:
                admissions.append((t, rid))
                events.append(SessionEvent(t, "admit", {"rid": rid}))
            seen_admits = len(engine.admitted_order)

        admit_due(t_cold)
        log_admissions(t_cold)
        evicted_logged: set[int] = set()
        accepts_logged = 0

        def log_evictions(t: float) -> None:
            for rid in sorted(engine.completed - evicted_logged):
                events.append(SessionEvent(t, "evict", {"rid": rid}))
                evicted_logged.add(rid)

        def log_accepts(t: float) -> None:
            # speculative pool: per-round acceptance records become
            # host-visible at flush; stamp them on the byte clock
            nonlocal accepts_logged
            if not speculative:
                return
            for rec in engine.accept_log[accepts_logged:]:
                events.append(SessionEvent(t, "accept_round", dict(rec)))
            accepts_logged = len(engine.accept_log)

        while (next_req < n_req or engine.queue or
               any(not s.free for s in engine.slots)):
            if rounds >= max_rounds:
                raise AssertionError("slot-pool run did not converge")
            t = wall()
            feed_until(t)
            if engine.upgrade_if_available():
                events.append(SessionEvent(
                    t, "upgrade",
                    {"step": engine._step_count, "stage": engine.stage}))
            admit_due(t)
            log_admissions(t)
            if any(not s.free for s in engine.slots):
                snapshot = engine.step()
                if len(engine._pending) >= dispatch_window:
                    stats = engine.flush()
                    events.append(SessionEvent(
                        t, "pool_window",
                        {"steps": stats.steps,
                         "tokens": stats.tokens_emitted,
                         "active": len(snapshot),
                         "stage": engine.stage}))
                    log_accepts(t)
                    engine._admit_from_queue()
                    log_admissions(t)
                    log_evictions(t)
                rounds += 1
            elif engine.queue:
                # every active slot budget-evicted mid-window: flush the
                # in-flight tail so the queue can take the freed slots
                stats = engine.flush()
                if stats is not None:
                    events.append(SessionEvent(
                        t, "pool_window",
                        {"steps": stats.steps,
                         "tokens": stats.tokens_emitted,
                         "active": 0, "stage": engine.stage}))
                log_accepts(t)
                engine._admit_from_queue()
                log_admissions(t)
                log_evictions(t)
                rounds += 1
            else:
                # idle pool, crowd still to come (queue empty + no active
                # slot implies next_req < n_req by the loop condition):
                # fast-forward the clock to the next arrival instead of
                # spinning one round per step_time_s tick (a fast link
                # makes that microscopic)
                nxt = t_cold + arrival_offsets_s[order[next_req]]
                skip = int((nxt - t_cold) / step_time_s) - 1
                rounds = max(rounds + 1, min(skip, max_rounds - 1))
        stats = engine.flush()
        t_end = wall()
        if stats is not None:
            events.append(SessionEvent(
                t_end, "pool_window",
                {"steps": stats.steps, "tokens": stats.tokens_emitted,
                 "active": 0, "stage": engine.stage}))
        log_accepts(t_end)
        log_evictions(t_end)
        events.sort(key=lambda e: e.t_s)
        return SessionResult(
            events=events, client=client, server=engine,
            tokens={rid: list(v) for rid, v in engine.outputs.items()},
            upgrades=list(engine.upgrades),
            admissions=admissions)
