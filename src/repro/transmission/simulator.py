"""Discrete-event bandwidth simulator.

Models the byte stream of a progressive model crossing a link of given
bandwidth (the paper uses 0.1–2.5 MB/s browser links; a TPU-pod
cold-start sees checkpoint-store->pod links). Deterministic: time is
derived, never measured, so tests are exact and the Table-I benchmark is
reproducible on any machine.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence


@dataclasses.dataclass(frozen=True)
class Link:
    """A constant-rate link with optional per-request latency."""

    bandwidth_bytes_per_s: float
    latency_s: float = 0.0

    def transfer_time(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s


@dataclasses.dataclass(frozen=True)
class TransferEvent:
    """One contiguous payload fully received."""

    label: str
    nbytes: int
    start_s: float
    end_s: float


def simulate_transfer(
    payloads: Sequence[tuple[str, int]], link: Link, start_s: float = 0.0
) -> list[TransferEvent]:
    """Stream payloads back-to-back over one connection (a progressive
    model is a single HTTP stream in the paper; latency paid once)."""
    events: list[TransferEvent] = []
    t = start_s + link.latency_s
    for label, nbytes in payloads:
        end = t + nbytes / link.bandwidth_bytes_per_s
        events.append(TransferEvent(label=label, nbytes=nbytes, start_s=t, end_s=end))
        t = end
    return events


def bytes_available(events: Sequence[TransferEvent], at_s: float) -> int:
    """Total bytes delivered by time ``at_s`` (mid-payload counts
    proportionally — links deliver bytes, not whole files)."""
    total = 0
    for e in events:
        if at_s >= e.end_s:
            total += e.nbytes
        elif at_s > e.start_s:
            total += int(e.nbytes * (at_s - e.start_s) / (e.end_s - e.start_s))
    return total
