"""Discrete-event bandwidth simulation: constant links and trace-driven
bandwidth profiles.

Models the byte stream of a progressive model crossing a link (the paper
uses 0.1–2.5 MB/s browser links; a TPU-pod cold-start sees
checkpoint-store->pod links; a phone on a drive test sees LTE handoffs
and tunnel outages). Deterministic: time is *derived*, never measured —
:class:`BandwidthTrace` exposes the exact inverse pair

    ``bytes_available(at_s)``   cumulative bytes delivered by time t
    ``time_to_deliver(nbytes)`` earliest t at which nbytes have landed

so every milestone in the scheduler algebra and the co-simulation
:mod:`~repro.transmission.session` harness is a closed-form query, and
tests can assert equality to 1e-9 s on any machine.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Sequence, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Link:
    """A constant-rate link with optional per-request latency."""

    bandwidth_bytes_per_s: float
    latency_s: float = 0.0

    def transfer_time(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s

    def trace(self) -> "BandwidthTrace":
        return BandwidthTrace.constant(self.bandwidth_bytes_per_s)


class BandwidthTrace:
    """A piecewise-constant bandwidth profile over absolute time.

    ``segments`` is ``[(duration_s, bytes_per_s), ...]``; the last
    segment's rate is held forever past the end of the trace, so a
    finite trace always defines delivery for an arbitrarily large
    payload (unless it ends in a zero-rate tail, in which case
    ``time_to_deliver`` raises once the deliverable bytes run out).
    Rates may be zero (stalls/outages); durations must be positive and
    finite.
    """

    def __init__(self, segments: Sequence[tuple[float, float]], *, name: str = ""):
        segs = [(float(d), float(r)) for d, r in segments]
        for d, r in segs:
            if not (d > 0.0) or not np.isfinite(d):
                raise ValueError(f"segment duration must be positive/finite, got {d}")
            if r < 0.0 or not np.isfinite(r):
                raise ValueError(f"segment rate must be >= 0 and finite, got {r}")
        if not segs:
            raise ValueError("trace needs at least one segment")
        self.name = name
        self._durations = tuple(d for d, _ in segs)
        self._rates = tuple(r for _, r in segs)
        # segment start times / cumulative bytes at segment starts
        starts, cum = [0.0], [0.0]
        for d, r in segs:
            starts.append(starts[-1] + d)
            cum.append(cum[-1] + d * r)
        self._starts = tuple(starts)   # len n+1; [-1] == trace end
        self._cum = tuple(cum)         # len n+1; [-1] == bytes at trace end

    # -- introspection -----------------------------------------------------
    @property
    def segments(self) -> tuple[tuple[float, float], ...]:
        return tuple(zip(self._durations, self._rates))

    @property
    def duration_s(self) -> float:
        """End of the explicit trace (the final rate is held after it)."""
        return self._starts[-1]

    def rate_at(self, at_s: float) -> float:
        if at_s < 0:
            return 0.0
        for i, start in enumerate(self._starts[:-1]):
            if at_s < self._starts[i + 1]:
                return self._rates[i]
        return self._rates[-1]

    def __repr__(self) -> str:
        label = f" {self.name!r}" if self.name else ""
        return (f"BandwidthTrace({len(self._rates)} segments,"
                f" {self.duration_s:.3g}s{label})")

    # -- the exact query pair ----------------------------------------------
    def bytes_available(self, at_s: float) -> float:
        """Cumulative bytes delivered on [0, at_s] (float — the byte
        clock is continuous; callers quantize where they must)."""
        if at_s <= 0.0:
            return 0.0
        for i in range(len(self._rates)):
            if at_s < self._starts[i + 1]:
                return self._cum[i] + self._rates[i] * (at_s - self._starts[i])
        return self._cum[-1] + self._rates[-1] * (at_s - self._starts[-1])

    def time_to_deliver(self, nbytes: float, start_s: float = 0.0) -> float:
        """Earliest t >= start_s such that ``nbytes`` have been delivered
        on (start_s, t]. Exact inverse of :meth:`bytes_available`:
        ``time_to_deliver(bytes_available(t))`` lands on t's byte
        position, not one event later. A zero-byte payload takes zero
        time; delivery that must cross a stall jumps to the stall's end;
        if the trace ends in a zero-rate tail with bytes still owed,
        raises ``ValueError``.
        """
        if nbytes <= 0.0:
            return max(start_s, 0.0)
        target = self.bytes_available(start_s) + float(nbytes)
        for i in range(len(self._rates)):
            if self._cum[i + 1] >= target and self._rates[i] > 0.0:
                t = self._starts[i] + (target - self._cum[i]) / self._rates[i]
                return max(t, start_s)
        if self._rates[-1] > 0.0:
            t = (self._starts[-1]
                 + (target - self._cum[-1]) / self._rates[-1])
            return max(t, start_s)
        raise ValueError(
            f"trace {self.name or '<anon>'} ends in a zero-rate tail after "
            f"{self._cum[-1]:.0f} bytes; cannot deliver {nbytes:.0f} more")

    # -- constructors ------------------------------------------------------
    @classmethod
    def constant(cls, bytes_per_s: float, *, name: str = "") -> "BandwidthTrace":
        return cls([(1.0, bytes_per_s)], name=name or f"const-{bytes_per_s:g}")

    @classmethod
    def steps(cls, segments: Sequence[tuple[float, float]], *,
              name: str = "") -> "BandwidthTrace":
        return cls(segments, name=name)

    @classmethod
    def ramp(cls, from_bps: float, to_bps: float, duration_s: float, *,
             steps: int = 8, name: str = "") -> "BandwidthTrace":
        """Linear ramp approximated by ``steps`` piecewise-constant
        segments (rate sampled at each sub-interval's midpoint)."""
        if steps < 1:
            raise ValueError("ramp needs >= 1 step")
        d = duration_s / steps
        mids = (np.arange(steps) + 0.5) / steps
        rates = from_bps + (to_bps - from_bps) * mids
        return cls([(d, float(r)) for r in rates], name=name)

    @classmethod
    def jittered(cls, mean_bytes_per_s: float, jitter_frac: float, *,
                 seed: int, interval_s: float = 0.5, n_intervals: int = 128,
                 name: str = "") -> "BandwidthTrace":
        """Seeded multiplicative jitter around a mean rate: each interval
        draws rate = mean * (1 + U(-jitter, +jitter)). Deterministic in
        ``seed`` — the same seed yields the same trace on any machine."""
        if not (0.0 <= jitter_frac < 1.0):
            raise ValueError("jitter_frac must be in [0, 1)")
        rng = np.random.default_rng(seed)
        rates = mean_bytes_per_s * (
            1.0 + jitter_frac * (2.0 * rng.random(n_intervals) - 1.0))
        return cls([(interval_s, float(r)) for r in rates],
                   name=name or f"jitter-{mean_bytes_per_s:g}@{seed}")

    @classmethod
    def from_csv(cls, path: Union[str, Path], *, name: str = "") -> "BandwidthTrace":
        """Load a mobile-style trace CSV: rows ``time_s,bytes_per_s``
        (``#`` comments and a header row are skipped). Each row's rate
        applies from its timestamp until the next row; the last rate is
        held. Timestamps must start at 0 and strictly increase."""
        path = Path(path)
        rows: list[tuple[float, float]] = []
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split(",")]
            if len(parts) != 2:
                raise ValueError(f"{path}:{lineno}: expected 2 columns, got {len(parts)}")
            try:
                rows.append((float(parts[0]), float(parts[1])))
            except ValueError:
                if rows:
                    raise ValueError(f"{path}:{lineno}: non-numeric row {line!r}")
                continue  # header row
        if len(rows) < 2:
            raise ValueError(f"{path}: need >= 2 data rows")
        if rows[0][0] != 0.0:
            raise ValueError(f"{path}: trace must start at time 0, got {rows[0][0]}")
        segs = []
        for (t0, r), (t1, _) in zip(rows, rows[1:]):
            if t1 <= t0:
                raise ValueError(f"{path}: timestamps must strictly increase at t={t1}")
            segs.append((t1 - t0, r))
        # last row's rate held forever: represent as a 1s segment
        segs.append((1.0, rows[-1][1]))
        return cls(segs, name=name or path.stem)

    # -- transforms --------------------------------------------------------
    def with_outage(self, start_s: float, duration_s: float) -> "BandwidthTrace":
        """Overlay a zero-rate window on [start_s, start_s+duration_s):
        the channel is dead during the window; the original profile
        resumes (in absolute time) after it.

        Edge cases are pinned (tests/test_simulator.py): a window
        boundary landing exactly on a segment (or delivery-chunk)
        boundary produces no zero-length segments and delivery that
        *ends* exactly at ``start_s`` is unaffected; overlapping
        windows compose to their union (re-zeroing a dead region is a
        no-op); ``duration_s <= 0`` returns self; a negative
        ``start_s`` clamps to 0 (the window's tail still applies)."""
        if duration_s <= 0:
            return self
        end_s = start_s + duration_s
        start_s = max(start_s, 0.0)
        if end_s <= start_s:
            return self
        # ensure explicit coverage past the window (tail rate is held)
        segs = list(zip(self._durations, self._rates))
        if self.duration_s < end_s + 1.0:
            segs.append((end_s + 1.0 - self.duration_s, self._rates[-1]))
        out: list[tuple[float, float]] = []
        t = 0.0
        for d, r in segs:
            a, b = t, t + d
            for lo, hi, rate in ((a, min(b, start_s), r),
                                 (max(a, start_s), min(b, end_s), 0.0),
                                 (max(a, end_s), b, r)):
                if hi > lo:
                    out.append((hi - lo, rate))
            t = b
        return BandwidthTrace(
            out, name=f"{self.name}+outage[{start_s:g},{end_s:g})"
            if self.name else "")

    def scaled(self, factor: float) -> "BandwidthTrace":
        return BandwidthTrace(
            [(d, r * factor) for d, r in zip(self._durations, self._rates)],
            name=self.name)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

FAULT_KINDS = ("corrupt", "truncate", "duplicate", "reorder", "disconnect")


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """Seeded channel-fault profile, composable with a
    :class:`BandwidthTrace`: the bandwidth trace says *when* bytes
    land, the fault trace says *what happens to them* on the way. The
    session transport applies it per delivered chunk.

    At most one fault fires per delivery, drawn from one uniform
    against the cumulative probabilities (so the kinds must sum to
    <= 1):

    * ``corrupt``     — ``flips_per_corruption`` seeded bit flips
    * ``truncate``    — the chunk's tail is silently dropped
    * ``duplicate``   — the chunk lands twice
    * ``reorder``     — the chunk swaps places with its successor
    * ``disconnect``  — the connection dies mid-chunk (a seeded prefix
      lands, the rest is lost; the transport must reconnect and resume
      from the client's cursor)

    Deterministic: an injector (:meth:`start`) consumes one RNG stream
    in delivery order, so a fixed (seed, probabilities, delivery
    sequence) reproduces the same faults on any machine. Retransmitted
    bytes pass through the injector again — repairs can themselves be
    faulted.
    """

    seed: int = 0
    p_corrupt: float = 0.0
    p_truncate: float = 0.0
    p_duplicate: float = 0.0
    p_reorder: float = 0.0
    p_disconnect: float = 0.0
    flips_per_corruption: int = 1

    def __post_init__(self):
        ps = (self.p_corrupt, self.p_truncate, self.p_duplicate,
              self.p_reorder, self.p_disconnect)
        if any(p < 0 for p in ps) or sum(ps) > 1.0 + 1e-12:
            raise ValueError(
                f"fault probabilities must be >= 0 and sum to <= 1, "
                f"got {ps}")
        if self.flips_per_corruption < 1:
            raise ValueError("flips_per_corruption must be >= 1")

    @property
    def total_p(self) -> float:
        return (self.p_corrupt + self.p_truncate + self.p_duplicate
                + self.p_reorder + self.p_disconnect)

    def start(self) -> "FaultInjector":
        """Fresh stateful injector (one per transport run)."""
        return FaultInjector(self)

    def __repr__(self) -> str:
        on = {k: getattr(self, f"p_{k}") for k in FAULT_KINDS
              if getattr(self, f"p_{k}") > 0}
        return f"FaultTrace(seed={self.seed}, {on or 'clean'})"


@dataclasses.dataclass
class ChunkDelivery:
    """What one chunk delivery looks like after the channel is done
    with it."""

    data: bytes                 # bytes that actually land
    kind: str | None = None     # fault kind, None for a clean delivery
    detail: dict | None = None  # audit payload (positions, kept bytes)
    duplicate: bool = False     # deliver `data` a second time
    reorder: bool = False       # hold this chunk; successor goes first
    disconnect: bool = False    # connection died after `data` landed


class FaultInjector:
    """Stateful per-run consumer of a :class:`FaultTrace`'s RNG stream."""

    def __init__(self, trace: FaultTrace):
        self.trace = trace
        self._rng = np.random.default_rng(trace.seed)
        self.counts: dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.deliveries = 0

    @staticmethod
    def flip_bits(data: bytes, bit_positions) -> bytes:
        out = bytearray(data)
        for b in bit_positions:
            out[b // 8] ^= 1 << (b % 8)
        return bytes(out)

    def deliver(self, chunk: bytes) -> ChunkDelivery:
        """Pass one chunk through the channel. Consumes exactly one
        uniform draw per delivery plus parameter draws when a fault
        fires."""
        self.deliveries += 1
        ft = self.trace
        u = float(self._rng.random())
        edges = [("corrupt", ft.p_corrupt), ("truncate", ft.p_truncate),
                 ("duplicate", ft.p_duplicate), ("reorder", ft.p_reorder),
                 ("disconnect", ft.p_disconnect)]
        kind, acc = None, 0.0
        for k, p in edges:
            acc += p
            if u < acc:
                kind = k
                break
        if kind is None or len(chunk) == 0:
            return ChunkDelivery(data=bytes(chunk))
        self.counts[kind] += 1
        if kind == "corrupt":
            nbits = len(chunk) * 8
            flips = sorted(int(b) for b in self._rng.integers(
                0, nbits, size=min(ft.flips_per_corruption, nbits)))
            return ChunkDelivery(data=self.flip_bits(chunk, flips),
                                 kind=kind, detail={"bit_positions": flips})
        if kind == "truncate":
            keep = int(self._rng.integers(0, len(chunk)))
            return ChunkDelivery(data=bytes(chunk[:keep]), kind=kind,
                                 detail={"kept": keep, "lost": len(chunk) - keep})
        if kind == "duplicate":
            return ChunkDelivery(data=bytes(chunk), kind=kind,
                                 detail={}, duplicate=True)
        if kind == "reorder":
            return ChunkDelivery(data=bytes(chunk), kind=kind,
                                 detail={}, reorder=True)
        keep = int(self._rng.integers(0, len(chunk)))
        return ChunkDelivery(data=bytes(chunk[:keep]), kind="disconnect",
                             detail={"kept": keep, "lost": len(chunk) - keep},
                             disconnect=True)


TraceLike = Union[Link, BandwidthTrace]


def as_trace(link: TraceLike) -> tuple[BandwidthTrace, float]:
    """Normalize a Link or BandwidthTrace to ``(trace, latency_s)``.
    The latency is a one-time shift of the byte clock (the stream's
    request/response round trip, paid once per connection)."""
    if isinstance(link, Link):
        return link.trace(), link.latency_s
    if isinstance(link, BandwidthTrace):
        return link, 0.0
    raise TypeError(f"expected Link or BandwidthTrace, got {type(link).__name__}")


@dataclasses.dataclass(frozen=True)
class TransferEvent:
    """One contiguous payload fully received."""

    label: str
    nbytes: int
    start_s: float
    end_s: float


def simulate_transfer(
    payloads: Sequence[tuple[str, int]], link: TraceLike, start_s: float = 0.0
) -> list[TransferEvent]:
    """Stream payloads back-to-back over one connection (a progressive
    model is a single HTTP stream in the paper; latency paid once).
    Zero-length payloads yield zero-duration events at the current
    clock. Accepts a constant :class:`Link` or a :class:`BandwidthTrace`
    (whose clock starts when the stream does)."""
    trace, latency = as_trace(link)
    t0 = start_s + latency
    tt = 0.0  # trace-clock time of the last delivered byte
    events: list[TransferEvent] = []
    for label, nbytes in payloads:
        begin = t0 + tt
        tt = trace.time_to_deliver(nbytes, start_s=tt)
        events.append(TransferEvent(label=label, nbytes=nbytes,
                                    start_s=begin, end_s=t0 + tt))
    return events


def bytes_available(events: Sequence[TransferEvent], at_s: float) -> int:
    """Total bytes delivered by time ``at_s`` (mid-payload counts
    proportionally — links deliver bytes, not whole files). Exact at
    event boundaries: a payload counts fully at its ``end_s`` and the
    proportional share is clamped to ``nbytes`` so float rounding never
    over- or under-counts a finished payload."""
    total = 0
    for e in events:
        if at_s >= e.end_s:
            total += e.nbytes
        elif at_s > e.start_s and e.end_s > e.start_s:
            frac = (at_s - e.start_s) / (e.end_s - e.start_s)
            total += min(e.nbytes, int(e.nbytes * frac))
    return total
