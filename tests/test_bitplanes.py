"""Property tests for eq. (3)/(4): bit division + concatenation, and the
dense wire packing."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitplanes
from repro.core.quantize import quantize, truncate


def widths_strategy(bits):
    """Random partition of `bits` into plane widths."""

    def build(cuts):
        cs = sorted(set(cuts) | {bits})
        prev, out = 0, []
        for c in cs:
            if c > prev:
                out.append(c - prev)
                prev = c
        return tuple(out)

    return st.lists(st.integers(1, bits - 1), min_size=0, max_size=6).map(build)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=48)
    .map(lambda xs: np.asarray(xs, np.float32)),
    st.integers(2, 16),
    st.data(),
)
def test_split_concat_roundtrip(x, bits, data):
    widths = data.draw(widths_strategy(bits))
    qt = quantize(jnp.asarray(x), bits)
    planes = bitplanes.split(qt, widths)
    q2 = bitplanes.concat(planes, bits, widths)
    assert (np.asarray(q2) == np.asarray(qt.q)).all()


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=48)
    .map(lambda xs: np.asarray(xs, np.float32)),
    st.integers(2, 16),
    st.data(),
)
def test_prefix_equals_truncate(x, bits, data):
    """Receiving planes [1..j] == truncating q to the cumulative width —
    the invariant that makes intermediate models well-defined."""
    widths = data.draw(widths_strategy(bits))
    j = data.draw(st.integers(1, len(widths)))
    qt = quantize(jnp.asarray(x), bits)
    planes = bitplanes.split(qt, widths)
    got = bitplanes.concat(planes[:j], bits, widths)
    cum = bitplanes.cumulative(widths)[j - 1]
    want = truncate(qt, cum).q
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=64),
    st.integers(1, 16),
)
def test_pack_unpack_roundtrip(vals, width):
    vals = np.asarray(vals, np.uint32) & ((1 << width) - 1)
    packed = bitplanes.pack_bits(jnp.asarray(vals), width)
    assert packed.dtype == jnp.uint8
    # dense: exactly ceil(n*w/8) bytes — the "no size increase" unit fact
    assert packed.shape[0] == -(-len(vals) * width // 8)
    out = bitplanes.unpack_bits(packed, width, len(vals))
    assert (np.asarray(out) == vals).all()


def test_width_validation():
    with pytest.raises(ValueError):
        bitplanes.validate_widths(8, (2, 2))  # sums to 4
    with pytest.raises(ValueError):
        bitplanes.validate_widths(8, (0, 8))
    with pytest.raises(ValueError):
        bitplanes.PlaneSchedule(bits=16, widths=(8, 4))


def test_paper_default_schedule():
    s = bitplanes.PAPER_DEFAULT
    assert s.bits == 16 and s.widths == (2,) * 8
    assert s.cumulative_bits == (2, 4, 6, 8, 10, 12, 14, 16)
