"""TransmissionSchedule construction + constraint tests: whatever the
measured gains are, the emitted schedule must ship every tensor's
planes MSB-first while interleaving freely across tensors, and the
serialized form must round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wire
from repro.core.calibrate import (TransmissionSchedule, _convexify,
                                  build_schedule, calibrate_schedule,
                                  uniform_schedule)
from repro.core.progressive import divide


@pytest.fixture(scope="module")
def model():
    k = jax.random.PRNGKey(7)
    params = {
        "big": jax.random.normal(k, (16, 8)),
        "small": jax.random.normal(jax.random.fold_in(k, 1), (5,)),
        "scalar": jnp.float32(1.25),
    }
    return divide(params)


def _plane_counts(model):
    return [t.plan.schedule.n_planes for t in model.tensors]


def test_uniform_schedule_is_stage_major(model):
    sched = uniform_schedule(model)
    sched.validate(_plane_counts(model))
    assert sched.n_stages == model.n_stages
    # stage s ships plane s-1 of every tensor, in stage order
    k = 0
    for s in range(1, model.n_stages + 1):
        for i, _ in model.stage(s):
            assert sched.units[k] == (i, s - 1)
            k += 1
    assert sched.checkpoints[-1] == len(sched.units)


def test_validate_rejects_out_of_order_planes(model):
    counts = _plane_counts(model)
    base = uniform_schedule(model)
    units = list(base.units)
    # swap two planes of the same tensor -> LSB before MSB
    a = next(k for k, (t, p) in enumerate(units) if t == 0 and p == 0)
    b = next(k for k, (t, p) in enumerate(units) if t == 0 and p == 1)
    units[a], units[b] = units[b], units[a]
    bad = TransmissionSchedule(tuple(units), base.checkpoints)
    with pytest.raises(ValueError, match="MSB-first"):
        bad.validate(counts)


def test_validate_rejects_incomplete_and_bad_checkpoints(model):
    counts = _plane_counts(model)
    base = uniform_schedule(model)
    with pytest.raises(ValueError):
        TransmissionSchedule(base.units[:-1],
                             (len(base.units) - 1,)).validate(counts)
    with pytest.raises(ValueError, match="checkpoints"):
        TransmissionSchedule(base.units, ()).validate(counts)
    with pytest.raises(ValueError, match="checkpoints"):
        TransmissionSchedule(
            base.units, (len(base.units) - 1,)).validate(counts)
    with pytest.raises(ValueError, match="checkpoints"):
        TransmissionSchedule(
            base.units, (3, 3, len(base.units))).validate(counts)


@pytest.mark.parametrize("seed", range(20))
def test_build_schedule_msb_first_under_arbitrary_gains(model, seed):
    """Whatever per-plane gains calibration measures — including
    adversarial ones that reward LSB planes — the built schedule must
    interleave across tensors but stay MSB-first within each tensor."""
    rng = np.random.default_rng(seed)
    counts = _plane_counts(model)
    gains = {i: list(rng.exponential(1.0, n)) for i, n in enumerate(counts)}
    if seed % 3 == 0:  # reward LSBs hard: forces bundle merging
        gains = {i: g[::-1] for i, g in gains.items()}
    sched = build_schedule(model, gains)
    sched.validate(counts)  # raises on any MSB-first violation
    # interleaving is allowed AND units cover every (tensor, plane)
    assert sorted(sched.units) == sorted(
        (i, p) for i, n in enumerate(counts) for p in range(n))


def test_build_schedule_front_loads_high_gain_tensor(model):
    counts = _plane_counts(model)
    gains = {i: [0.0] * n for i, n in enumerate(counts)}
    gains[0] = [100.0] + [50.0] * (counts[0] - 1)  # tensor 0 dominates
    sched = build_schedule(model, gains)
    sched.validate(counts)
    assert [t for t, _ in sched.units[:counts[0]]] == [0] * counts[0]


def test_convexify_rates_non_increasing():
    rng = np.random.default_rng(3)
    for _ in range(50):
        n = int(rng.integers(1, 10))
        gains = rng.exponential(1.0, n)
        costs = rng.integers(1, 100, n)
        bundles = _convexify(list(gains), list(costs))
        # bundles tile [0, n) exactly
        assert bundles[0][0] == 0 and bundles[-1][1] == n
        assert all(b[1] == c[0] for b, c in zip(bundles, bundles[1:]))
        rates = [g / c for (_, _, g, c) in bundles]
        assert all(a >= b - 1e-12 for a, b in zip(rates, rates[1:]))


def test_meta_roundtrip(model):
    sched = uniform_schedule(model)
    again = TransmissionSchedule.from_meta(sched.to_meta())
    assert again == sched
    # and via the v2 wire header
    blob = wire.encode(model, schedule=sched, entropy_coded=True)
    meta, _ = wire.decode_header(blob)
    assert TransmissionSchedule.from_meta(meta) == sched


def test_calibrate_schedule_end_to_end(model):
    """Weighted-MSE calibration loss: the heavily weighted tensor's
    planes must ship before the zero-weight tensors'."""
    from repro.core.plane_store import PlaneStore

    store = PlaneStore.from_model(model)
    for s in range(1, model.n_stages + 1):
        store.ingest(model.stage(s))
    refs = {k: np.asarray(v) for k, v in store.materialize_leaves().items()}

    def eval_loss(leaves):
        loss = 0.0
        for key, v in leaves.items():
            w = 50.0 if "big" in str(key) else 1e-6
            loss += w * float(np.mean((np.asarray(v) - refs[key]) ** 2))
        return loss

    sched = calibrate_schedule(model, eval_loss)
    sched.validate(_plane_counts(model))
    big_idxs = {i for i, t in enumerate(model.tensors)
                if "big" in str(t.path)}
    first_big = min(k for k, (t, _) in enumerate(sched.units)
                    if t in big_idxs)
    first_rest = min(k for k, (t, _) in enumerate(sched.units)
                     if t not in big_idxs)
    assert first_big < first_rest
