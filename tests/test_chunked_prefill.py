"""Chunked ragged prefill: the ISSUE-6 acceptance surface.

1. Token identity: chunked admission (prompt KV streamed into pooled
   cache rows ``prefill_chunk`` tokens at a time, interleaved with
   decode) is token-identical to the legacy batch-1 prefill + grow +
   slot-write path, per slot and per precision stage, for dense, MoE,
   recurrent (xLSTM) and sliding-window (ring cache) archs — with
   exactly ONE decode executable and ONE prefill-chunk executable.
2. Isolation: a chunk tick and the masked decode steps it interleaves
   with never touch another slot's cache rows (byte identity for idle
   slots); ring caches wrap correctly mid- and post-prefill.
3. Zero copies: the admit path performs no ``grow_caches`` and traces
   no cache-sized transpose/copy/concatenate/gather (jaxpr regression
   mirroring the speculative rollback pin).
4. Validation: malformed requests (2-D prompts, bad extras) raise at
   ``submit`` before any device work; batch-1 bucketing compiles
   O(log max_len) prefill variants, not one per distinct length.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.serving.engine import PoolRequest, SlotPoolEngine

CHUNK = 4  # small so 3-9 token prompts still span multiple blocks

ARCH_OVERRIDES = {
    "olmo-1b": {},                                    # dense attention
    "dbrx-132b": {"n_experts": 2, "top_k": 1},        # MoE
    "xlstm-125m": {},                                 # slstm + mlstm
    "mixtral-8x22b": {"n_experts": 2, "top_k": 1,
                      "window": 8},                   # swa_moe ring caches
}


def _build(arch, seed=0, **over):
    base = dict(n_layers=2, d_model=32, d_ff=64, vocab=64,
                n_heads=2, n_kv=2)
    base.update(ARCH_OVERRIDES[arch])
    base.update(over)
    cfg = get_config(arch).reduced(**base)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params, divide(params)


def _prompts(cfg, lengths, seed=1):
    return [jax.random.randint(jax.random.PRNGKey(seed + i), (L,), 0,
                               cfg.vocab).astype(jnp.int32)
            for i, L in enumerate(lengths)]


def _run_pool(model, prog, prompts, *, steps, stage, chunked, max_len,
              n_slots=3, dispatch_window=2):
    pool = SlotPoolEngine(model, prog, n_slots=n_slots, max_len=max_len,
                          dispatch_window=dispatch_window,
                          chunked_prefill=chunked,
                          prefill_chunk=CHUNK,
                          prefill_buckets=False)
    for _ in range(stage):
        pool.receive_stage()
    for i, p in enumerate(prompts):
        pool.submit(PoolRequest(rid=i, prompt=p, max_new_tokens=steps))
    out = pool.run()
    return pool, out


# ---------------------------------------------------------------------------
# acceptance: chunked == batch-1, per slot, per stage, one executable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ARCH_OVERRIDES))
def test_chunked_equals_batch1_per_stage(arch):
    """For each precision stage, a pool admitting via chunked prefill
    must emit EXACTLY the token stream of the legacy batch-1 admission
    pool — ragged lengths spanning multiple chunks, more requests than
    slots (queueing), one decode + one chunk executable."""
    cfg, model, params, prog = _build(arch)
    steps = 4
    prompts = _prompts(cfg, [5, 9, 3, 8])
    max_len = 9 + steps
    for stage in (1, prog.n_stages):
        legacy, out_l = _run_pool(model, prog, prompts, steps=steps,
                                  stage=stage, chunked=False,
                                  max_len=max_len)
        chunked, out_c = _run_pool(model, prog, prompts, steps=steps,
                                   stage=stage, chunked=True,
                                   max_len=max_len)
        assert chunked._tick_count > 0, "chunked pool must consume chunks"
        assert legacy._tick_count == 0
        assert chunked.decode_cache_size() == 1
        assert chunked.prefill_cache_size() == 1, \
            "4 distinct prompt lengths must share one chunk executable"
        for rid in range(len(prompts)):
            assert out_c[rid] == out_l[rid], f"{arch} stage {stage} rid {rid}"
            assert chunked.stage_log[rid] == legacy.stage_log[rid]


def test_ring_wraparound_long_decode():
    """Sliding-window ring caches: chunked prefill writes through the
    over-allocated ring (margin = prefill_chunk) and a long decode
    wraps it repeatedly; stream equality with the batch-1 pool pins
    both the wraparound arithmetic and the prefill ring writes."""
    cfg, model, params, prog = _build("mixtral-8x22b", seed=3)
    steps = 12  # decode positions cross the window-8 ring several times
    prompts = _prompts(cfg, [9, 6], seed=7)
    max_len = 9 + steps
    legacy, out_l = _run_pool(model, prog, prompts, steps=steps,
                              stage=prog.n_stages, chunked=False,
                              max_len=max_len, n_slots=2)
    chunked, out_c = _run_pool(model, prog, prompts, steps=steps,
                               stage=prog.n_stages, chunked=True,
                               max_len=max_len, n_slots=2)
    for rid in range(len(prompts)):
        assert out_c[rid] == out_l[rid], f"rid {rid}"


# ---------------------------------------------------------------------------
# isolation: idle slots are untouched, mid-prefill upgrades are sound
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmo-1b", "xlstm-125m"])
def test_idle_slot_rows_byte_identical(arch):
    """A pool serving ONE request must leave every other slot's cache
    rows byte-identical to their init state: chunk ticks mask idle
    lanes, and decode steps write NOTHING for pos = -1 slots (the
    regression: clamped writes used to scribble on row 0)."""
    cfg, model, params, prog = _build(arch)
    pool = SlotPoolEngine(model, prog, n_slots=3, max_len=16,
                          dispatch_window=2, chunked_prefill=True,
                          prefill_chunk=CHUNK, prefill_buckets=False)
    pool.receive_stage()
    before = [np.array(x) for x in jax.tree.leaves(pool.caches)]
    pool.submit(PoolRequest(rid=0, prompt=_prompts(cfg, [6])[0],
                            max_new_tokens=4))
    pool.run()
    after = jax.tree.leaves(pool.caches)
    for b, a in zip(before, after):
        a = np.array(a)
        for idle in (1, 2):
            # every cache leaf carries the slot axis first (tail) or
            # second (stacked cycles)
            rows = (a[idle], b[idle]) if a.shape[0] == 3 \
                else (a[:, idle], b[:, idle])
            np.testing.assert_array_equal(*rows)


def test_mid_prefill_upgrade_converges():
    """A precision upgrade landing BETWEEN chunk ticks of one prompt:
    the remaining chunks run at the new stage, the run converges, and
    the pool still holds one decode + one chunk executable. (Token
    parity with a fixed-stage replay is undefined here by design — the
    prompt's KV spans two precisions.)"""
    cfg, model, params, prog = _build("olmo-1b")
    steps = 4
    prompt = _prompts(cfg, [20])[0]  # 5 chunks of CHUNK=4
    pool = SlotPoolEngine(model, prog, n_slots=2, max_len=20 + steps,
                          dispatch_window=2, chunked_prefill=True,
                          prefill_chunk=CHUNK, prefill_buckets=False)
    pool.receive_stage()
    pool.submit(PoolRequest(rid=0, prompt=prompt, max_new_tokens=steps))
    pool.step(); pool.step()             # two chunks at stage 1
    assert 0 in pool._prefill_state      # still mid-prefill
    assert pool.upgrade_if_available()   # pull mode: advances one stage
    out = pool.run()
    assert len(out[0]) == steps
    assert pool.admit_stage[0] == 1      # first chunk's stage
    assert set(pool.stage_log[0]) == {2}  # decode ran post-upgrade
    assert pool.decode_cache_size() == 1
    assert pool.prefill_cache_size() == 1
    assert pool.upgrade_log and pool.upgrade_log[-1]["stage"] == 2


def test_speculative_pool_composes_with_chunked_prefill():
    """SpeculativeSlotPool over chunked admission: draft/verify rounds
    start from the chunk-installed first token and the stream equals
    the legacy-admission speculative pool's (which is itself pinned to
    plain greedy elsewhere)."""
    from repro.serving.speculative import SpecConfig, SpeculativeSlotPool

    cfg, model, params, prog = _build("olmo-1b")
    steps, spec = 6, SpecConfig(draft_bits=4, k=2)
    prompts = _prompts(cfg, [5, 9, 3], seed=9)
    max_len = 9 + steps + spec.k_max + 1
    outs = {}
    for chunked in (False, True):
        pool = SpeculativeSlotPool(model, prog, n_slots=2, max_len=max_len,
                                   spec=spec, dispatch_window=2,
                                   chunked_prefill=chunked,
                                   prefill_chunk=CHUNK)
        for _ in range(prog.n_stages):
            pool.receive_stage()
        for i, p in enumerate(prompts):
            pool.submit(PoolRequest(rid=i, prompt=p, max_new_tokens=steps))
        outs[chunked] = pool.run()
    for rid in range(len(prompts)):
        assert outs[True][rid] == outs[False][rid], f"rid {rid}"
        assert len(outs[True][rid]) == steps


# ---------------------------------------------------------------------------
# jaxpr + host regression: the admit path copies nothing cache-sized
# ---------------------------------------------------------------------------

def _collect_eqns(jaxpr):
    out, stack = [], [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            out.append(eqn)
            for v in eqn.params.values():
                vals = v if isinstance(v, (tuple, list)) else (v,)
                for item in vals:
                    if hasattr(item, "jaxpr"):
                        stack.append(item.jaxpr)
                    elif hasattr(item, "eqns"):
                        stack.append(item)
    return out


def test_chunk_step_jaxpr_zero_cache_copies():
    """Tracing the chunk step must show no cache-sized transpose /
    copy / concatenate / gather — prompt KV lands via the same
    functional in-place writes decode uses, and each attention block
    writes its k and v exactly once per chunk."""
    cfg, model, params, prog = _build("olmo-1b")
    B, C = 3, CHUNK
    pool = SlotPoolEngine(model, prog, n_slots=B, max_len=16,
                          dispatch_window=2, chunked_prefill=True,
                          prefill_chunk=C, prefill_buckets=False)
    pool.receive_stage()
    jaxpr = jax.make_jaxpr(pool._chunk_step)(
        pool.params, pool.caches, jnp.zeros((B, C), jnp.int32),
        jnp.full((B, C), -1, jnp.int32), jnp.full((B,), -1, jnp.int32),
        pool.pos, pool.last_logits, pool._last_tok, pool._first_cap)
    cache_sizes = {int(np.prod(leaf.shape[-4:]))
                   for leaf in jax.tree.leaves(pool.caches)
                   if leaf.ndim >= 4}
    assert cache_sizes
    offenders, writes = [], 0
    for eqn in _collect_eqns(jaxpr.jaxpr):
        sized_out = any(v.aval.ndim >= 4
                        and int(np.prod(v.aval.shape)) in cache_sizes
                        for v in eqn.outvars if hasattr(v.aval, "shape"))
        if not sized_out:
            continue
        if eqn.primitive.name in ("transpose", "copy", "concatenate",
                                  "gather"):
            offenders.append((eqn.primitive.name,
                              [v.aval.shape for v in eqn.outvars]))
        if eqn.primitive.name in ("dynamic_update_slice", "scatter"):
            writes += 1
    assert not offenders, f"cache-sized copies in chunk_step: {offenders}"
    # the cycle scan traces one attention body: one masked k write + one
    # v write per chunk row (single-row writes cannot clamp at the
    # cache end the way a C-wide block write would)
    assert writes == 2 * C, writes


def test_chunked_admit_never_grows_caches(monkeypatch):
    """Chunked admission is host bookkeeping: no batch-1 prefill, no
    grow_caches, no per-leaf slot copy — the legacy admit path must be
    UNREACHABLE when chunking is on and the request has no extras."""
    cfg, model, params, prog = _build("olmo-1b")
    pool = SlotPoolEngine(model, prog, n_slots=2, max_len=16,
                          dispatch_window=2, chunked_prefill=True,
                          prefill_chunk=CHUNK)
    pool.receive_stage()

    def boom(*a, **k):
        raise AssertionError("grow_caches on the chunked admit path")

    monkeypatch.setattr(type(model), "grow_caches", boom)
    pool.submit(PoolRequest(rid=0, prompt=_prompts(cfg, [6])[0],
                            max_new_tokens=3))
    out = pool.run()
    assert len(out[0]) == 3


# ---------------------------------------------------------------------------
# validation + bucketing satellites
# ---------------------------------------------------------------------------

def test_submit_rejects_malformed_before_device_work():
    cfg, model, params, prog = _build("olmo-1b")
    pool = SlotPoolEngine(model, prog, n_slots=2, max_len=16,
                          dispatch_window=2)
    pool.receive_stage()
    good = _prompts(cfg, [4])[0]
    with pytest.raises(ValueError, match=r"one-dimensional"):
        pool.submit(PoolRequest(rid=0, prompt=good[None], max_new_tokens=2))
    with pytest.raises(ValueError, match=r"max_new_tokens"):
        pool.submit(PoolRequest(rid=1, prompt=good, max_new_tokens=0))
    with pytest.raises(ValueError, match=r">= 1 token"):
        pool.submit(PoolRequest(rid=2, prompt=good[:0], max_new_tokens=2))
    with pytest.raises(ValueError, match=r"unknown extras key"):
        pool.submit(PoolRequest(rid=3, prompt=good, max_new_tokens=2,
                                extras={"pixels": np.zeros((2, 2))}))
    # nothing was admitted, queued, or launched
    assert not pool.queue and not pool._prefill_state
    assert all(s.free for s in pool.slots)
    assert pool._tick_count == 0


def test_vision_extras_shape_rejected_before_prefill():
    cfg = get_config("llama32-vision-90b").reduced()
    model = build_model(cfg)
    prog = divide(model.init(jax.random.PRNGKey(0)))
    pool = SlotPoolEngine(model, prog, n_slots=2, max_len=16,
                          dispatch_window=2)
    pool.receive_stage()
    prompt = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match=r"per-request shape"):
        # batched (1, T, D) instead of per-request (T, D)
        pool.submit(PoolRequest(
            rid=0, prompt=prompt, max_new_tokens=2,
            extras={"vision_embeds": np.zeros(
                (1, cfg.vision_tokens, cfg.d_vision), np.float32)}))


def test_batch1_buckets_compile_log_many_prefills():
    """The legacy path with prefill_buckets pads prompts to power-of-two
    lengths with masked positions: 4 distinct lengths -> 2 compiled
    prefill shapes (unbucketed: 4), identical tokens."""
    cfg, model, params, prog = _build("olmo-1b")
    steps = 3
    prompts = _prompts(cfg, [3, 5, 6, 7], seed=13)
    max_len = 7 + steps
    outs, sizes = {}, {}
    for buckets in (False, True):
        pool = SlotPoolEngine(model, prog, n_slots=4, max_len=max_len,
                              dispatch_window=2, chunked_prefill=False,
                              prefill_buckets=buckets)
        for _ in range(prog.n_stages):
            pool.receive_stage()
        for i, p in enumerate(prompts):
            pool.submit(PoolRequest(rid=i, prompt=p, max_new_tokens=steps))
        outs[buckets] = pool.run()
        sizes[buckets] = pool.prefill_cache_size()
    assert sizes[False] == 4
    assert sizes[True] == 2, "lengths 3,5,6,7 must share buckets {4, 8}"
    for rid in range(len(prompts)):
        assert outs[True][rid] == outs[False][rid], f"rid {rid}"
