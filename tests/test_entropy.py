"""Entropy codec property tests: decode(encode(x)) must be
byte-identical for ANY input, and the coded body must never exceed the
raw payload (the wire adds only the 2-byte frame on top).

The deterministic seeded sweeps below always run; hypothesis variants
ride along when the package is installed.
"""
import numpy as np
import pytest

from repro.core import entropy

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic sweeps below still run
    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _StrategyStub()

    def settings(**_kw):
        return lambda f: f

    def given(*_a, **_kw):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)


def roundtrip(data: bytes) -> tuple[int, bytes]:
    mode, body = entropy.encode(data)
    assert mode in entropy.MODES
    # never-worse guarantee: coded body <= raw payload, so a framed
    # unit costs at most raw + FRAME_BYTES on the wire
    assert len(body) <= len(data)
    assert entropy.decode(mode, body, len(data)) == data
    return mode, body


SIZES = [1, 2, 3, 7, 8, 9, 63, 64, 255, 256, 1000, 4096]


def test_empty_payload():
    mode, body = entropy.encode(b"")
    assert body == b""
    assert entropy.decode(mode, body, 0) == b""


@pytest.mark.parametrize("n", SIZES)
def test_all_zero_planes(n):
    mode, body = roundtrip(b"\x00" * n)
    if n >= 8:  # constant planes must compress hard
        assert len(body) < n


@pytest.mark.parametrize("n", SIZES)
def test_all_one_planes(n):
    mode, body = roundtrip(b"\xff" * n)
    if n >= 8:
        assert len(body) < n


def test_every_single_byte_payload():
    """1-byte payloads: all 256 values round-trip and never expand."""
    for v in range(256):
        mode, body = roundtrip(bytes([v]))
        assert len(body) <= 1


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("p", [0.005, 0.05, 0.2, 0.5])
def test_random_bit_skew(seed, p):
    """Packed bitplanes with biased bit distributions — the shape real
    low-significance planes take. Byte-identity at every skew."""
    rng = np.random.default_rng(1000 * seed + int(p * 1000))
    for n in (1, 17, 256, 3001):
        bits = rng.random(n * 8) < p
        data = np.packbits(bits).tobytes()[:n]
        roundtrip(data)


@pytest.mark.parametrize("seed", range(4))
def test_incompressible_random_falls_back_raw(seed):
    """Uniform random bytes are incompressible: the codec must fall
    back to MODE_RAW (identity body) rather than expand."""
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    mode, body = roundtrip(data)
    assert mode == entropy.MODE_RAW
    assert body == data


def test_run_structured_payloads():
    """Long runs broken by literals — RLE's best and worst cases,
    including the 1-byte literal tail."""
    cases = [
        b"\x00" * 500 + b"\xab",
        b"\xab" + b"\x00" * 500,
        b"\x01\x02\x03" * 100 + b"\xff" * 300,
        bytes(range(256)) * 3 + b"\x00" * 64,
        b"\x00\x01" * 200,
    ]
    for data in cases:
        roundtrip(data)


def test_megabyte_payload_lane_count_fits_header():
    """Payloads >= 1 MiB used to clip the rANS lane count to 256, which
    overflows the single-byte header field (struct.error at encode time
    on real full-size model planes). Lanes must cap at 255."""
    rng = np.random.default_rng(7)
    n = 4096 * 256 + 13  # past the old 256-lane threshold, ragged tail
    data = np.packbits(rng.random(n * 8) < 0.05).tobytes()[:n]
    mode, body = roundtrip(data)
    assert mode == entropy.MODE_RANS  # skewed MB-scale plane compresses
    assert len(body) < n


def test_decode_raw_is_identity():
    data = bytes(range(256))
    assert entropy.decode(entropy.MODE_RAW, data, len(data)) == data


def test_decode_rejects_bad_mode():
    with pytest.raises(ValueError):
        entropy.decode(99, b"\x00", 1)


@settings(max_examples=60, deadline=None)
@given(st.binary(max_size=2048))
def test_hypothesis_arbitrary_bytes_roundtrip(data):
    roundtrip(data)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.0, 1.0),
       st.integers(1, 4096))
def test_hypothesis_skewed_planes_roundtrip(seed, p, n):
    rng = np.random.default_rng(seed)
    bits = rng.random(n * 8) < p
    roundtrip(np.packbits(bits).tobytes()[:n])
