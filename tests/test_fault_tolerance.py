"""Fault-tolerant transport: verify-before-ingest, resume cursors,
retry/backoff, and end-to-end recovery under injected channel faults.

The load-bearing claims of ISSUE 9, pinned:

* the PlaneStore OR is irreversible, so the quarantine path is what
  keeps the session alive: force-ingesting a corrupted plane diverges
  the store FOREVER, while the quarantined+repaired stream stays
  bit-identical to the clean one at every checkpoint;
* a corrupt unit is NEVER OR-ed — stage completion stalls at the last
  verified checkpoint (graceful degradation) until the repair lands;
* the resume cursor is durable: a dropped connection replays from
  ``(unit_seq, byte_offset)`` without re-shipping verified units;
* transport runs are deterministic: a fixed (blob, trace, faults,
  policy) reproduces the identical event log, byte for byte;
* an exhausted retry budget is a typed :class:`TransportError`, never
  a silent partial model.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wire
from repro.core.progressive import divide
from repro.transmission.client import ProgressiveClient
from repro.transmission.session import FaultPolicy, Session, TransportError
from repro.transmission.simulator import BandwidthTrace, FaultTrace

TRACE = BandwidthTrace.constant(1e6)


@pytest.fixture(scope="module")
def setup():
    k = jax.random.PRNGKey(0)
    params = {
        "embed": jax.random.normal(k, (40, 12)),
        "w": jax.random.normal(jax.random.fold_in(k, 1), (16, 16)),
        "b": jnp.ones((16,)),
    }
    model = divide(params)
    blob = wire.encode(model, integrity=True)
    meta, hdr = wire.decode_header(blob)
    layout = wire.layout_from_header(meta, hdr)
    offs = layout.unit_offsets()
    sizes = [e[2] for st in layout.stages for e in st]
    # clean per-checkpoint store fingerprints: the bit-identity oracle
    fps = []
    ref = ProgressiveClient(
        on_stage_complete=lambda s: fps.append(ref.store.fingerprint()))
    ref.feed(blob)
    assert ref.complete
    return model, blob, layout, offs, sizes, fps


def _corrupt_unit(blob, offs, sizes, seq, *, flip=0x10, at=None):
    """Flip one byte inside unit ``seq``'s payload body (past the
    8-byte integrity frame)."""
    o, n = offs[seq], sizes[seq]
    i = o + (8 + (n - 8) // 2 if at is None else at)
    mut = bytearray(blob)
    mut[i] ^= flip
    return bytes(mut)


def _run_transport(blob, faults, *, policy=None, chunk=1024,
                   latency=0.01, fps_out=None):
    sess = Session(blob, TRACE, chunk_bytes=chunk, latency_s=latency)
    client = ProgressiveClient()
    if fps_out is not None:  # record a store fingerprint per checkpoint
        client._on_stage_complete = \
            lambda s: fps_out.append(client.store.fingerprint())
    events: list = []
    _, runner = sess._make_transport(client, events,
                                     faults, policy or FaultPolicy(seed=1))
    runner.pump_all()
    return client, runner, events


# -- the irreversibility claim -------------------------------------------------

def test_force_ingesting_a_corrupt_plane_diverges_forever(setup):
    """No amount of later clean data can undo a corrupt OR: the
    accumulator fingerprint never returns to the clean trajectory."""
    model, blob, layout, offs, sizes, clean_fps = setup
    seq = 1
    bad_blob = _corrupt_unit(blob, offs, sizes, seq)
    # decode the damaged unit as if no verification existed and force
    # it into the store (what a CRC-less client would do)
    entries = [e for st in layout.stages for e in st]
    idx, w, nbytes, n_el = entries[seq]
    o = offs[seq]
    bad_body = bad_blob[o + 8:o + nbytes]  # strip <seq><crc>, keep v2 frame
    bad_plane = wire.decode_plane(bad_body, w, n_el, framed=True)

    poisoned_fps = []
    victim = ProgressiveClient()
    victim._on_stage_complete = lambda s: poisoned_fps.append(
        victim.store.fingerprint())
    victim.feed(bad_blob)           # the damaged unit is quarantined...
    assert seq in victim.nacks
    # ...but pretend verification passed (what a CRC-less client does):
    # accept the corrupt plane in place of the real one and let the
    # normal in-order ingest OR it into the accumulator
    victim._ready[seq] = (idx, bad_plane)
    victim._verified.add(seq)
    del victim._nacks[seq]
    victim._advance_contig()
    assert victim.complete
    assert len(poisoned_fps) == len(clean_fps)
    for cp, (clean, poisoned) in enumerate(zip(clean_fps, poisoned_fps)):
        assert clean != poisoned, f"checkpoint {cp} should have diverged"


def test_quarantined_and_repaired_stream_is_bit_identical(setup):
    """The same corruption through the verify-before-ingest path:
    quarantine -> NACK -> repair -> every checkpoint bit-identical."""
    model, blob, layout, offs, sizes, clean_fps = setup
    seq = 1
    bad_blob = _corrupt_unit(blob, offs, sizes, seq)
    got_fps = []
    client = ProgressiveClient(
        on_stage_complete=lambda s: got_fps.append(client.store.fingerprint()))
    client.feed(bad_blob)
    assert seq in client.nacks
    assert client.stages_complete == 0  # stage 1 held back by the gap
    assert client.feed_repair(seq, blob[offs[seq]:offs[seq] + sizes[seq]])
    assert client.complete and not client.nacks
    assert got_fps == clean_fps  # bit-identical at EVERY checkpoint


def test_corrupt_plane_never_reaches_the_store(setup):
    """Pin the invariant directly: while a unit is quarantined, the
    accumulators contain exactly the verified-prefix state — the
    corrupt bytes never touched them."""
    model, blob, layout, offs, sizes, clean_fps = setup
    seq = 2
    client = ProgressiveClient()
    client.feed(_corrupt_unit(blob, offs, sizes, seq))
    assert seq in client.nacks
    # materialize flushes only the verified contiguous prefix
    client.materialize()
    fresh = ProgressiveClient()
    fresh.feed(blob[:offs[seq]])  # clean stream cut before the bad unit
    fresh.materialize()
    assert client.store.fingerprint() == fresh.store.fingerprint()


# -- graceful degradation --------------------------------------------------------

def test_stage_completion_stalls_at_last_verified_checkpoint(setup):
    """Units past a quarantined gap arrive and verify but must NOT
    complete later stages: the serving engine keeps decoding at the
    last verified stage until the repair lands, then catches up."""
    model, blob, layout, offs, sizes, clean_fps = setup
    cp_units = []
    acc = 0
    for st in layout.stages:
        acc += len(st)
        cp_units.append(acc)
    # corrupt the first unit of stage 2
    seq = cp_units[0]
    client = ProgressiveClient()
    client.feed(_corrupt_unit(blob, offs, sizes, seq))
    assert client.stages_complete == 1  # stage 1 verified, stage 2+ held
    assert client.verified_units == len(offs) - 1
    assert client.feed_repair(seq, blob[offs[seq]:offs[seq] + sizes[seq]])
    assert client.complete  # one repair releases everything held


# -- resume cursor ----------------------------------------------------------------

def test_resume_cursor_replays_without_reshipping(setup):
    model, blob, layout, offs, sizes, clean_fps = setup
    client = ProgressiveClient()
    cut = offs[3] + 5  # mid-unit disconnect
    client.feed(blob[:cut])
    dropped = client.drop_unconsumed()
    assert dropped == 5  # the partial frame is discarded
    seq, off = client.resume_cursor
    assert (seq, off) == (3, offs[3])
    client.feed(blob[off:])  # replay EXACTLY from the cursor
    assert client.complete
    client.materialize()
    assert client.store.fingerprint() == clean_fps[-1]


def test_header_corruption_restarts_from_zero(setup):
    model, blob, *_ = setup
    mut = bytearray(blob)
    mut[16] ^= 0x01  # inside the JSON body -> header CRC mismatch
    client = ProgressiveClient()
    client.feed(bytes(mut))
    assert client.header_failed and not client.header_ready
    assert client.resume_cursor == (0, 0)
    client.feed(blob)  # fresh stream from byte 0
    assert client.complete


# -- full sessions under injected faults ----------------------------------------

@pytest.mark.parametrize("faults", [
    FaultTrace(seed=3, p_corrupt=0.15),
    FaultTrace(seed=4, p_truncate=0.10),
    FaultTrace(seed=5, p_duplicate=0.10),
    FaultTrace(seed=6, p_reorder=0.10),
    FaultTrace(seed=7, p_disconnect=0.10),
    FaultTrace(seed=8, p_corrupt=0.06, p_truncate=0.04, p_duplicate=0.04,
               p_reorder=0.04, p_disconnect=0.04),
], ids=["corrupt", "truncate", "duplicate", "reorder", "disconnect", "mixed"])
def test_session_converges_bit_identical_under_faults(setup, faults):
    model, blob, layout, offs, sizes, clean_fps = setup
    got_fps = []
    client, runner, events = _run_transport(blob, faults, fps_out=got_fps)
    assert client.complete and not client.nacks
    assert got_fps == clean_fps, "checkpoint fingerprints diverged"


def test_retry_backoff_determinism(setup):
    """Same (blob, trace, faults, policy) -> byte-identical event log,
    including every backoff float."""
    model, blob, *_ = setup
    faults = FaultTrace(seed=8, p_corrupt=0.08, p_truncate=0.04,
                        p_disconnect=0.04)
    def log():
        _, _, events = _run_transport(blob, faults,
                                      policy=FaultPolicy(seed=2))
        return [(e.t_s, e.kind, json.dumps(e.data, sort_keys=True))
                for e in events]
    assert log() == log()


def test_exhausted_retries_raise_transport_error(setup):
    model, blob, *_ = setup
    with pytest.raises(TransportError):
        _run_transport(blob, FaultTrace(seed=9, p_corrupt=1.0),
                       policy=FaultPolicy(seed=1, max_retries=2))


def test_fault_injection_requires_integrity_wire(setup):
    model, *_ = setup
    v1 = wire.encode(model)
    sess = Session(v1, TRACE, chunk_bytes=1024)
    with pytest.raises(ValueError, match="v3 integrity wire"):
        sess._make_transport(ProgressiveClient(), [],
                             FaultTrace(seed=0, p_corrupt=0.1),
                             FaultPolicy())


def test_fault_policy_validation():
    with pytest.raises(ValueError):
        FaultPolicy(chunk_timeout_s=0.0)
    with pytest.raises(ValueError):
        FaultPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        FaultPolicy(jitter_frac=1.5)
    rng = np.random.default_rng(0)
    p = FaultPolicy(backoff_base_s=0.1, backoff_cap_s=0.5, jitter_frac=0.0)
    assert p.backoff_s(0, rng) == pytest.approx(0.1)
    assert p.backoff_s(1, rng) == pytest.approx(0.2)
    assert p.backoff_s(10, rng) == pytest.approx(0.5)  # capped
