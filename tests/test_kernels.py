"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle across
shapes / dtypes / bit-widths (interpret=True executes the kernel body on
CPU with TPU semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplanes
from repro.core.quantize import dequant_affine, quantize, container_dtype
from repro.kernels import ref
from repro.kernels.bitplane import plane_extract, plane_or
from repro.kernels.decode_attention import flash_decode
from repro.kernels.dequant_matmul import dequant_matmul


# ---------------------------------------------------------------------------
# dequant_matmul — the eq.-(5) affine rides in as traced operands from
# the one shared dequant_affine helper (never recomputed per call site)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(8, 16, 8), (96, 200, 130), (128, 128, 128),
                                   (1, 64, 257), (33, 500, 65)])
@pytest.mark.parametrize("bits", [4, 8, 16])
def test_dequant_matmul_shapes_bits(M, K, N, bits):
    kx, kw = jax.random.split(jax.random.PRNGKey(M * 1000 + K + N + bits))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 3.0 + 0.5
    qt = quantize(w, bits)
    scale, offset = dequant_affine(qt.lo, qt.hi, bits)
    y = dequant_matmul(x, qt.q, scale, offset,
                       bm=32, bn=64, bk=64, interpret=True)
    yr = ref.dequant_matmul_ref(x, qt.q, scale, offset)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_input_dtypes(x_dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64)).astype(x_dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
    qt = quantize(w, 16)
    scale, offset = dequant_affine(qt.lo, qt.hi, 16)
    y = dequant_matmul(x, qt.q, scale, offset, bm=16, bn=16, bk=32,
                       interpret=True)
    yr = ref.dequant_matmul_ref(x.astype(jnp.float32), qt.q, scale, offset)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("received", [2, 6, 10, 16])
def test_dequant_matmul_partial_precision(received):
    """Consuming a truncated accumulator must equal the oracle at the
    received precision (the serving engine's mid-transmission matmul)."""
    from repro.core.quantize import truncate

    x = jax.random.normal(jax.random.PRNGKey(2), (16, 40))
    w = jax.random.normal(jax.random.PRNGKey(3), (40, 24))
    qt = truncate(quantize(w, 16), received)
    scale, offset = dequant_affine(qt.lo, qt.hi, 16, received_bits=received)
    y = dequant_matmul(x, qt.q, scale, offset,
                       bm=16, bn=16, bk=16, interpret=True)
    yr = ref.dequant_matmul_ref(x, qt.q, scale, offset)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-5, atol=3e-4)


def test_dequant_matmul_zero_received_uses_range_centre():
    x = jnp.ones((4, 8))
    q = jnp.zeros((8, 4), jnp.uint16)
    lo, hi = jnp.float32(-1.0), jnp.float32(3.0)
    scale, offset = dequant_affine(lo, hi, 16, received_bits=0)
    y = dequant_matmul(x, q, scale, offset,
                       bm=4, bn=4, bk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y), 8 * 1.0, rtol=1e-5)


def test_dequant_matmul_upgrade_changes_values_not_executables():
    """received_bits is NOT a static argument: sweeping it must reuse
    one compiled executable (the zero-recompile upgrade contract)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 32))
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    qt = quantize(w, 16)
    before = dequant_matmul._cache_size()
    outs = []
    for m in (2, 4, 8, 16):
        scale, offset = dequant_affine(qt.lo, qt.hi, 16, received_bits=m)
        outs.append(dequant_matmul(x, qt.q, scale, offset,
                                   bm=16, bn=16, bk=32, interpret=True))
    assert dequant_matmul._cache_size() - before <= 1
    # sanity: different precisions produce different numbers
    assert not np.allclose(np.asarray(outs[0]), np.asarray(outs[-1]))


# ---------------------------------------------------------------------------
# bitplane kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (64,), (37, 53), (3, 5, 11)])
@pytest.mark.parametrize("widths", [(2,) * 8, (1, 3, 12), (8, 8), (16,)])
def test_plane_extract_or_roundtrip(shape, widths):
    x = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape)
    qt = quantize(x, 16)
    cum = (0,) + bitplanes.cumulative(widths)
    acc = jnp.zeros_like(qt.q)
    for m, w in enumerate(widths, 1):
        pk = plane_extract(qt.q, bits=16, before=cum[m - 1], width=w,
                           interpret=True)
        want = bitplanes.split_plane(qt.q, 16, widths, m)
        assert (np.asarray(pk) == np.asarray(want, np.uint16)).all()
        acc = plane_or(acc, pk, shift=16 - cum[m], interpret=True)
    assert (np.asarray(acc) == np.asarray(qt.q)).all()


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_plane_or_matches_ref(bits):
    k1, k2 = jax.random.split(jax.random.PRNGKey(bits))
    dt = container_dtype(bits)
    acc = jax.random.randint(k1, (129,), 0, 2 ** (bits // 2)).astype(dt)
    plane = jax.random.randint(k2, (129,), 0, 4).astype(dt)
    shift = bits - 2
    got = plane_or(acc, plane, shift=shift, interpret=True)
    want = ref.plane_or_ref(acc, plane, shift)
    assert (np.asarray(got) == np.asarray(want)).all()


# ---------------------------------------------------------------------------
# flash decode attention (ragged batches, native (B, Kh, S, hd) layout)
# ---------------------------------------------------------------------------

def _ragged_inputs(key, B, H, Kh, hd, S, pos):
    """Random q/k/v in native layout + lock-stepped position operands
    (every slot at ``pos``)."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q_pos = jnp.full((B,), pos, jnp.int32)
    return q, k, v, k_pos, q_pos


@pytest.mark.parametrize("B,H,Kh,hd,S", [
    (1, 4, 4, 32, 64),     # MHA
    (2, 8, 2, 64, 300),    # GQA, ragged S (block shrinks to a divisor)
    (2, 16, 1, 32, 128),   # MQA
    (1, 8, 8, 128, 1024),  # long-ish
])
def test_flash_decode_vs_ref(B, H, Kh, hd, S):
    q, k, v, k_pos, q_pos = _ragged_inputs(
        jax.random.PRNGKey(B + H + S), B, H, Kh, hd, S, S * 3 // 4)
    o = flash_decode(q, k, v, k_pos, q_pos, bs=128, interpret=True)
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_decode_window(window):
    B, H, Kh, hd, S = 2, 8, 4, 32, 200
    q, k, v, k_pos, q_pos = _ragged_inputs(
        jax.random.PRNGKey(window), B, H, Kh, hd, S, 150)
    o = flash_decode(q, k, v, k_pos, q_pos, window=window, bs=64,
                     interpret=True)
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


def test_flash_decode_softcap_and_ring_positions():
    """Ring-buffer slot positions (unordered, with overwrites, per-slot
    write depths) must work."""
    from repro.models.attention import ring_positions

    B, H, Kh, hd, W = 2, 4, 2, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, W, hd))
    v = jax.random.normal(ks[2], (B, Kh, W, hd))
    q_pos = jnp.array([50, 17], jnp.int32)  # one wrapped ring, one not
    k_pos = ring_positions(W, q_pos)        # (B, W)
    o = flash_decode(q, k, v, k_pos, q_pos, window=W, softcap=20.0,
                     bs=16, interpret=True)
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos, window=W,
                               softcap=20.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


# -- ragged-parity sweeps: kernel (interpret) vs the chunked_attention
#    oracle, per-slot positions / GQA / window / softcap / empty slots ------

def _chunked_oracle(q, k, v, k_pos, q_pos, *, window=0, softcap=0.0):
    """Per-slot chunked_attention reference: runs each slot as its own
    B=1 sequence-major call, i.e. the PR-3 single-stream semantics."""
    from repro.models.attention import chunked_attention

    B = q.shape[0]
    outs = []
    for b in range(B):
        ob = chunked_attention(
            q[b][None, None],                      # (1, 1, H, hd)
            jnp.swapaxes(k[b], 0, 1)[None],        # (1, S, Kh, hd)
            jnp.swapaxes(v[b], 0, 1)[None],
            q_pos[b][None],
            k_pos[b],
            causal=True, window=window, softcap=softcap, chunk=32,
        )[0, 0]
        outs.append(ob)
    return jnp.stack(outs)


@pytest.mark.parametrize("Kh,window,softcap", [
    (4, 0, 0.0),    # MHA
    (2, 0, 0.0),    # GQA groups
    (2, 24, 0.0),   # sliding window
    (1, 0, 30.0),   # MQA + softcap
    (2, 16, 25.0),  # everything at once
])
def test_flash_decode_ragged_parity_vs_chunked(Kh, window, softcap):
    """Every slot at its own position (including one EMPTY slot with
    q_pos = -1 and k_pos all -1): the batched kernel must equal the
    single-stream chunked_attention oracle slot by slot — this is the
    contract that makes slot-pool decode token-identical to the
    lock-stepped path."""
    B, H, hd, S = 4, 8, 32, 96
    ks = jax.random.split(jax.random.PRNGKey(Kh * 100 + window), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    q_pos = jnp.array([95, 40, 7, -1], jnp.int32)  # ragged + one empty
    base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    k_pos = jnp.where(q_pos[:, None] >= 0, base, -1)

    got = flash_decode(q, k, v, k_pos, q_pos, window=window,
                       softcap=softcap, bs=32, interpret=True)
    live = [b for b in range(B) if int(q_pos[b]) >= 0]
    want_live = _chunked_oracle(
        q[jnp.array(live)], k[jnp.array(live)], v[jnp.array(live)],
        k_pos[jnp.array(live)], q_pos[jnp.array(live)],
        window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got)[live], np.asarray(want_live),
                               rtol=2e-5, atol=2e-5)
    # the empty slot's row must be finite garbage, never NaN/Inf
    assert bool(jnp.all(jnp.isfinite(got[3])))
    # and it must equal the jnp oracle exactly on the same inputs
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos, window=window,
                               softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_divisor_hostile_length_pads_tail():
    """A prime cache length can't shrink the block to a useful divisor;
    the wrapper must fall back to masked tail padding and stay exact."""
    B, H, Kh, hd, S = 2, 4, 2, 32, 97  # prime S
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    q_pos = jnp.array([96, 40], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    o = flash_decode(q, k, v, k_pos, q_pos, bs=32, interpret=True)
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_all_slots_empty_is_finite():
    """A fully idle pool (every k_pos = -1) still runs one launch and
    produces finite output."""
    B, H, Kh, hd, S = 3, 4, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    k_pos = jnp.full((B, S), -1, jnp.int32)
    q_pos = jnp.full((B,), -1, jnp.int32)
    o = flash_decode(q, k, v, k_pos, q_pos, bs=32, interpret=True)
    assert bool(jnp.all(jnp.isfinite(o)))
    orf = ref.flash_decode_ref(q, k, v, k_pos, q_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_dispatch_matches_kernel():
    """ops.decode_attention (the model's entry point: oracle on CPU,
    Pallas on TPU) agrees with the interpret-mode kernel on identical
    ragged operands."""
    from repro.kernels import ops

    B, H, Kh, hd, S = 3, 8, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    q_pos = jnp.array([63, 20, 5], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    got = ops.decode_attention(q, k, v, k_pos, q_pos)
    want = flash_decode(q, k, v, k_pos, q_pos, bs=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_verify — draft-block verify attention (T = k+1 ragged queries
# per slot, one cache pass)
# ---------------------------------------------------------------------------

from repro.kernels.verify_attention import flash_verify


def _verify_inputs(key, B, T, H, Kh, hd, S, bases):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    base = jnp.asarray(bases, jnp.int32)
    q_pos = jnp.where(base[:, None] >= 0,
                      base[:, None] + jnp.arange(T, dtype=jnp.int32),
                      -1)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    k_pos = jnp.where(base[:, None] >= 0, k_pos, -1)
    return q, k, v, k_pos, q_pos


@pytest.mark.parametrize("B,T,H,Kh,hd,S", [
    (1, 5, 4, 4, 32, 64),     # MHA
    (2, 3, 8, 2, 64, 300),    # GQA + divisor-shrunk block
    (2, 9, 16, 1, 32, 128),   # MQA, long draft block
    (3, 2, 4, 2, 32, 97),     # prime S: masked tail padding
])
def test_flash_verify_vs_ref(B, T, H, Kh, hd, S):
    q, k, v, k_pos, q_pos = _verify_inputs(
        jax.random.PRNGKey(B * 100 + T + S), B, T, H, Kh, hd, S,
        [S - T - 1] + [max(0, S // (b + 2) - T) for b in range(1, B)])
    o = flash_verify(q, k, v, k_pos, q_pos, bs=64, interpret=True)
    orf = ref.flash_verify_ref(q, k, v, k_pos, q_pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_verify_window_and_ragged_rows(window):
    """Sliding-window verify with per-row positions AND ragged draft
    lengths: slot 1's last two rows are padding (q_pos = -1), slot 2 is
    a free pool slot (whole row masked). Padding/free rows must come
    out finite and live rows must match the oracle."""
    B, T, H, Kh, hd, S = 3, 4, 8, 2, 32, 96
    ks = jax.random.split(jax.random.PRNGKey(window), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, Kh, S, hd))
    v = jax.random.normal(ks[2], (B, Kh, S, hd))
    q_pos = jnp.array([[60, 61, 62, 63],
                       [30, 31, -1, -1],
                       [-1, -1, -1, -1]], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    o = flash_verify(q, k, v, k_pos, q_pos, window=window, bs=32,
                     interpret=True)
    orf = ref.flash_verify_ref(q, k, v, k_pos, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=2e-5, atol=2e-5)
    assert bool(jnp.all(jnp.isfinite(o)))


def test_flash_verify_row_matches_flash_decode():
    """Each live verify row must equal a single-token flash_decode call
    at the same position against the same cache — the kernel-level face
    of 'verify logits == sequential decode logits' that makes
    speculative decoding lossless."""
    B, T, H, Kh, hd, S = 2, 4, 8, 2, 32, 64
    q, k, v, k_pos, q_pos = _verify_inputs(
        jax.random.PRNGKey(3), B, T, H, Kh, hd, S, [40, 9])
    o = flash_verify(q, k, v, k_pos, q_pos, bs=32, interpret=True)
    for t in range(T):
        ot = flash_decode(q[:, t], k, v, k_pos, q_pos[:, t], bs=32,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(o[:, t]), np.asarray(ot),
                                   rtol=2e-5, atol=2e-5)


def test_verify_attention_dispatch_matches_kernel():
    """ops.verify_attention (oracle on CPU, Pallas on TPU) agrees with
    the interpret-mode kernel on identical operands."""
    from repro.kernels import ops

    B, T, H, Kh, hd, S = 2, 3, 4, 2, 32, 64
    q, k, v, k_pos, q_pos = _verify_inputs(
        jax.random.PRNGKey(29), B, T, H, Kh, hd, S, [50, 12])
    got = ops.verify_attention(q, k, v, k_pos, q_pos)
    want = flash_verify(q, k, v, k_pos, q_pos, bs=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
