"""Per-architecture smoke tests on reduced variants (2 layers-ish,
d_model <= 512, <= 4 experts): one forward + one train step on CPU with
shape and finiteness asserts, plus prefill+decode vs full-forward
consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model
from repro.train import optimizer as opt
from repro.launch.steps import make_train_step

ARCH_LIST = [a for a in ARCHS if a != "progressivenet_cnn"]


def tiny_batch(cfg, B=2, S=24, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab).astype(jnp.int32),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab).astype(jnp.int32),
    }
    if cfg.enc_layers:
        batch["enc_input"] = 0.1 * jax.random.normal(
            jax.random.fold_in(k, 1),
            (B, max(1, S // cfg.enc_seq_divisor), cfg.d_model),
        ).astype(cfg.dtype)
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(k, 2), (B, cfg.vision_tokens, cfg.d_vision)
        ).astype(cfg.dtype)
    return batch


import functools


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCH_LIST)
def test_reduced_dims_within_smoke_budget(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.n_layers <= max(2, len(cfg.cycle))
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_LIST)
def test_forward_shapes_and_finite(arch):
    cfg, model, params = _setup(arch)
    B, S = 2, 24
    batch = tiny_batch(cfg, B, S)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux["balance_loss"]))


@pytest.mark.parametrize("arch", ARCH_LIST)
def test_one_train_step_no_nans(arch):
    cfg, model, params = _setup(arch)
    batch = tiny_batch(cfg)
    step = jax.jit(make_train_step(model, opt.OptConfig(lr=1e-3, total_steps=10)))
    opt_state = opt.init(params)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCH_LIST)
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill must reproduce the full
    forward's logits (same tokens), validating every cache path. MoE
    reduced configs use drop-free capacity so routing is identical."""
    cfg, model, params = _setup(arch)
    B, S, extra = 1, 16, 4
    batch = tiny_batch(cfg, B, S + extra, seed=3)
    full_logits, _ = model.forward(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S]
    pre_batch.pop("labels")
    last, caches = model.prefill(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, S - 1]), rtol=2e-3, atol=2e-3
    )

    caches = model.grow_caches(caches, S + extra)
    for t in range(extra):
        tok = batch["tokens"][:, S + t : S + t + 1]
        logits, caches = model.decode_step(params, caches, tok, jnp.int32(S + t))
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full_logits[:, S + t]),
            rtol=3e-3,
            atol=3e-3,
            err_msg=f"{arch} decode step {t}",
        )


@pytest.mark.parametrize("arch", ["gemma3-27b", "mixtral-8x22b"])
def test_sliding_window_ring_cache_consistency(arch):
    """Run decode past the window so the ring buffer wraps; logits must
    still match the full forward (window semantics are position-based)."""
    cfg = get_config(arch).reduced(window=8, attn_chunk=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S, extra = 1, 12, 6  # decode positions 12..17 > window 8
    batch = tiny_batch(cfg, B, S + extra, seed=5)
    full_logits, _ = model.forward(params, batch)
    pre = {"tokens": batch["tokens"][:, :S]}
    last, caches = model.prefill(params, pre)
    caches = model.grow_caches(caches, S + extra)
    for t in range(extra):
        tok = batch["tokens"][:, S + t : S + t + 1]
        logits, caches = model.decode_step(params, caches, tok, jnp.int32(S + t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, S + t]),
            rtol=3e-3, atol=3e-3, err_msg=f"step {t}",
        )


@pytest.mark.parametrize("arch", ARCH_LIST)
def test_costing_variant_same_function(arch):
    """cfg.costing unrolls scans but must compute the same function."""
    cfg, model, params = _setup(arch)
    model_c = build_model(cfg.for_costing())
    batch = tiny_batch(cfg, seed=9)
    la, _ = model.forward(params, batch)
    lb, _ = model_c.forward(params, batch)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=2e-4, atol=2e-4)


def test_input_specs_cover_all_inputs():
    for arch in ARCH_LIST:
        cfg = get_config(arch)
        model = build_model(cfg)
        for mode in ("train", "prefill", "decode"):
            specs = model.input_specs(batch=4, seq_len=64, mode=mode)
            assert "tokens" in specs
            if mode != "decode":
                if cfg.enc_layers:
                    assert "enc_input" in specs
                if cfg.vision_tokens:
                    assert "vision_embeds" in specs
