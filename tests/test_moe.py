"""MoE dispatch properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe


@pytest.fixture(scope="module")
def cfg():
    return get_config("dbrx-132b").reduced()


def test_capacity_formula(cfg):
    c = moe.capacity(cfg, 128)
    assert c >= cfg.top_k
    assert c == int(cfg.capacity_factor * 128 * cfg.top_k / cfg.n_experts)


def test_output_finite_and_shaped(cfg):
    p = moe.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), cfg.dtype)
    y, aux = moe.moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


def test_dropfree_capacity_matches_dense_mixture(cfg):
    """With capacity >= T*K/E guaranteed drop-free, token-choice dispatch
    must equal the explicit per-token mixture of its top-k experts."""
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    p = moe.moe_init(cfg, jax.random.PRNGKey(0))
    B, T = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model), jnp.float32)
    y, aux = moe.moe_apply(cfg, p, x)
    assert float(aux["dropped_frac"]) == 0.0

    # dense reference: every token through its top-k experts
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    from repro.models.common import activation

    for b in range(B):
        for t in range(T):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.top_k):
                e = int(gi[b, t, j])
                h = activation(cfg, x[b, t] @ p["we_gate"][e]) * (x[b, t] @ p["we_up"][e])
                acc = acc + gv[b, t, j] * (h @ p["we_down"][e])
            want = want.at[b, t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_balance_loss_favors_uniform_routing(cfg):
    p = moe.moe_init(cfg, jax.random.PRNGKey(0))
    # force collapsed routing: with positive activations, a positive
    # column-0 router weight makes logit_0 = sum(x) >> others
    p_bad = dict(p)
    router = np.zeros_like(np.asarray(p["router"]))
    router[:, 0] = 1.0
    p_bad["router"] = jnp.asarray(router)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))) + 0.1
    _, aux_ok = moe.moe_apply(cfg, p, x.astype(jnp.float32))
    _, aux_bad = moe.moe_apply(cfg, p_bad, x.astype(jnp.float32))
    # balanced top-k routing scores ~K; collapsed-to-fixed-pair scores ~2K
    assert float(aux_bad["balance_loss"]) > 1.3 * float(aux_ok["balance_loss"])


def test_tight_capacity_drops_tokens(cfg):
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    p = moe.moe_init(cfg, jax.random.PRNGKey(0))
    p = dict(p)
    router = np.zeros_like(np.asarray(p["router"]))
    router[:, 0] = 1.0  # everyone wants expert 0 -> overflow
    p["router"] = jnp.asarray(router)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (1, 64, cfg.d_model))) + 0.1
    x = x.astype(jnp.float32)
    _, aux = moe.moe_apply(cfg, p, x)
    assert float(aux["dropped_frac"]) > 0.1
