"""The telemetry stack: registry, percentiles, exporters, tracer,
event schema, and the ``repro-telemetry`` analyzer.

Pins the ISSUE-10 acceptance surface:

* exact percentiles agree with ``np.percentile`` oracles (including
  random samples, extreme q, and tiny inputs);
* the registry interns by name, rejects kind collisions, and hands the
  shared no-op metric out while disabled;
* the Prometheus export round-trips through :func:`parse_prometheus`
  with values intact, and malformed text raises;
* every event a real ``browser-3g`` and ``browser-3g-lossy`` session
  emits validates against the schema registry — renames and payload
  drift fail loudly;
* ``repro-telemetry`` renders per-stage / latency / stall tables with
  p50/p99 from a SessionResult JSONL alone.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core import wire
from repro.core.progressive import divide
from repro.models.model import build_model
from repro.obs import report as report_mod
from repro.obs.exporters import (parse_prometheus, to_jsonl, to_prometheus,
                                 to_summary)
from repro.obs.registry import (NULL_METRIC, Histogram, MetricsRegistry,
                                percentile)
from repro.obs.schema import (EVENT_SCHEMAS, SchemaError, validate_event,
                              validate_jsonl)
from repro.obs.tracer import Tracer
from repro.transmission import Session, get_scenario
from repro.transmission.session import FaultPolicy, SessionEvent


@pytest.fixture(scope="module")
def served():
    cfg = get_config("olmo-1b").reduced(n_layers=2, d_model=64, d_ff=128,
                                        vocab=128, n_heads=2, n_kv=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prog = divide(params)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab).astype(jnp.int32)}
    return cfg, model, prog, batch


# ---------------------------------------------------------------------------
# percentiles: pinned against numpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q", [0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0])
@pytest.mark.parametrize("n", [1, 2, 3, 17, 100])
def test_percentile_matches_numpy_oracle(q, n):
    rng = np.random.default_rng(n * 1000 + int(q))
    vals = rng.normal(size=n).tolist()
    assert percentile(vals, q) == pytest.approx(
        float(np.percentile(vals, q)), rel=1e-12, abs=1e-12)


def test_percentile_random_q_sweep():
    rng = np.random.default_rng(7)
    vals = (rng.uniform(-1e3, 1e3, size=257)).tolist()
    for q in rng.uniform(0, 100, size=50):
        assert percentile(vals, float(q)) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-9)


def test_percentile_edge_cases():
    import math
    assert math.isnan(percentile([], 50.0))
    assert percentile([4.0], 0.0) == 4.0 == percentile([4.0], 100.0)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], 101.0)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], -0.5)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_interning_labels_and_stats():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("reqs_total", "requests")
    assert reg.counter("reqs_total") is c          # interned by name
    c.inc(); c.inc(2, route="a"); c.inc(route="a")
    assert c.value() == 1.0
    assert c.value(route="a") == 3.0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)

    g = reg.gauge("depth")
    g.set(5); g.inc(2); g.dec(3)
    assert g.value() == 4.0

    h = reg.histogram("lat_s")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v, path="x")
    st = h.stats(quantiles=(50, 99), path="x")
    assert st["count"] == 4 and st["sum"] == pytest.approx(1.0)
    assert st["min"] == 0.1 and st["max"] == 0.4
    assert st["p50"] == pytest.approx(np.percentile([0.1, 0.2, 0.3, 0.4], 50))
    assert [m.name for m in reg.collect()] == ["depth", "lat_s", "reqs_total"]


def test_registry_kind_collision_raises():
    reg = MetricsRegistry(enabled=True)
    reg.counter("x_total")
    with pytest.raises(TypeError, match="already registered as counter"):
        reg.histogram("x_total")


def test_disabled_registry_is_inert():
    reg = MetricsRegistry(enabled=False)
    m = reg.counter("never_total")
    assert m is NULL_METRIC is reg.histogram("also_never")
    m.inc(5, any_label="v")        # all no-ops, nothing registered
    assert len(reg) == 0 and reg.collect() == []
    assert NULL_METRIC.value() == 0.0 and NULL_METRIC.samples() == []


# ---------------------------------------------------------------------------
# exporters: Prometheus round-trip + summary/jsonl views
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry(enabled=True)
    reg.counter("bytes_total", "wire bytes").inc(1234, stage="1")
    reg.counter("bytes_total").inc(766, stage="2")
    reg.gauge("resident_bytes", "store residency").set(4096)
    h = reg.histogram("ttft_s", "time to first token")
    for v in (0.5, 1.0, 1.5, 2.0):
        h.observe(v, engine="pool")
    return reg


def test_prometheus_round_trip():
    reg = _populated_registry()
    text = to_prometheus(reg)
    fams = parse_prometheus(text)
    assert fams["bytes_total"]["type"] == "counter"
    assert fams["bytes_total"]["samples"]['bytes_total{stage="1"}'] == 1234.0
    assert fams["resident_bytes"]["type"] == "gauge"
    assert fams["resident_bytes"]["samples"]["resident_bytes"] == 4096.0
    # histograms export as summaries with exact quantiles + sum/count
    s = fams["ttft_s"]["samples"]
    assert fams["ttft_s"]["type"] == "summary"
    assert s['ttft_s{engine="pool",quantile="0.5"}'] == pytest.approx(
        float(np.percentile([0.5, 1.0, 1.5, 2.0], 50)))
    assert s['ttft_s_sum{engine="pool"}'] == pytest.approx(5.0)
    assert s['ttft_s_count{engine="pool"}'] == 4.0


@pytest.mark.parametrize("bad, match", [
    ("orphan_metric 1.0\n", "before its TYPE"),
    ("# TYPE x widget\nx 1\n", "unknown TYPE"),
    ("# TYPE x counter\nx notafloat\n", "bad value"),
    ("# HELP y only help\ny 2\n", "no TYPE line"),
], ids=["no-type", "bad-kind", "bad-float", "help-only"])
def test_parse_prometheus_rejects_malformed(bad, match):
    with pytest.raises(ValueError, match=match):
        parse_prometheus(bad)


def test_summary_and_jsonl_views():
    reg = _populated_registry()
    tracer = Tracer(reg)
    tracer.record("upgrade", wall_s=0.01, stage=3)
    summ = to_summary(reg, tracer)
    assert summ["counters"]["bytes_total"] == {"stage=1": 1234.0,
                                               "stage=2": 766.0}
    assert summ["gauges"]["resident_bytes"]["_"] == 4096.0
    hs = summ["histograms"]["ttft_s"]["engine=pool"]
    assert hs["count"] == 4 and "p99" in hs
    assert summ["spans"][0]["name"] == "upgrade"
    lines = to_jsonl(reg).strip().splitlines()
    recs = [json.loads(l) for l in lines]
    assert {r["metric"] for r in recs} == {"bytes_total", "resident_bytes",
                                           "ttft_s", "span_upgrade_wall_s"}
    assert all(r["type"] in ("counter", "gauge", "histogram") for r in recs)


# ---------------------------------------------------------------------------
# tracer: dual clocks
# ---------------------------------------------------------------------------

def test_tracer_dual_clock_records():
    reg = MetricsRegistry(enabled=True)
    tr = Tracer(reg)
    wall_only = tr.record("decode_window", wall_s=0.02, engine="pool")
    sim_only = tr.record("stage_arrival", sim_t0=0.0, sim_t1=3.5, stage=2)
    both = tr.record("upgrade_ingest", wall_s=0.001, sim_t0=1.0, sim_t1=1.25)
    assert wall_only.sim_s is None and "wall_s" in wall_only.to_dict()
    assert sim_only.wall_s is None and sim_only.sim_s == pytest.approx(3.5)
    assert both.to_dict()["sim_s"] == pytest.approx(0.25)
    # spans feed per-clock histograms
    assert isinstance(reg.get("span_decode_window_wall_s"), Histogram)
    assert reg.get("span_stage_arrival_sim_s").count(stage=2) == 1
    assert reg.get("span_stage_arrival_wall_s") is None
    assert tr.of("decode_window") == [wall_only]


def test_tracer_inert_when_disabled():
    reg = MetricsRegistry(enabled=False)
    tr = Tracer(reg)
    assert tr.record("x", wall_s=1.0) is None
    with tr.span("y"):
        pass
    assert tr.spans == [] and len(reg) == 0


def test_global_telemetry_context_restores_and_clears():
    assert not obs.enabled()          # default-off is the contract
    with obs.telemetry(True) as reg:
        assert obs.enabled()
        reg.counter("scratch_total").inc()
        assert len(reg) == 1
    assert not obs.enabled()
    assert obs.get_registry().get("scratch_total") is None  # cleared


# ---------------------------------------------------------------------------
# event schema: replay real sessions
# ---------------------------------------------------------------------------

def test_schema_replay_browser_3g(served):
    """Every event of a clean browser-3g serving run validates; the
    JSONL export validates line by line."""
    cfg, model, prog, batch = served
    blob = wire.encode(prog)
    session = Session.from_scenario(blob, get_scenario("browser-3g"), seed=3)
    res = session.run_serving(model, prog, decode_steps=6, batch=batch)
    assert len(res.events) > 0
    for e in res.events:
        validate_event(e)
    assert validate_jsonl(res.to_jsonl()) == len(res.events)
    kinds = {e.kind for e in res.events}
    assert {"chunk", "stage_complete", "cold_start", "decode_step"} <= kinds


def test_schema_replay_browser_3g_lossy(served):
    """The fault-channel kinds (fault/quarantine/nack/repair/reconnect/
    transport_summary) validate too, on a real lossy run over the v3
    integrity wire."""
    cfg, model, prog, batch = served
    blob = wire.encode(prog, integrity=True)
    scenario = get_scenario("browser-3g-lossy")
    assert scenario.lossy
    # the reduced blob is only a handful of catalog-sized chunks, too
    # few draws for the ~1% channel to fire; shrink the chunk grid so
    # the lossy path deterministically exercises its event kinds
    session = Session.from_scenario(blob, scenario, seed=3, chunk_bytes=512)
    res = session.run_serving(model, prog, decode_steps=6, batch=batch,
                              faults=scenario.make_faults(3),
                              fault_policy=FaultPolicy(seed=1))
    for e in res.events:
        validate_event(e)
    assert validate_jsonl(res.to_jsonl()) == len(res.events)
    kinds = {e.kind for e in res.events}
    assert "transport_summary" in kinds
    assert kinds & {"fault", "quarantine", "nack", "repair", "reconnect"}


def test_schema_rejects_drift():
    with pytest.raises(SchemaError, match="unknown event kind"):
        validate_event(SessionEvent(0.0, "not_a_kind", {}))
    with pytest.raises(SchemaError, match="missing required"):
        validate_event(SessionEvent(0.0, "chunk", {"bytes": 10}))
    with pytest.raises(SchemaError, match="unexpected field"):
        validate_event(SessionEvent(0.0, "header", {"bytes": 1, "oops": 2}))
    with pytest.raises(SchemaError, match="got bool"):
        validate_event(SessionEvent(0.0, "chunk",
                                    {"bytes": True, "through": 1}))
    with pytest.raises(SchemaError, match="got str"):
        validate_event(SessionEvent(0.0, "repair",
                                    {"unit": 1, "attempt": 0, "ok": "yes"}))
    # JSONL records validate through the same path (envelope handling)
    with pytest.raises(SchemaError, match="envelope"):
        validate_jsonl('{"kind": "chunk", "bytes": 1, "through": 1}\n')
    assert "fault" in EVENT_SCHEMAS and EVENT_SCHEMAS["fault"].allow_extra


# ---------------------------------------------------------------------------
# repro-telemetry: the analyzer CLI
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def session_log(served, tmp_path_factory):
    cfg, model, prog, batch = served
    blob = wire.encode(prog)
    session = Session.from_scenario(blob, get_scenario("browser-3g"), seed=0)
    res = session.run_serving(model, prog, decode_steps=8, batch=batch)
    p = tmp_path_factory.mktemp("logs") / "browser3g.jsonl"
    p.write_text(res.to_jsonl())
    return p, res


def test_analyze_computes_stage_and_latency_tables(session_log):
    p, res = session_log
    rep = report_mod.analyze(report_mod.load_events(p))
    assert rep["events"] == len(res.events)
    stages = [r["stage"] for r in rep["stages"]]
    assert stages == sorted(stages) and stages[0] == 1
    for row in rep["stages"]:
        assert row["bytes"] > 0 and row["goodput_bps"] > 0
    assert rep["latency"]["ttft_s"] >= 0.0
    assert rep["latency"]["decode_gap_s"]["count"] >= 1
    assert "p50" in rep["stalls"]["chunk_gap_s"]
    assert "p99" in rep["stalls"]["chunk_gap_s"]


def test_analyze_accuracy_per_byte_column(session_log):
    p, _ = session_log
    events = report_mod.load_events(p)
    acc = {r["stage"]: 0.1 * r["stage"]
           for r in report_mod.analyze(events)["stages"]}
    rep = report_mod.analyze(events, accuracy=acc)
    for row in rep["stages"]:
        assert row["accuracy"] == pytest.approx(0.1 * row["stage"])
        assert row["acc_per_mb"] == pytest.approx(
            row["accuracy"] / (row["bytes"] / 2**20))


def test_report_cli_renders_tables(session_log, capsys):
    p, _ = session_log
    assert report_mod.main([str(p), "--validate"]) == 0
    out = capsys.readouterr().out
    assert "per-stage arrivals:" in out
    assert "ttft_s=" in out
    assert "p50" in out and "p99" in out


def test_report_cli_json_mode(session_log, capsys):
    p, _ = session_log
    assert report_mod.main([str(p), "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert str(p) in rep and rep[str(p)]["stages"]


def test_report_cli_check_prom(tmp_path, capsys):
    prom = tmp_path / "serve.prom"
    prom.write_text(to_prometheus(_populated_registry()))
    assert report_mod.main(["--check-prom", str(prom)]) == 0
    assert "OK" in capsys.readouterr().out
    bad = tmp_path / "bad.prom"
    bad.write_text("definitely not prometheus{ 1\n")
    with pytest.raises(ValueError):
        report_mod.main(["--check-prom", str(bad)])


def test_report_cli_requires_input(capsys):
    with pytest.raises(SystemExit):
        report_mod.main([])
